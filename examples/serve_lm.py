"""Serving example: batched request scheduling with prefill + decode against
a KV cache (reduced config on CPU; same code path as the decode dry-run).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-2b
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import serve


def main() -> None:
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv += ["--arch", "gemma2-2b"]
    sys.argv = [sys.argv[0], "--smoke", "--requests", "8", "--slots", "4",
                "--max-new", "8", *argv]
    serve.main()


if __name__ == "__main__":
    main()
