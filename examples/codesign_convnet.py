"""End-to-end HASCO flow on a CNN workload set (the paper's primary
scenario): ResNet convolution layers, edge power budget, GEMM vs CONV2D
intrinsics compared, Pareto front printed.

    PYTHONPATH=src python examples/codesign_convnet.py [--layers 6]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import Constraints, codesign, separate_design
from repro.core import workloads as W
from repro.core.hw_primitives import HWBuilder
from repro.core.pareto import pareto_mask


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--trials", type=int, default=10)
    ap.add_argument("--power-w", type=float, default=2.0)
    args = ap.parse_args()

    wl = W.cnn_set("resnet")[: args.layers]
    cons = Constraints(power_w=args.power_w)
    print(f"application: {len(wl)} ResNet convolutions, "
          f"edge budget {args.power_w} W")

    report = codesign(wl, intrinsics=["GEMM", "CONV2D"], constraints=cons,
                      n_trials=args.trials, n_init=4, seed=0)
    for intr, res in report.per_intrinsic.items():
        ys = res.pareto_ys
        print(f"\n{intr} Pareto front ({len(ys)} points):")
        print("  latency_s      power_w    area_um2")
        for lat, pw, area in sorted(map(tuple, ys)):
            print(f"  {lat:.4e}  {pw:9.3f}  {area:.3e}")

    base_hw = (HWBuilder("GEMM").reshapeArray([8, 8], depth=16)
               .addCache(256).partitionBanks(1).build())
    base = separate_design(wl, base_hw, tuned_software=True)
    print(f"\ndecoupled baseline: {base.describe()}")
    if report.solution:
        print(f"co-designed       : {report.solution.describe()}")
        print(f"co-design speedup : "
              f"{base.latency_s / report.solution.latency_s:.2f}x")
    else:
        print("no feasible point under the constraint — raise --trials")


if __name__ == "__main__":
    main()
