"""End-to-end driver example: train a (reduced) LM for a few hundred steps
with checkpointing, watchdog, prefetching — the full production code path on
host devices.  Any of the ten assigned architectures works via --arch.

    PYTHONPATH=src python examples/train_lm.py --arch rwkv6-3b --steps 200
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch import train


def main() -> None:
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv += ["--arch", "qwen3-8b"]
    if "--steps" not in argv:
        argv += ["--steps", "200"]
    sys.argv = [sys.argv[0], "--smoke", "--checkpoint-every", "50",
                "--global-batch", "16", "--seq-len", "64", *argv]
    train.main()


if __name__ == "__main__":
    main()
