"""Quickstart: HASCO co-design in ~40 lines.

Co-designs one accelerator (hardware intrinsic + parameters) and per-workload
schedules for a tiny two-workload application, saves the solution registry,
and runs the tuned GEMM Pallas kernel (interpret mode on CPU) with the
co-designed block shapes.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import Constraints, codesign
from repro.core import solution as registry
from repro.core import workloads as W
from repro.kernels import ops


def main() -> None:
    # 1. an "application": two tensor computations sharing one accelerator
    app = [W.conv2d(64, 32, 28, 28, name="conv3x3"),
           W.gemm(256, 256, 128, name="proj")]

    # 2. co-design: partition (TST matching) -> MOBO hardware DSE driven by
    #    heuristic+Q-learning software DSE -> constrained solution
    report = codesign(app, intrinsics=["GEMM"], n_trials=8, n_init=4,
                      constraints=Constraints(power_w=50.0), seed=0)
    sol = report.solution
    assert sol is not None, "no feasible design point under constraints"
    print("co-designed solution:")
    print(" ", sol.describe())
    for wname, sched in sol.schedules.items():
        print(f"  {wname}: {sched.describe()}")

    # 3. persist and consume: the registry feeds kernel block shapes
    path = Path("artifacts/solutions.json")
    registry.save("quickstart", sol, path)
    bm, bn, bk = registry.kernel_blocks("quickstart", path)
    print(f"tuned Pallas GEMM blocks: bm={bm} bn={bn} bk={bk}")

    a = jnp.asarray(np.random.default_rng(0).standard_normal((128, 96)),
                    jnp.float32)
    b = jnp.asarray(np.random.default_rng(1).standard_normal((96, 64)),
                    jnp.float32)
    out = ops.matmul(a, b, bm=min(bm, 64), bn=min(bn, 64), bk=min(bk, 64),
                     implementation="interpret")  # CPU: interpret the kernel
    ref = a @ b
    print("tuned kernel max err vs XLA:",
          float(jnp.max(jnp.abs(out - ref))))


if __name__ == "__main__":
    main()
