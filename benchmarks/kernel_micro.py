"""Kernel microbenchmarks: wall-clock us_per_call of the XLA implementations
on this host (CPU) + modeled TPU-v5e latency from the cost model.  Interpret-
mode Pallas timings are NOT reported (they measure the interpreter, not the
TPU); the dry-run roofline is the TPU-side evidence.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def timeit(fn, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    rng = np.random.default_rng(0)
    print("benchmark,kernel,shape,us_per_call,derived_gflops")
    m = n = k = 512
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    us = timeit(lambda x, y: ops.matmul(x, y, implementation="xla"), a, b)
    print(f"micro,gemm,{m}x{n}x{k},{us:.1f},{2*m*n*k/us/1e3:.2f}")

    q = jnp.asarray(rng.standard_normal((1, 1024, 8, 64)), jnp.bfloat16)
    kk = jnp.asarray(rng.standard_normal((1, 1024, 2, 64)), jnp.bfloat16)
    us = timeit(lambda q, k: ops.attention(q, k, k, implementation="xla"),
                q, kk)
    flops = 4 * 1024 * 1024 * 8 * 64
    print(f"micro,flash_attention,b1s1024h8d64,{us:.1f},{flops/us/1e3:.2f}")

    r = jnp.asarray(rng.standard_normal((1, 512, 8, 64)), jnp.float32)
    w = jnp.asarray(-np.exp(rng.standard_normal((1, 512, 8, 64)) * .3),
                    jnp.float32)
    u = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    us = timeit(lambda r, w: ops.rwkv6(r, r, r, w, u,
                                       implementation="xla")[0], r, w)
    print(f"micro,rwkv6,b1s512h8,{us:.1f},")

    x = jnp.asarray(rng.standard_normal((1, 512, 8, 64)), jnp.float32)
    av = jnp.asarray(-np.abs(rng.standard_normal((1, 512, 8)) * .3),
                     jnp.float32)
    bc = jnp.asarray(rng.standard_normal((1, 512, 8, 32)), jnp.float32)
    us = timeit(lambda x, a: ops.mamba2(x, a, bc, bc,
                                        implementation="xla")[0], x, av)
    print(f"micro,mamba2_ssd,b1s512h8n32,{us:.1f},")


if __name__ == "__main__":
    main()
