"""Paper Fig. 7: tensor computations × hardware intrinsics.

All four intrinsics get the same resource budget (the paper's 64 PEs +
256 KiB scratchpad); per (workload, intrinsic) the software DSE finds the
best schedule and we report normalized throughput.  Expected orderings
(paper §VII-B): TTM/GEMM prefer GEMM, conv prefers CONV2D, DOT is worst
everywhere, MTTKRP prefers GEMV (via the two-stage rewrite).
"""
from __future__ import annotations

import math

from repro.core import workloads as W
from repro.core.hw_primitives import HWBuilder
from repro.core.intrinsics import ALL_INTRINSICS
from repro.core.matching import partition_space
from repro.core.sw_dse import optimize

PE_BUDGET_HW = {
    # same 64-PE + 256 KiB budget, shaped per intrinsic family
    "GEMM": HWBuilder("GEMM").reshapeArray([8, 8], depth=16)
    .addCache(256).partitionBanks(2).build(),
    "CONV2D": HWBuilder("CONV2D").reshapeArray([8, 8], depth=16)
    .addCache(256).partitionBanks(2).build(),
    "GEMV": HWBuilder("GEMV").reshapeArray([8, 8], depth=8)
    .addCache(256).partitionBanks(2).build(),
    "DOT": HWBuilder("DOT").reshapeArray([8, 8], depth=64)
    .addCache(256).partitionBanks(2).build(),
}


def workload_sets() -> dict[str, list]:
    return {
        "GEMM": W.table1_gemm()[2:6],
        "TTM": W.table1_ttm()[2:6],
        "CONV": W.table1_conv()[:4],
        "MTTKRP": [w for i in (1, 3) for w in W.mttkrp_stages(
            *[64, 64, 64, 32][:4], name=f"mtt{i}")],
    }


HOST_FALLBACK_FLOPS = 1e9  # workloads the intrinsic cannot tile run here
# (paper §VII-B: the GEMM intrinsic covers only MTTKRP's first stage; the
#  uncovered stage determines the application-level preference)


def run(budget_rounds: int = 3, pool: int = 10) -> list[tuple]:
    rows = []
    intr = list(ALL_INTRINSICS.values())
    for comp_name, wl in workload_sets().items():
        part = partition_space(intr, wl)
        for iname, hw in PE_BUDGET_HW.items():
            total_lat, total_flops, covered = 0.0, 0.0, 0
            for w in wl:
                choices = part.get((w.name, iname))
                res_lat = math.inf
                if choices:
                    res = optimize(w, choices, hw, pool_size=pool,
                                   rounds=budget_rounds, k=4, seed=0)
                    res_lat = res.latency_s
                if math.isfinite(res_lat):
                    covered += 1
                else:
                    res_lat = w.flops() / HOST_FALLBACK_FLOPS
                total_lat += res_lat
                total_flops += w.flops()
            if covered:
                thr = total_flops / total_lat / 1e9  # GFLOP/s, app level
                rows.append((comp_name, iname, covered, thr,
                             total_lat * 1e6 / len(wl)))
    return rows


def main() -> None:
    rows = run()
    best = {}
    for comp, iname, covered, thr, us in rows:
        best.setdefault(comp, (0.0, ""))
        if thr > best[comp][0]:
            best[comp] = (thr, iname)
    print("benchmark,workload,intrinsic,covered,gflops,us_per_call")
    for comp, iname, covered, thr, us in rows:
        print(f"fig7,{comp},{iname},{covered},{thr:.3f},{us:.2f}")
    for comp, (thr, iname) in best.items():
        print(f"fig7_best,{comp},{iname},,{thr:.3f},")


if __name__ == "__main__":
    main()
