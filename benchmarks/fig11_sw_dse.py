"""Paper Fig. 11 + §VII-D: software quality on a fixed accelerator.

GEMMCore (16×16 PE, 256 KiB scratchpad) runs ResNet convolutions under three
software stacks:
  * library    — im2col conversion + array-shape splitting (Gemmini library
                 style; pays materialized im2col/col2im traffic),
  * template   — AutoTVM-style: fixed tensorize choice + source loop order,
                 only tile sizes tuned,
  * HASCO      — full tensorize-choice + primitive exploration
                 (heuristic + Q-learning).
Paper claims: HASCO ≈3.17× vs library, ≈1.21× vs AutoTVM.
"""
from __future__ import annotations

from repro.core import workloads as W
from repro.core.codesign import (human_template_choice, library_schedule,
                                 template_search)
from repro.core.cost_model import evaluate
from repro.core.hw_primitives import HWBuilder
from repro.core.intrinsics import GEMM
from repro.core.matching import match
from repro.core.sw_dse import optimize

GEMMCORE = (HWBuilder("GEMM").reshapeArray([16, 16], depth=16)
            .addCache(256).partitionBanks(2).build())


def run(n_layers: int = 10):
    rows = []
    for w in W.cnn_set("resnet")[:n_layers]:
        choices = match(GEMM, w)
        _, lib_lat, lib_ovh = library_schedule(w, GEMMCORE)
        tmpl_choice = human_template_choice(w, choices)
        tmpl = template_search(w, tmpl_choice, GEMMCORE, seed=0, budget=48)
        tmpl_lat = evaluate(w, tmpl, GEMMCORE).latency_s
        hasco = optimize(w, choices, GEMMCORE, pool_size=24, rounds=10, k=6,
                         seed=0)
        rows.append((w.name, lib_lat, lib_ovh, tmpl_lat, hasco.latency_s))
    return rows


def main() -> None:
    rows = run()
    print("benchmark,layer,library_us,im2col_overhead_us,template_us,"
          "hasco_us,speedup_vs_library,speedup_vs_template")
    gl, gt, gh = 0.0, 0.0, 0.0
    for name, lib, ovh, tmpl, hasco in rows:
        print(f"fig11,{name},{lib*1e6:.2f},{ovh*1e6:.2f},{tmpl*1e6:.2f},"
              f"{hasco*1e6:.2f},{lib/hasco:.2f},{tmpl/hasco:.2f}")
        gl += lib
        gt += tmpl
        gh += hasco
    print(f"fig11_summary,geo_total,,,,,"
          f"{gl/gh:.2f},{gt/gh:.2f}")


if __name__ == "__main__":
    main()
