"""Paper Table III: overall co-design benefit under power constraints.

Edge (2 W) and cloud (20 W) scenarios.  Baseline = the traditional decoupled
flow (a fixed default GEMMCore, AutoTVM-style software tuned afterwards);
HASCO-GEMMCore / HASCO-ConvCore = the full co-design loop per intrinsic.
Paper claims 1.25–1.44× latency from co-design, and ConvCore a further
≈1.42× on convolution sets.
"""
from __future__ import annotations

import math

from repro.core import workloads as W
from repro.core.codesign import Constraints, codesign, separate_design
from repro.core.hw_primitives import HWBuilder

SCENARIOS = {
    "edge": dict(power_w=2.0,
                 base=HWBuilder("GEMM").reshapeArray([8, 8], depth=16)
                 .addCache(256).partitionBanks(1).build()),
    "cloud": dict(power_w=20.0,
                  base=HWBuilder("GEMM").reshapeArray([64, 64], depth=64)
                  .addCache(1024).partitionBanks(1).build()),
}


def run(n_layers: int = 6, n_trials: int = 20):
    wl = W.cnn_set("resnet")[:n_layers]
    rows = []
    for scen, spec in SCENARIOS.items():
        cons = Constraints(power_w=spec["power_w"])
        base = separate_design(wl, spec["base"], tuned_software=True, seed=0)
        gemm = codesign(wl, intrinsics=["GEMM"], constraints=cons,
                        n_trials=n_trials, n_init=6, seed=0)
        conv = codesign(wl, intrinsics=["CONV2D"], constraints=cons,
                        n_trials=n_trials, n_init=6, seed=0)
        rows.append((scen, base, gemm.solution, conv.solution))
    return rows


def main() -> None:
    rows = run()
    print("benchmark,scenario,system,pe,vmem_kib,banks,latency_us,power_w,"
          "speedup_vs_baseline")
    for scen, base, gemm, conv in rows:
        def emit(tag, sol):
            if sol is None:
                print(f"table3,{scen},{tag},,,,inf,,")
                return
            hw = sol.hw
            sp = base.latency_s / sol.latency_s \
                if math.isfinite(sol.latency_s) else 0.0
            print(f"table3,{scen},{tag},{hw.pe_rows}x{hw.pe_cols},"
                  f"{hw.vmem_kib},{hw.banks},{sol.latency_s*1e6:.1f},"
                  f"{sol.power_w:.2f},{sp:.2f}")
        emit("baseline-GEMMCore", base)
        emit("HASCO-GEMMCore", gemm)
        emit("HASCO-ConvCore", conv)


if __name__ == "__main__":
    main()
