"""Observability overhead gate (DESIGN.md §13).

The observability layer's contract is that it may be left ON in serving
without changing results or meaningfully costing throughput.  This bench
runs the ROADMAP 10:1 short/long mixed scenario on the paged engine twice —
tracing disabled vs enabled (full lifecycle instrumentation: spans around
every decode/prefill step, per-request instants, queue/TTFT histograms,
page-pool gauges) — and gates:

  * traced tokens/s >= 0.97x untraced (best-of-2 each, interleaved so
    neither side systematically benefits from cache warmth);
  * per-request outputs BIT-IDENTICAL between the two runs (tracing must
    never perturb the math);
  * the exported trace replays every request's lifecycle: submit ->
    admit -> (preempt/resume)* -> retire, in order, with the trace's
    preempt count matching each request's ``preemptions`` field;
  * the exported telemetry artifact passes schema validation.

Prints CSV; merges metrics into ``artifacts/bench_results.json``.

    PYTHONPATH=src python -m benchmarks.bench_obs
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# the bench_serve mixed scenario: identical physical KV budget to 4 pinned
# slots x 48 rows, spent on 8 paged slots (see benchmarks/bench_serve.py)
N_REQUESTS = 22
MAX_NEW = 8
SLOTS = 8
PAGE_SIZE = 8
N_PAGES = 24
PREFILL_CHUNK = 16
LONG_EVERY = 11

MIN_RATIO = 0.97
ROUNDS = 2          # best-of-N per side, interleaved

LAST_METRICS: dict = {}


def _serve(cfg, params):
    from repro.launch.serve import make_requests, serve_requests

    reqs = make_requests(cfg, N_REQUESTS, MAX_NEW, seed=0,
                         long_every=LONG_EVERY)
    t0 = time.perf_counter()
    done, stats = serve_requests(cfg, params, reqs, slots=SLOTS,
                                 paged=True, page_size=PAGE_SIZE,
                                 n_pages=N_PAGES,
                                 prefill_chunk=PREFILL_CHUNK)
    dt = time.perf_counter() - t0
    return sorted(done, key=lambda r: r.rid), stats, dt


def _lifecycle_defects(tracer, done) -> list[str]:
    """Replay every request's lifecycle from the trace; [] == clean."""
    from repro.obs.trace import ARGS, NAME

    life: dict[int, list[str]] = {}
    for ev in tracer.events():
        if ev[NAME].startswith("req."):
            life.setdefault(ev[ARGS]["rid"], []).append(
                ev[NAME].removeprefix("req."))
    defects = []
    by_rid = {r.rid: r for r in done}
    if set(life) != set(by_rid):
        defects.append(f"traced rids {sorted(life)} != served "
                       f"{sorted(by_rid)}")
        return defects
    for rid, seq in sorted(life.items()):
        req = by_rid[rid]
        if seq[0] != "submit" or seq[-1] != "retire":
            defects.append(f"rid {rid}: lifecycle {seq} does not run "
                           f"submit..retire")
        if seq.count("admit") != 1:
            defects.append(f"rid {rid}: {seq.count('admit')} fresh admits")
        if seq.count("preempt") != req.preemptions:
            defects.append(f"rid {rid}: trace has {seq.count('preempt')} "
                           f"preempts, engine counted {req.preemptions}")
        if seq.count("resume") != seq.count("preempt"):
            defects.append(f"rid {rid}: {seq.count('preempt')} preempts vs "
                           f"{seq.count('resume')} resumes (all requests "
                           f"finished, so these must pair)")
        if "first_token" not in seq:
            defects.append(f"rid {rid}: no first_token event")
    return defects


def run() -> dict:
    import jax

    from repro import obs
    from repro.configs import get_config
    from repro.models import family_module, reduced
    from repro.obs.export import validate_telemetry_file

    cfg = reduced(get_config("qwen3-8b"))
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0), tp=1)

    _serve(cfg, params)                       # warm every jit shape

    # interleaved best-of-N: off, on, off, on ...
    t_off, t_on = [], []
    outs_off = outs_on = None
    preemptions = 0
    last_state = None
    tokens = 0
    for _ in range(ROUNDS):
        obs.disable()
        done, stats, dt = _serve(cfg, params)
        outs_off = [r.out for r in done]
        tokens = stats["generated"]
        t_off.append(dt)

        last_state = obs.enable()
        done, stats, dt = _serve(cfg, params)
        outs_on = [r.out for r in done]
        done_on = done
        preemptions = stats["preemptions"]
        t_on.append(dt)
    obs.disable()

    identical = outs_off == outs_on
    defects = _lifecycle_defects(last_state.tracer, done_on)

    # export + validate through the real artifact path
    art = Path(__file__).resolve().parents[1] / "artifacts"
    from repro.obs.export import export_chrome_trace, export_telemetry
    tpath = export_telemetry(last_state.tracer, last_state.metrics,
                             art / "telemetry.json")
    export_chrome_trace(last_state.tracer, art / "trace.json")
    schema_errs = validate_telemetry_file(tpath)

    tok_off = tokens / min(t_off)
    tok_on = tokens / min(t_on)
    return {
        "requests": N_REQUESTS, "max_new": MAX_NEW, "rounds": ROUNDS,
        "preemptions": preemptions,
        "trace_events": len(last_state.tracer),
        "trace_dropped": last_state.tracer.dropped,
        "metrics_instruments": len(last_state.metrics),
        "tok_s_untraced": round(tok_off, 1),
        "tok_s_traced": round(tok_on, 1),
        "overhead_ratio": round(tok_on / tok_off, 4),
        "outputs_identical": identical,
        "lifecycle_defects": defects,
        "schema_errors": schema_errs,
    }


def main() -> None:
    global LAST_METRICS
    from benchmarks._results import publish

    m = run()
    m["pass"] = bool(m["outputs_identical"]
                     and m["overhead_ratio"] >= MIN_RATIO
                     and not m["lifecycle_defects"]
                     and not m["schema_errors"])
    LAST_METRICS = m
    print("bench,case,tok_s_untraced,tok_s_traced,ratio,detail")
    print(f"bench_obs,mixed_10to1_paged_{SLOTS}slots,"
          f"{m['tok_s_untraced']},{m['tok_s_traced']},"
          f"{m['overhead_ratio']},"
          f"identical={m['outputs_identical']}_events={m['trace_events']}"
          f"_preemptions={m['preemptions']}")
    publish("bench_obs", m, failed=not m["pass"])
    if not m["pass"]:
        raise SystemExit(
            f"bench_obs gate FAILED: ratio {m['overhead_ratio']} "
            f"(need >= {MIN_RATIO}), identical={m['outputs_identical']}, "
            f"lifecycle_defects={m['lifecycle_defects']}, "
            f"schema_errors={m['schema_errors']}")


if __name__ == "__main__":
    main()
