"""Ablation: the Q-learning revision policy vs random revisions in the
software DSE (paper §VI-B motivates DQN over 'exhaustively trying out all
the possible revision choices'; this quantifies the component's value under
equal evaluation budgets, 3-seed means)."""
from __future__ import annotations

import numpy as np

from repro.core import workloads as W
from repro.core.hw_primitives import HWBuilder
from repro.core.intrinsics import GEMM
from repro.core.matching import match
from repro.core.sw_dse import optimize

HW = (HWBuilder("GEMM").reshapeArray([16, 16], depth=16)
      .addCache(256).partitionBanks(2).build())


def run(seeds=(0, 1, 2)):
    wls = [W.gemm(512, 512, 512), W.conv2d(128, 64, 28, 28),
           W.ttm(128, 64, 64, 64)]
    rows = []
    for w in wls:
        choices = match(GEMM, w)
        for use_q in (True, False):
            lats = []
            for seed in seeds:
                res = optimize(w, choices, HW, pool_size=16, rounds=8, k=4,
                               seed=seed, use_qlearning=use_q)
                lats.append(res.latency_s)
            rows.append((w.name, "dqn" if use_q else "random-revision",
                         float(np.mean(lats)), float(np.std(lats))))
    return rows


def main() -> None:
    rows = run()
    print("benchmark,workload,revision_policy,mean_latency_us,std_us")
    for name, pol, mean, std in rows:
        print(f"ablation_ql,{name},{pol},{mean*1e6:.2f},{std*1e6:.2f}")
    by = {}
    for name, pol, mean, _ in rows:
        by.setdefault(name, {})[pol] = mean
    for name, d in by.items():
        print(f"ablation_ql_summary,{name},dqn_speedup,"
              f"{d['random-revision'] / d['dqn']:.3f},")


if __name__ == "__main__":
    main()
