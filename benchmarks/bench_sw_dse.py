"""Microbenchmark: the lock-step batched software-DSE engine (DESIGN.md §10)
against the sequential per-search reference.

Two measurements, both gated:

  round_loop — 16 concurrent searches (4 GEMM/conv workloads × 4 accelerator
               candidates, the shape of one ``mobo(q=4)`` trial) × 12-pool ×
               16-round × k=6 heuristic+Q-learning DSE: ``engine="batched"``
               vs ``engine="reference"``.  96 transitions per search, so the
               per-search DQNs genuinely train.  Gate: >= 5x speedup AND
               bit-exact best-schedule/latency parity per search (best-of-2
               timings).
  codesign_q4 — a full same-seed ``codesign(q=4)`` run (2 workloads, GEMM
               intrinsic) with both engines, jit-warm.  Gate: batched is
               strictly faster AND commits the identical solution.

Prints CSV; exit code 1 if a gate is missed.  Also merges its metrics into
``artifacts/bench_results.json`` so CI can upload the perf snapshot without
running the whole ``benchmarks.run`` suite.

    PYTHONPATH=src python -m benchmarks.bench_sw_dse
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

N_SEARCHES = 16
POOL = 12
ROUNDS = 16
K = 6
TARGET_SPEEDUP = 5.0

LAST_METRICS: dict = {}


def _specs():
    from repro.core import workloads as W
    from repro.core.hw_primitives import HWBuilder
    from repro.core.intrinsics import ALL_INTRINSICS
    from repro.core.matching import match
    from repro.core.sw_dse import SearchSpec

    gemm = ALL_INTRINSICS["GEMM"]
    wls = [W.gemm(256, 256, 128, name="g0"), W.gemm(512, 128, 256, name="g1"),
           W.gemm(128, 512, 512, name="g2"),
           W.conv2d(32, 16, 14, 14, name="c0")]
    hws = [(HWBuilder("GEMM").reshapeArray([r, c], depth=16)
            .addCache(kib).partitionBanks(2).build())
           for r, c, kib in [(16, 16, 256), (8, 32, 128), (32, 8, 512),
                             (16, 8, 256)]]
    out, n = [], 0
    for hw in hws:
        for w in wls:
            out.append(SearchSpec(w, match(gemm, w), hw, 17 * n))
            n += 1
    assert len(out) == N_SEARCHES
    return out


def run_round_loop():
    from repro.core.sw_dse import run_searches

    cfg = dict(pool_size=POOL, rounds=ROUNDS, k=K)
    specs = _specs()
    bat = run_searches(specs, engine="batched", **cfg)    # jit warmup
    ref = run_searches(specs, engine="reference", **cfg)
    parity = all(r.schedule == b.schedule and r.latency_s == b.latency_s
                 and r.history == b.history for r, b in zip(ref, bat))

    t_bat = t_ref = float("inf")                      # best-of-2: de-noise
    for _ in range(2):                                # shared-runner jitter
        t0 = time.perf_counter()
        run_searches(specs, engine="batched", **cfg)
        t_bat = min(t_bat, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_searches(specs, engine="reference", **cfg)
        t_ref = min(t_ref, time.perf_counter() - t0)
    return t_ref, t_bat, parity


def run_codesign_q4():
    from repro.core import workloads as W
    from repro.core.codesign import codesign

    wl = [W.gemm(256, 256, 128, name="g0"),
          W.conv2d(32, 16, 14, 14, name="c0")]
    kw = dict(intrinsics=["GEMM"], n_trials=10, n_init=4, seed=0, q=4)
    rb = codesign(wl, **kw)                           # jit warmup
    rr = codesign(wl, engine="reference", **kw)

    def _best_of(fn, repeats: int = 2) -> float:      # de-noise: these are
        best = float("inf")                           # single-second runs on
        for _ in range(repeats):                      # shared CI runners
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_bat = _best_of(lambda: codesign(wl, **kw))
    t_ref = _best_of(lambda: codesign(wl, engine="reference", **kw))
    same = (rb.solution is not None and rr.solution is not None
            and rb.solution.latency_s == rr.solution.latency_s
            and rb.solution.hw.encode() == rr.solution.hw.encode()
            and rb.solution.schedules == rr.solution.schedules)
    return t_ref, t_bat, same


def main() -> None:
    print("bench,case,metric,reference_s,batched_s,speedup,detail")
    t_ref, t_bat, parity = run_round_loop()
    sp = t_ref / t_bat
    print(f"bench_sw_dse,round_loop,{N_SEARCHES}x{POOL}x{ROUNDS},"
          f"{t_ref:.3f},{t_bat:.3f},{sp:.1f},parity={parity}")

    t_cref, t_cbat, same = run_codesign_q4()
    sp_c = t_cref / t_cbat
    print(f"bench_sw_dse,codesign_q4,10_trials,{t_cref:.3f},{t_cbat:.3f},"
          f"{sp_c:.1f},identical_solution={same}")

    ok = (sp >= TARGET_SPEEDUP) and parity and (t_cbat < t_cref) and same
    verdict = "PASS" if ok else "FAIL"
    print(f"bench_sw_dse,summary,round_loop_speedup,{sp:.1f},target,"
          f"{TARGET_SPEEDUP:.0f},{verdict}")

    global LAST_METRICS
    LAST_METRICS = {
        "round_loop_speedup": round(sp, 1),
        "round_loop_reference_s": round(t_ref, 3),
        "round_loop_batched_s": round(t_bat, 3),
        "round_loop_parity": parity,
        "codesign_q4_speedup": round(sp_c, 2),
        "codesign_q4_reference_s": round(t_cref, 3),
        "codesign_q4_batched_s": round(t_cbat, 3),
        "codesign_q4_identical": same,
        "target_speedup": TARGET_SPEEDUP,
        "pass": ok,
    }
    from benchmarks._results import publish
    publish("bench_sw_dse", LAST_METRICS, failed=not ok)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
