"""Calibration benchmark (DESIGN.md §8.2): does fitting the per-op
correction on measured latencies improve how well the cost model *ranks*
candidates?

Builds a 64-candidate GEMM population (random hardware knobs × random
schedules), measures every candidate through the interpret-mode Pallas
backend (deduplicated lowerings), fits the log-linear correction on a train
split, and reports the Spearman rank correlation between predicted and
measured latency on the held-out split — before vs. after calibration.

  PYTHONPATH=src python -m benchmarks.bench_calibration
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

POPULATION = 64
TRAIN = 44

LAST_METRICS: dict = {}   # filled by main(); consumed by benchmarks/run.py


def build_population(wl, choice, n, seed=7):
    from repro.core.hw_primitives import HWConfig
    from repro.core.sw_primitives import Schedule

    rng = np.random.default_rng(seed)
    loops = list(choice.mapped_compute_indices)
    hws, scheds = [], []
    for _ in range(n):
        hws.append(HWConfig(
            intrinsic="GEMM", pe_rows=int(rng.choice([8, 16, 32])),
            pe_cols=int(rng.choice([8, 16, 32])),
            pe_depth=int(rng.choice([8, 16, 32])),
            vmem_kib=int(rng.choice([256, 1024, 4096])),
            banks=int(rng.choice([1, 2])),
            burst_bytes=int(rng.choice([256, 1024, 4096])),
            dataflow=str(rng.choice(["OS", "WS", "IS"]))))
        tiles = tuple(sorted((c, int(rng.choice([16, 32, 64])))
                             for c in loops))
        order = list(wl.all_indices())
        rng.shuffle(order)
        scheds.append(Schedule(choice, tiles, tuple(order), 0))
    return hws, scheds


def main() -> None:
    from repro.core import workloads as W
    from repro.core.cost_model import evaluate_batch_reports
    from repro.core.intrinsics import GEMM
    from repro.core.matching import match
    from repro.tuner import calibrate as C
    from repro.tuner import measure as M

    wl = W.gemm(64, 64, 64, name="bench_cal")
    choice = match(GEMM, wl)[0]
    hws, scheds = build_population(wl, choice, POPULATION)

    reports = evaluate_batch_reports(wl, hws, scheds, "tpu")
    t0 = time.time()
    meas = M.measure_batch(wl, hws, scheds,
                           M.MeasureOptions(warmup=2, repeats=7))
    t_measure = time.time() - t0
    n_points = len({m.point for m in meas if m.ok})
    n_fail = sum(not m.ok for m in meas)

    pred = np.array([r.latency_s for r in reports])
    truth = np.array([m.latency_s for m in meas])

    cal = C.fit(C.collect_samples(wl, reports[:TRAIN], meas[:TRAIN]))
    corrected = C.CalibratedCostModel(cal).predict_latency(
        wl, reports[TRAIN:])
    before = C.spearman(pred[TRAIN:], truth[TRAIN:])
    after = C.spearman(corrected, truth[TRAIN:])
    before_all = C.spearman(pred, truth)

    print("population,train,heldout,distinct_kernels,failures,"
          "measure_s,spearman_before_all,spearman_before,spearman_after,"
          "correction")
    print(f"{POPULATION},{TRAIN},{POPULATION - TRAIN},{n_points},{n_fail},"
          f"{t_measure:.1f},{before_all:.3f},{before:.3f},{after:.3f},"
          f"{cal.for_op('gemm').kind}")
    print(f"# held-out Spearman(analytical, measured): {before:.3f} -> "
          f"{after:.3f} after calibration "
          f"({'improved' if after > before else 'NOT improved'})")
    global LAST_METRICS
    LAST_METRICS = {
        "population": POPULATION, "train": TRAIN,
        "spearman_before": round(float(before), 3),
        "spearman_after": round(float(after), 3),
        "measure_s": round(t_measure, 1), "failures": int(n_fail),
    }


if __name__ == "__main__":
    main()
