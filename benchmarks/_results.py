"""Shared writer for ``artifacts/bench_results.json``.

``benchmarks.run`` rewrites the whole document after a full suite;
individually-run gated benchmarks (bench_sw_dse, bench_serve) call
:func:`publish` to merge just their own entry so CI can upload a perf
snapshot without re-running everything — one schema, one merge routine.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

RESULTS_PATH = (Path(__file__).resolve().parents[1] / "artifacts"
                / "bench_results.json")


def publish(name: str, metrics: dict, *, failed: bool) -> None:
    """Merge one benchmark's entry into bench_results.json (same shape
    ``benchmarks.run`` writes) without clobbering other entries."""
    RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
    try:
        doc = json.loads(RESULTS_PATH.read_text())
        assert isinstance(doc.get("results"), list)
    except Exception:
        doc = {"results": []}
    doc["generated_unix"] = int(time.time())
    doc["results"] = [r for r in doc["results"] if r.get("name") != name]
    doc["results"].append({"name": name, "failed": failed,
                           "metrics": metrics})
    RESULTS_PATH.write_text(json.dumps(doc, indent=2) + "\n")
