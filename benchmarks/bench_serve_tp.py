"""Tensor-parallel serving gate (DESIGN.md §15).

Three scenarios, all gated (exit 1 on miss):

  * ``model=1``: the paged engine hosted on a (data=1, model=1) mesh must
    produce BIT-IDENTICAL per-request outputs to the plain
    ``PagedServeEngine`` — a trivial mesh adds sharding machinery but no
    collectives, so any drift is a bug in the mesh plumbing, not numerics.
  * ``8-way``: a subprocess widened to 8 host devices
    (``--xla_force_host_platform_device_count``) decodes the same traffic
    on a (data=1, model=8) mesh and on a single device with the SAME tp=8
    padded params; greedy tokens must match token-for-token (sharded
    reductions may reassociate ulps; argmax token identity is the
    contract).
  * ``codesign``: with the interconnect term in the cost model, a seeded
    codesign run over (chip config × TP degree) must commit a *different*,
    TP-aware solution (hw.tp > 1, lower modeled latency) than the TP-blind
    run on the same workloads.

Tokens/s for every serving run is published into
``artifacts/bench_results.json``.

    PYTHONPATH=src python -m benchmarks.bench_serve_tp
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"
sys.path.insert(0, str(SRC))

SLOTS = 4
MAX_SEQ = 48
N_REQUESTS = 8
MAX_NEW = 8
PAGE_SIZE = 8
PREFILL_CHUNK = 16

LAST_METRICS: dict = {}


def _serve(cfg, params, *, tp=1, mesh=None):
    from repro.launch.serve import make_requests, serve_requests

    reqs = make_requests(cfg, N_REQUESTS, MAX_NEW, seed=0)
    t0 = time.perf_counter()
    done, stats = serve_requests(
        cfg, params, reqs, slots=SLOTS, max_seq=MAX_SEQ, tp=tp, mesh=mesh,
        paged=True, page_size=PAGE_SIZE, prefill_chunk=PREFILL_CHUNK)
    dt = time.perf_counter() - t0
    done = sorted(done, key=lambda r: r.rid)
    return [r.out for r in done], stats["generated"] / dt


def run_model1() -> dict:
    """Trivial mesh vs no mesh: bit-identical outputs on one device."""
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import family_module, reduced

    cfg = reduced(get_config("qwen3-8b"))
    params = family_module(cfg).init(cfg, jax.random.PRNGKey(0), tp=1)
    mesh = make_host_mesh(tp=1)
    for _ in range(2):                       # second run is the warm timing
        outs_plain, tok_s_plain = _serve(cfg, params)
        outs_mesh, tok_s_mesh = _serve(cfg, params, mesh=mesh)
    return {
        "tok_s_plain": round(tok_s_plain, 1),
        "tok_s_mesh": round(tok_s_mesh, 1),
        "outputs_identical": outs_plain == outs_mesh,
    }


_TP8 = textwrap.dedent("""
    import dataclasses, json, os, sys, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, "__SRC__")
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import make_requests, serve_requests
    from repro.models import family_module, reduced

    # f32 so token identity is a meaningful gate: sharded partial sums
    # round at shard boundaries, and bf16's 2^-8 steps are the same order
    # as this random-init model's top-2 logit gaps — f32 leaves ~60x
    # margin between reassociation drift and the closest gap
    cfg = dataclasses.replace(reduced(get_config("qwen3-8b")),
                              dtype="float32")
    params = family_module(cfg).init(cfg, jax.random.PRNGKey(0), tp=8)
    mesh = make_host_mesh(tp=8)
    assert dict(mesh.shape) == {"data": 1, "model": 8}

    def serve(mesh_arg):
        reqs = make_requests(cfg, __N__, __MAX_NEW__, seed=0)
        t0 = time.perf_counter()
        done, stats = serve_requests(
            cfg, params, reqs, slots=__SLOTS__, max_seq=__MAX_SEQ__,
            tp=8, mesh=mesh_arg, paged=True, page_size=__PAGE_SIZE__,
            prefill_chunk=__CHUNK__)
        dt = time.perf_counter() - t0
        done = sorted(done, key=lambda r: r.rid)
        return [r.out for r in done], stats["generated"] / dt

    for _ in range(2):                      # second run is the warm timing
        outs_ref, tok_s_ref = serve(None)   # single device, same tp=8 params
        outs_tp, tok_s_tp = serve(mesh)
    print(json.dumps({"outputs_identical": outs_ref == outs_tp,
                      "tok_s_single": round(tok_s_ref, 1),
                      "tok_s_tp8": round(tok_s_tp, 1)}))
""")


def run_tp8() -> dict:
    """8-way mesh decode vs single-device, same tp=8 params, in a widened
    subprocess (the host device count is fixed at jax import)."""
    script = (_TP8.replace("__SRC__", str(SRC))
              .replace("__N__", str(N_REQUESTS))
              .replace("__MAX_NEW__", str(MAX_NEW))
              .replace("__SLOTS__", str(SLOTS))
              .replace("__MAX_SEQ__", str(MAX_SEQ))
              .replace("__PAGE_SIZE__", str(PAGE_SIZE))
              .replace("__CHUNK__", str(PREFILL_CHUNK)))
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise SystemExit(f"tp8 subprocess failed:\n{out.stdout}\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_codesign() -> dict:
    """Seeded (chip × TP) search vs the TP-blind search: the interconnect
    term must change the committed solution."""
    from repro.core import workloads as W
    from repro.core.codesign import codesign
    from repro.core.hw_space import PARALLELISM_AXES

    wl = W.table1_gemm()[:2]
    kw = dict(intrinsics=["GEMM"], n_trials=8, n_init=4, seed=0, q=2)
    blind = codesign(wl, **kw).solution
    aware = codesign(wl, space_axes=PARALLELISM_AXES, **kw).solution
    return {
        "hw_blind": list(blind.hw.encode()),
        "hw_aware": list(aware.hw.encode()),
        "tp_blind": blind.hw.tp,
        "tp_aware": aware.hw.tp,
        "latency_blind_s": blind.latency_s,
        "latency_aware_s": aware.latency_s,
        "solutions_differ": blind.hw.encode() != aware.hw.encode(),
    }


def main() -> None:
    global LAST_METRICS
    from benchmarks._results import publish

    m1 = run_model1()
    m8 = run_tp8()
    mc = run_codesign()
    ok = bool(m1["outputs_identical"] and m8["outputs_identical"]
              and mc["solutions_differ"] and mc["tp_aware"] > 1
              and mc["latency_aware_s"] < mc["latency_blind_s"])
    m = {"model1": m1, "tp8": m8, "codesign": mc, "pass": ok}
    LAST_METRICS = m

    print("bench,case,detail")
    print(f"bench_serve_tp,model1_bit_exact,"
          f"identical={m1['outputs_identical']},"
          f"tok_s={m1['tok_s_mesh']}_vs_{m1['tok_s_plain']}")
    print(f"bench_serve_tp,tp8_token_exact,"
          f"identical={m8['outputs_identical']},"
          f"tok_s={m8['tok_s_tp8']}_vs_{m8['tok_s_single']}")
    print(f"bench_serve_tp,codesign_tp_aware,"
          f"tp={mc['tp_aware']}_vs_{mc['tp_blind']},"
          f"latency={mc['latency_aware_s']:.3g}_vs_"
          f"{mc['latency_blind_s']:.3g}")
    publish("bench_serve_tp", m, failed=not ok)
    if not ok:
        raise SystemExit(
            f"bench_serve_tp gate missed: model1_identical="
            f"{m1['outputs_identical']} tp8_identical="
            f"{m8['outputs_identical']} codesign_differ="
            f"{mc['solutions_differ']} tp_aware={mc['tp_aware']}")


if __name__ == "__main__":
    main()
