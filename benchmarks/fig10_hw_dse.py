"""Paper Fig. 10 + Table II: hardware DSE — MOBO vs NSGA-II vs random.

All methods get the same trial budget (evaluations are the expensive
resource); hypervolume curves are rescored against a shared reference so the
runs are comparable (paper plots all methods on one axis).  Reports the
paper's two headline metrics: final-hypervolume ratio MOBO/NSGA-II and the
trial count at which MOBO first exceeds NSGA-II's final hypervolume.
"""
from __future__ import annotations

import numpy as np

from repro.core import workloads as W
from repro.core.codesign import hw_objectives
from repro.core.hw_space import HWSpace
from repro.core.intrinsics import ALL_INTRINSICS
from repro.core.matching import partition_space
from repro.core.mobo import mobo, rescore_hv_history, shared_reference
from repro.core.nsga2 import nsga2
from repro.core.random_search import random_search


PAPER_AXES = {
    # the paper's FPGA regime: 4x4..64x64 PE arrays, <=1 MiB scratchpads
    "pe_rows": (4, 8, 16, 32, 64),
    "pe_cols": (4, 8, 16, 32, 64),
    "pe_depth": (4, 8, 16, 32, 64),
    "vmem_kib": (128, 256, 512, 1024),
}


def run(n_trials: int = 20, seed: int = 0):
    wl = W.xception_ground_truth()[:4]
    part = partition_space([ALL_INTRINSICS["GEMM"]], wl)
    # one shared evaluation cache: hardware points probed by several methods
    # (same seed -> overlapping initial designs) are scored once
    from repro.core.cost_model import EvalCache
    f = hw_objectives(wl, part, "GEMM", sw_budget="small", seed=seed,
                      cache=EvalCache())
    base = HWSpace("GEMM")
    space = HWSpace("GEMM", axes={**base.axes, **PAPER_AXES})
    res_m = mobo(space, f, n_init=5, n_trials=n_trials, seed=seed)
    res_n = nsga2(space, f, pop_size=5, n_trials=n_trials, seed=seed)
    res_r = random_search(space, f, n_trials=n_trials, seed=seed)
    ref = shared_reference([res_m, res_n, res_r])
    curves = {
        "MOBO": rescore_hv_history(res_m, ref),
        "NSGAII": rescore_hv_history(res_n, ref),
        "random": rescore_hv_history(res_r, ref),
    }
    return curves, (res_m, res_n, res_r)


def main(seeds=(0, 1, 2)) -> None:
    """Multi-seed means: 20-trial DSE runs are noisy; the paper's comparison
    is about the expected behaviour of the methods."""
    finals = {"MOBO": [], "NSGAII": [], "random": []}
    reach_speedups = []
    lat_under = {"MOBO": [], "NSGAII": [], "random": []}
    print("benchmark,method,trial,hypervolume,seed")
    for seed in seeds:
        curves, (res_m, res_n, res_r) = run(seed=seed)
        for method, hv in curves.items():
            finals[method].append(hv[-1])
            for t, v in enumerate(hv):
                print(f"fig10,{method},{t + 1},{v:.4f},{seed}")
        hv_n = curves["NSGAII"][-1]
        reach = next((t + 1 for t, v in enumerate(curves["MOBO"])
                      if v >= hv_n), None)
        if reach:
            reach_speedups.append(len(curves["NSGAII"]) / reach)
        bound = float(np.nanmedian(np.concatenate(
            [res_m.ys[:, 1], res_n.ys[:, 1], res_r.ys[:, 1]])))
        for name, res in (("MOBO", res_m), ("NSGAII", res_n),
                          ("random", res_r)):
            pick = res.best_under({1: bound})
            lat_under[name].append(pick[1][0] if pick else float("inf"))
    m, n, r = (float(np.mean(finals[k]))
               for k in ("MOBO", "NSGAII", "random"))
    print(f"fig10_summary,hv_ratio_mobo_vs_nsga2,,{m / n:.3f}")
    print(f"fig10_summary,hv_ratio_mobo_vs_random,,{m / r:.3f}")
    print(f"fig10_summary,trials_speedup_vs_nsga2,,"
          f"{float(np.mean(reach_speedups)) if reach_speedups else float('nan'):.2f}")
    print("table2,method,mean_best_latency_s_under_power_bound")
    for name, lats in lat_under.items():
        print(f"table2,{name},{float(np.mean(lats)):.4e}")


if __name__ == "__main__":
    main()
