"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).

  fig7_intrinsics    Fig. 7   tensor computations × hardware intrinsics
  fig10_hw_dse       Fig. 10 + Table II  MOBO vs NSGA-II vs random
  fig11_sw_dse       Fig. 11  HASCO software vs im2col library vs template
  table3_codesign    Table III  co-design vs decoupled, edge/cloud power
  kernel_micro       host-side kernel microbenchmarks
  bench_batched_eval batched vs scalar cost-model evaluation throughput
  bench_acquisition  vectorized Pareto/HVI engine vs per-candidate loops
                     (DESIGN.md §9)
  bench_sw_dse       lock-step batched software-DSE engine vs sequential
                     per-search reference (DESIGN.md §10)
  bench_calibration  analytical-vs-measured rank correlation, before/after
                     per-op calibration (DESIGN.md §8)
  bench_serve        continuous-batching serving engine vs sequential
                     one-request-at-a-time baseline (DESIGN.md §11)

Each prints CSV; ``python -m benchmarks.run`` runs them all and writes a
machine-readable summary — per-benchmark name, key metrics (a module's
``LAST_METRICS`` dict, when it publishes one), wall-clock, gate outcome — to
``artifacts/bench_results.json`` so the perf trajectory is trackable across
PRs.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

RESULTS_PATH = Path(__file__).resolve().parents[1] / "artifacts" / "bench_results.json"


def main() -> None:
    from benchmarks import (ablation_qlearning, bench_acquisition,
                            bench_batched_eval, bench_calibration,
                            bench_serve, bench_sw_dse, fig7_intrinsics,
                            fig10_hw_dse, fig11_sw_dse, kernel_micro,
                            table3_codesign)

    failures = []
    results = []
    try:
        for mod in (kernel_micro, bench_batched_eval, bench_acquisition,
                    bench_sw_dse, bench_serve, bench_calibration,
                    fig7_intrinsics,
                    fig11_sw_dse, fig10_hw_dse, table3_codesign,
                    ablation_qlearning):
            name = mod.__name__.split(".")[-1]
            print(f"# === {name} ===", flush=True)
            t0 = time.time()
            failed = False
            try:
                mod.main()
            except SystemExit as e:  # a gated benchmark (e.g. the 10x
                # batched-eval target) must not abort the rest of the suite
                if e.code:
                    failed = True
                    failures.append(name)
                    print(f"# {name} FAILED its gate (exit {e.code})",
                          flush=True)
            wall = time.time() - t0
            print(f"# {name} done in {wall:.1f}s", flush=True)
            results.append({"name": name, "wall_clock_s": round(wall, 3),
                            "failed": failed,
                            "metrics": getattr(mod, "LAST_METRICS", None)
                            or {}})
    finally:
        # persist whatever completed even if a benchmark crashes outright
        RESULTS_PATH.parent.mkdir(parents=True, exist_ok=True)
        RESULTS_PATH.write_text(json.dumps(
            {"generated_unix": int(time.time()), "results": results,
             "quarantined_candidates": _quarantined_count()},
            indent=2) + "\n")
        print(f"# wrote {RESULTS_PATH}", flush=True)
    if failures:
        raise SystemExit(f"gated benchmarks failed: {', '.join(failures)}")


def _quarantined_count() -> int:
    """Persistently-failing kernel candidates in the default tuning DB
    (DESIGN.md §14) — surfaced so a growing quarantine is visible in the
    tracked benchmark artifact, not buried in the DB."""
    try:
        from repro.tuner.db import DEFAULT_DB_PATH, TuningDB

        n = len(TuningDB.load(DEFAULT_DB_PATH).quarantine)
    except Exception:
        return 0
    if n:
        print(f"# WARNING: {n} kernel candidate(s) quarantined in "
              f"{DEFAULT_DB_PATH} — these are skipped by measurement runs",
              flush=True)
    return n


if __name__ == "__main__":
    main()
