"""Benchmark harness: one module per paper table/figure (DESIGN.md §7).

  fig7_intrinsics   Fig. 7   tensor computations × hardware intrinsics
  fig10_hw_dse      Fig. 10 + Table II  MOBO vs NSGA-II vs random
  fig11_sw_dse      Fig. 11  HASCO software vs im2col library vs template
  table3_codesign   Table III  co-design vs decoupled, edge/cloud power
  kernel_micro      host-side kernel microbenchmarks

Each prints CSV; ``python -m benchmarks.run`` runs them all.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main() -> None:
    from benchmarks import (ablation_qlearning, fig7_intrinsics,
                            fig10_hw_dse, fig11_sw_dse, kernel_micro,
                            table3_codesign)

    for mod in (kernel_micro, fig7_intrinsics, fig11_sw_dse, fig10_hw_dse,
                table3_codesign, ablation_qlearning):
        name = mod.__name__.split(".")[-1]
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        mod.main()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
