"""Microbenchmark: the vectorized Pareto/hypervolume acquisition engine
(DESIGN.md §9) against the pre-engine per-candidate scoring loops.

Two measurements, both gated:

  hvi        — exclusive-hypervolume scoring of a 256-candidate × 24-draw
               acquisition workload (the per-trial cost of MOBO stage 2)
               via one ``BoxDecomposition`` + ``hvi`` pass, vs the
               per-candidate ``_reference_hypervolume`` recompute loop.
               Gate: >= 10x speedup.
  mobo_e2e   — a full same-seed ``mobo()`` run (synthetic objectives, so
               acquisition dominates the wall-clock) with
               ``acquisition="vectorized"`` vs ``acquisition="reference"``.
               Gate: vectorized is strictly faster at equal trial budget
               AND reaches the same final hypervolume within 1e-6 relative
               (with these seeds the pick sequences are identical, so the
               histories agree to float precision).

A third, ungated row reports the q-batch mode (``q=4``) at the same trial
budget for context.  Prints CSV; exit code 1 if a gate is missed.

    PYTHONPATH=src python -m benchmarks.bench_acquisition
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.hw_space import HWSpace
from repro.core.mobo import mobo
from repro.core.pareto import (BoxDecomposition, _reference_hypervolume,
                               default_reference, pareto_front)

N_CANDIDATES = 256
N_DRAWS = 24
N_TRIALS = 18
TARGET_SPEEDUP = 10.0
HV_PARITY_RTOL = 1e-6

LAST_METRICS: dict = {}


def _objectives(hw):
    """Synthetic 3-objective surface over the hardware space (cheap on
    purpose: the benchmark times the *acquisition* machinery)."""
    n = hw.pe_rows * hw.pe_cols
    lat = 1.0 / n + hw.burst_bytes * 1e-9
    pow_ = n * 1e-3 + hw.vmem_kib * 1e-4
    area = n * 10.0 + hw.vmem_kib * 5.0
    return (lat, pow_, area)


def _best_of(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_hvi(n_cands: int = N_CANDIDATES, n_draws: int = N_DRAWS,
            seed: int = 0):
    """One acquisition round's worth of HVI scoring, both engines."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (48, 3))         # log-space objective cloud
    ref = default_reference(pts, margin=1.3)
    front = pareto_front(pts)
    cands = rng.uniform(0, 1.1, (n_cands * n_draws, 3))

    def scalar():
        hv0 = _reference_hypervolume(front, ref)
        return np.array([_reference_hypervolume(np.vstack([front, c[None]]),
                                                ref) - hv0 for c in cands])

    def vectorized():
        return BoxDecomposition(front, ref).hvi(cands)

    ref_vals = scalar()
    vec_vals = vectorized()
    err = float(np.abs(ref_vals - vec_vals).max())
    t_scalar = _best_of(scalar, repeats=1)   # ~10 s per rep; once is plenty
    t_vec = _best_of(vectorized)
    return t_scalar, t_vec, err, len(front)


def run_mobo(seed: int = 0, n_trials: int = N_TRIALS):
    """End-to-end same-seed MOBO, reference vs vectorized vs q-batch."""
    space = HWSpace("GEMM")
    t0 = time.perf_counter()
    res_v = mobo(space, _objectives, n_init=5, n_trials=n_trials, seed=seed)
    t_vec = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_r = mobo(space, _objectives, n_init=5, n_trials=n_trials, seed=seed,
                 acquisition="reference")
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_q = mobo(space, _objectives, n_init=5, n_trials=n_trials, seed=seed,
                 q=4)
    t_q = time.perf_counter() - t0
    return (t_ref, t_vec, t_q, res_r.hv_history[-1], res_v.hv_history[-1],
            res_q.hv_history[-1])


def main() -> None:
    print("bench,case,metric,scalar_s,vectorized_s,speedup,detail")
    t_s, t_v, err, front_n = run_hvi()
    sp_hvi = t_s / t_v
    print(f"bench_acquisition,hvi,{N_CANDIDATES}x{N_DRAWS},{t_s:.4f},"
          f"{t_v:.4f},{sp_hvi:.1f},front={front_n} maxerr={err:.2e}")

    t_ref, t_vec, t_q, hv_r, hv_v, hv_q = run_mobo()
    sp_e2e = t_ref / t_vec
    hv_err = abs(hv_v - hv_r) / max(abs(hv_r), 1e-9)
    print(f"bench_acquisition,mobo_e2e,{N_TRIALS}_trials,{t_ref:.3f},"
          f"{t_vec:.3f},{sp_e2e:.1f},hv_ref={hv_r:.6f} hv_vec={hv_v:.6f} "
          f"rel_err={hv_err:.2e}")
    print(f"bench_acquisition,mobo_q4,{N_TRIALS}_trials,,{t_q:.3f},,"
          f"hv_q4={hv_q:.6f}")

    ok_hvi = sp_hvi >= TARGET_SPEEDUP
    ok_e2e = t_vec < t_ref
    ok_parity = hv_err <= HV_PARITY_RTOL and err <= 1e-9
    verdict = "PASS" if (ok_hvi and ok_e2e and ok_parity) else "FAIL"
    print(f"bench_acquisition,summary,hvi_speedup,{sp_hvi:.1f},target,"
          f"{TARGET_SPEEDUP:.0f},{verdict}")

    global LAST_METRICS
    LAST_METRICS = {
        "hvi_speedup": round(sp_hvi, 1),
        "hvi_scalar_s": round(t_s, 4), "hvi_vectorized_s": round(t_v, 4),
        "mobo_e2e_speedup": round(sp_e2e, 1),
        "mobo_reference_s": round(t_ref, 3),
        "mobo_vectorized_s": round(t_vec, 3), "mobo_q4_s": round(t_q, 3),
        "hv_parity_rel_err": hv_err, "target_speedup": TARGET_SPEEDUP,
        "pass": ok_hvi and ok_e2e and ok_parity,
    }
    if verdict == "FAIL":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
