"""Microbenchmark: batched vs scalar cost-model evaluation (DESIGN.md §4.3).

The DSE hot path scores thousands of (hw config, schedule) candidates per
run.  This benchmark times a 1024-candidate population three ways:

  scalar   — the original per-candidate Python loop (``_evaluate_reference``)
  batched  — one ``evaluate_batch`` call (vectorized structure-of-arrays)
  cached   — ``evaluate_batch`` re-scoring an already-seen population
             through an :class:`EvalCache` (the repeated-probe case MOBO
             iterations hit constantly)

Acceptance target: batched >= 10x scalar throughput on 1024 candidates.
Prints CSV like the other benchmarks; exit code 1 if the target is missed.

    PYTHONPATH=src python -m benchmarks.bench_batched_eval
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import workloads as W
from repro.core.cost_model import EvalCache, _evaluate_reference, evaluate_batch
from repro.core.hw_space import HWSpace
from repro.core.intrinsics import ALL_INTRINSICS
from repro.core.matching import match
from repro.core.sw_space import SoftwareSpace

N_CANDIDATES = 1024
TARGET_SPEEDUP = 10.0

LAST_METRICS: dict = {}   # filled by main(); consumed by benchmarks/run.py


def _population(wl, intrinsic: str, n: int, seed: int):
    """n random (hw, schedule) candidates for one workload × intrinsic."""
    rng = np.random.default_rng(seed)
    choices = match(ALL_INTRINSICS[intrinsic], wl)
    hws = HWSpace(intrinsic).sample(rng, 8)
    space = SoftwareSpace(wl, choices, hws[0], "spatial")
    schedules = [space.random_schedule(rng) for _ in range(n)]
    hw_list = [hws[int(rng.integers(len(hws)))] for _ in range(n)]
    return hw_list, schedules


def _best_of(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n: int = N_CANDIDATES, seed: int = 0):
    rows = []
    cases = [
        ("gemm512", W.gemm(512, 512, 512), "GEMM"),
        ("conv2d_resnet", W.conv2d(128, 64, 28, 28), "GEMM"),
    ]
    for name, wl, intrinsic in cases:
        hw_list, schedules = _population(wl, intrinsic, n, seed)
        evaluate_batch(wl, hw_list, schedules)   # warm prep caches

        t_scalar = _best_of(lambda: [
            _evaluate_reference(wl, s, h, "spatial")
            for s, h in zip(schedules, hw_list)])
        t_batch = _best_of(lambda: evaluate_batch(wl, hw_list, schedules))
        cache = EvalCache()
        evaluate_batch(wl, hw_list, schedules, cache=cache)  # populate
        t_cached = _best_of(lambda: evaluate_batch(wl, hw_list, schedules,
                                                   cache=cache))
        rows.append((name, n, t_scalar, t_batch, t_cached,
                     t_scalar / t_batch, t_scalar / t_cached))
    return rows


def main() -> None:
    rows = run()
    print("bench,case,candidates,scalar_s,batched_s,cached_s,"
          "speedup_batched,speedup_cached")
    worst = float("inf")
    for name, n, ts, tb, tc, sp_b, sp_c in rows:
        print(f"bench_batched_eval,{name},{n},{ts:.4f},{tb:.4f},{tc:.4f},"
              f"{sp_b:.1f},{sp_c:.1f}")
        worst = min(worst, sp_b)
    ok = worst >= TARGET_SPEEDUP
    print(f"bench_batched_eval,summary,worst_speedup,{worst:.1f},"
          f"target,{TARGET_SPEEDUP:.0f},{'PASS' if ok else 'FAIL'}")
    global LAST_METRICS
    LAST_METRICS = {
        "worst_speedup_batched": round(worst, 1),
        "target_speedup": TARGET_SPEEDUP, "pass": ok,
        "cases": {name: {"scalar_s": round(ts, 4), "batched_s": round(tb, 4),
                         "cached_s": round(tc, 4)}
                  for name, _, ts, tb, tc, _, _ in rows},
    }
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
