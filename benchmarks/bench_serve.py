"""Serving-throughput benchmark: continuous batching and paged KV against
the sequential one-request-at-a-time lower bound (DESIGN.md §11-12).

Two scenarios:

  * ``uniform``: 12 mixed-short requests, the slot-pinned engine at 4
    slots vs itself at ``max_concurrency=1`` — same compiled step
    functions both sides, so the speedup is pure slot occupancy.
  * ``mixed``: the ROADMAP 10:1 short/long traffic mix.  The paged engine
    gets the SAME physical KV budget as the slot-pinned engine but spends
    it on twice the slots (short requests only hold the pages they use),
    so queue latency — not just throughput — is the headline metric.

Gates (exit 1 on miss):
  * uniform: >= 2x generated tokens/s at 4 slots over sequential AND
    identical per-request outputs (batching changes wall-clock, never
    content)
  * mixed: paged >= 2x tokens/s over sequential AND paged p95 queue
    latency strictly below the slot-pinned engine's, with outputs
    identical across all three engines

Prints CSV; merges metrics into ``artifacts/bench_results.json`` so CI can
upload the perf snapshot without running the whole ``benchmarks.run`` suite.

    PYTHONPATH=src python -m benchmarks.bench_serve
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

SLOTS = 4
MAX_SEQ = 48
N_REQUESTS = 12
MAX_NEW = 16
TARGET_SPEEDUP = 2.0

# mixed 10:1 short/long scenario (ROADMAP item 1): identical physical KV
# budget both ways — 4 slots x 48 rows pinned == 24 pages x 8 rows paged —
# but the paged engine spends it on 8 slots
MIX_N = 22
MIX_MAX_NEW = 8
MIX_SLOTS_PAGED = 8
MIX_PAGE_SIZE = 8
MIX_N_PAGES = SLOTS * MAX_SEQ // MIX_PAGE_SIZE
MIX_PREFILL_CHUNK = 16

LAST_METRICS: dict = {}


def _requests(cfg):
    import numpy as np

    from repro.launch.serve import Request

    rng = np.random.default_rng(0)
    # mixed prompt lengths over a small fixed set so both timed runs reuse
    # the same jitted prefill shapes
    lengths = [4, 7, 11, 5, 9, 6] * 3
    return [Request(i, rng.integers(0, cfg.vocab, size=lengths[i])
                    .astype(np.int32), MAX_NEW) for i in range(N_REQUESTS)]


def _serve(cfg, params, *, max_concurrency=None):
    from repro.launch.serve import serve_requests

    t0 = time.perf_counter()
    done, stats = serve_requests(cfg, params, _requests(cfg), slots=SLOTS,
                                 max_seq=MAX_SEQ,
                                 max_concurrency=max_concurrency)
    return done, stats, time.perf_counter() - t0


def _mixed_requests(cfg):
    from repro.launch.serve import make_requests

    return make_requests(cfg, MIX_N, MIX_MAX_NEW, seed=0, long_every=11)


def _serve_mixed(cfg, params, mode):
    from repro.launch.serve import serve_requests

    kw = dict(max_seq=MAX_SEQ)
    if mode == "sequential":
        kw.update(slots=SLOTS, max_concurrency=1)
    elif mode == "pinned":
        kw.update(slots=SLOTS)
    else:                                     # paged: same budget, 8 slots
        kw.update(slots=MIX_SLOTS_PAGED, paged=True,
                  page_size=MIX_PAGE_SIZE, n_pages=MIX_N_PAGES,
                  prefill_chunk=MIX_PREFILL_CHUNK)
    t0 = time.perf_counter()
    done, stats = serve_requests(cfg, params, _mixed_requests(cfg), **kw)
    return done, stats, time.perf_counter() - t0


def run_mixed(cfg, params) -> dict:
    for mode in ("sequential", "pinned", "paged"):  # warm every jit shape
        _serve_mixed(cfg, params, mode)

    out = {}
    for mode in ("sequential", "pinned", "paged"):
        done, stats, dt = _serve_mixed(cfg, params, mode)
        done = sorted(done, key=lambda r: r.rid)
        out[mode] = {
            "outs": [r.out for r in done],
            "tok_s": stats["generated"] / dt,
            # the engine's own latency summary (submit -> first token);
            # same quantity the old ad-hoc np.percentile scan computed
            "p95_queue_s": stats["ttft_s"]["p95"],
            "preemptions": stats.get("preemptions", 0),
        }
    same = (out["paged"]["outs"] == out["pinned"]["outs"]
            == out["sequential"]["outs"])
    return {
        "requests": MIX_N, "max_new": MIX_MAX_NEW,
        "long_every": 11, "page_size": MIX_PAGE_SIZE,
        "n_pages": MIX_N_PAGES, "slots_paged": MIX_SLOTS_PAGED,
        "slots_pinned": SLOTS,
        "tok_s_sequential": round(out["sequential"]["tok_s"], 1),
        "tok_s_pinned": round(out["pinned"]["tok_s"], 1),
        "tok_s_paged": round(out["paged"]["tok_s"], 1),
        "speedup_paged": round(out["paged"]["tok_s"]
                               / out["sequential"]["tok_s"], 2),
        "p95_queue_pinned_s": round(out["pinned"]["p95_queue_s"], 4),
        "p95_queue_paged_s": round(out["paged"]["p95_queue_s"], 4),
        "preemptions": out["paged"]["preemptions"],
        "outputs_identical": same,
    }


def run() -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import family_module, reduced

    cfg = reduced(get_config("qwen3-8b"))
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0), tp=1)

    _serve(cfg, params)                       # warm every jit shape
    _serve(cfg, params, max_concurrency=1)

    done_b, stats_b, t_b = _serve(cfg, params)
    done_s, stats_s, t_s = _serve(cfg, params, max_concurrency=1)

    tok_s_batched = stats_b["generated"] / t_b
    tok_s_seq = stats_s["generated"] / t_s
    same = [r.out for r in done_b] == [r.out for r in done_s]
    return {
        "slots": SLOTS, "requests": N_REQUESTS, "max_new": MAX_NEW,
        "tokens": stats_b["generated"],
        "decode_steps_batched": stats_b["decode_steps"],
        "decode_steps_sequential": stats_s["decode_steps"],
        "tok_s_batched": round(tok_s_batched, 1),
        "tok_s_sequential": round(tok_s_seq, 1),
        "speedup": round(tok_s_batched / tok_s_seq, 2),
        "outputs_identical": same,
    }


def main() -> None:
    global LAST_METRICS
    import jax

    from benchmarks._results import publish
    from repro.configs import get_config
    from repro.models import family_module, reduced

    m = run()
    m["pass"] = bool(m["outputs_identical"]
                     and m["speedup"] >= TARGET_SPEEDUP)

    cfg = reduced(get_config("qwen3-8b"))
    params = family_module(cfg).init(cfg, jax.random.PRNGKey(0), tp=1)
    mm = run_mixed(cfg, params)
    mm["pass"] = bool(mm["outputs_identical"]
                      and mm["speedup_paged"] >= TARGET_SPEEDUP
                      and mm["p95_queue_paged_s"]
                      < mm["p95_queue_pinned_s"])

    LAST_METRICS = {**m, "mixed": mm}
    print("bench,case,tok_s_sequential,tok_s_batched,speedup,detail")
    print(f"bench_serve,{SLOTS}slots_mixed_prompts,"
          f"{m['tok_s_sequential']},{m['tok_s_batched']},{m['speedup']},"
          f"identical={m['outputs_identical']}")
    print(f"bench_serve_mixed,10to1_paged_{MIX_SLOTS_PAGED}slots,"
          f"{mm['tok_s_sequential']},{mm['tok_s_paged']},"
          f"{mm['speedup_paged']},"
          f"p95_paged={mm['p95_queue_paged_s']}s_vs_pinned="
          f"{mm['p95_queue_pinned_s']}s_identical="
          f"{mm['outputs_identical']}")
    publish("bench_serve", m, failed=not m["pass"])
    publish("bench_serve_mixed", mm, failed=not mm["pass"])
    if not m["pass"]:
        raise SystemExit(
            f"bench_serve gate missed: speedup {m['speedup']} "
            f"(target {TARGET_SPEEDUP}) identical={m['outputs_identical']}")
    if not mm["pass"]:
        raise SystemExit(
            f"bench_serve_mixed gate missed: speedup {mm['speedup_paged']} "
            f"(target {TARGET_SPEEDUP}), p95 paged "
            f"{mm['p95_queue_paged_s']}s vs pinned "
            f"{mm['p95_queue_pinned_s']}s, "
            f"identical={mm['outputs_identical']}")


if __name__ == "__main__":
    main()
