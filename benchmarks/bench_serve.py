"""Serving-throughput benchmark: the continuous-batching engine (DESIGN.md
§11) against the sequential one-request-at-a-time lower bound.

Same engine, same compiled step functions, same requests (mixed prompt
lengths); the only difference is ``max_concurrency=1`` for the baseline —
so the measured speedup is pure slot-occupancy, not a compilation artifact.

Gates (exit 1 on miss):
  * >= 2x generated tokens/s at 4 slots over the sequential baseline
  * per-request outputs identical between the two modes (batching must
    change wall-clock, never content)

Prints CSV; merges metrics into ``artifacts/bench_results.json`` so CI can
upload the perf snapshot without running the whole ``benchmarks.run`` suite.

    PYTHONPATH=src python -m benchmarks.bench_serve
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

SLOTS = 4
MAX_SEQ = 48
N_REQUESTS = 12
MAX_NEW = 16
TARGET_SPEEDUP = 2.0

LAST_METRICS: dict = {}


def _requests(cfg):
    import numpy as np

    from repro.launch.serve import Request

    rng = np.random.default_rng(0)
    # mixed prompt lengths over a small fixed set so both timed runs reuse
    # the same jitted prefill shapes
    lengths = [4, 7, 11, 5, 9, 6] * 3
    return [Request(i, rng.integers(0, cfg.vocab, size=lengths[i])
                    .astype(np.int32), MAX_NEW) for i in range(N_REQUESTS)]


def _serve(cfg, params, *, max_concurrency=None):
    from repro.launch.serve import serve_requests

    t0 = time.perf_counter()
    done, stats = serve_requests(cfg, params, _requests(cfg), slots=SLOTS,
                                 max_seq=MAX_SEQ,
                                 max_concurrency=max_concurrency)
    return done, stats, time.perf_counter() - t0


def run() -> dict:
    import jax

    from repro.configs import get_config
    from repro.models import family_module, reduced

    cfg = reduced(get_config("qwen3-8b"))
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0), tp=1)

    _serve(cfg, params)                       # warm every jit shape
    _serve(cfg, params, max_concurrency=1)

    done_b, stats_b, t_b = _serve(cfg, params)
    done_s, stats_s, t_s = _serve(cfg, params, max_concurrency=1)

    tok_s_batched = stats_b["generated"] / t_b
    tok_s_seq = stats_s["generated"] / t_s
    same = [r.out for r in done_b] == [r.out for r in done_s]
    return {
        "slots": SLOTS, "requests": N_REQUESTS, "max_new": MAX_NEW,
        "tokens": stats_b["generated"],
        "decode_steps_batched": stats_b["decode_steps"],
        "decode_steps_sequential": stats_s["decode_steps"],
        "tok_s_batched": round(tok_s_batched, 1),
        "tok_s_sequential": round(tok_s_seq, 1),
        "speedup": round(tok_s_batched / tok_s_seq, 2),
        "outputs_identical": same,
    }


def main() -> None:
    global LAST_METRICS
    from benchmarks._results import publish

    m = run()
    m["pass"] = bool(m["outputs_identical"]
                     and m["speedup"] >= TARGET_SPEEDUP)
    LAST_METRICS = m
    print("bench,case,tok_s_sequential,tok_s_batched,speedup,detail")
    print(f"bench_serve,{SLOTS}slots_mixed_prompts,"
          f"{m['tok_s_sequential']},{m['tok_s_batched']},{m['speedup']},"
          f"identical={m['outputs_identical']}")
    publish("bench_serve", m, failed=not m["pass"])
    if not m["pass"]:
        raise SystemExit(
            f"bench_serve gate missed: speedup {m['speedup']} "
            f"(target {TARGET_SPEEDUP}) identical={m['outputs_identical']}")


if __name__ == "__main__":
    main()
