"""Seeded golden regression for the full codesign() flow (DESIGN.md §10).

Two layers of protection for the batched-engine era:

  * bit-stability — the same seeded run executed twice in one process must
    commit the *identical* solution (schedules, hw encoding, float-exact
    objectives).  The lock-step engine, the q-batch acquisition, and the
    shared EvalCache are all deterministic; any nondeterminism is a bug.
  * golden snapshot — the chosen solution (intrinsic, hw params,
    per-workload latency) is compared against a checked-in JSON.  Structure
    and integer hw parameters must match exactly; floats to 1e-6 relative
    (cross-platform BLAS may differ in ulps).  Delete the file to re-bless
    after an intentional cost-model/DSE change.
"""
import json
import math
from pathlib import Path

from repro.core import workloads as W
from repro.core.codesign import codesign
from repro.core.cost_model import evaluate

GOLDEN = Path(__file__).parent / "golden" / "codesign_table1_gemm.json"


def _run():
    wl = W.table1_gemm()[:3]
    return wl, codesign(wl, intrinsics=["GEMM"], n_trials=8, n_init=4,
                        seed=0, q=2)


def _snapshot(wl, rep) -> dict:
    sol = rep.solution
    assert sol is not None
    per_workload = {}
    for w in wl:
        sched = sol.schedules[w.name]
        r = evaluate(w, sched, sol.hw)
        per_workload[w.name] = {
            "latency_s": r.latency_s,
            "schedule": sched.describe(),
        }
    return {
        "intrinsic": sol.intrinsic,
        "hw": list(sol.hw.encode()),           # JSON-stable form
        "latency_s": sol.latency_s,
        "power_w": sol.power_w,
        "area_um2": sol.area_um2,
        "workloads": per_workload,
    }


def test_codesign_gemm_set_bit_stable_and_matches_golden():
    wl, rep1 = _run()
    _, rep2 = _run()
    snap1, snap2 = _snapshot(wl, rep1), _snapshot(wl, rep2)
    assert snap1 == snap2                      # bit-stable across runs

    if not GOLDEN.exists():                    # first run blesses the golden
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(json.dumps(snap1, indent=2, sort_keys=True) + "\n")
    golden = json.loads(GOLDEN.read_text())

    assert snap1["intrinsic"] == golden["intrinsic"]
    assert snap1["hw"] == golden["hw"]
    assert set(snap1["workloads"]) == set(golden["workloads"])
    for key in ("latency_s", "power_w", "area_um2"):
        assert math.isclose(snap1[key], golden[key], rel_tol=1e-6), key
    for name, got in snap1["workloads"].items():
        want = golden["workloads"][name]
        assert got["schedule"] == want["schedule"], name
        assert math.isclose(got["latency_s"], want["latency_s"],
                            rel_tol=1e-6), name
