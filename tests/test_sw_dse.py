"""Software DSE: schedule moves, heuristic values, DQN mechanics, and the
full heuristic+Q-learning optimizer."""
import math

import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.cost_model import evaluate
from repro.core.heuristic import candidate_value, top_k
from repro.core.hw_primitives import HWBuilder
from repro.core.intrinsics import GEMM
from repro.core.matching import match
from repro.core.qlearning import DQN
from repro.core.sw_dse import optimize, optimize_set, total_latency
from repro.core.sw_primitives import schedule_from_primitives, Primitive
from repro.core.sw_space import SoftwareSpace


@pytest.fixture
def setup():
    wl = W.gemm(256, 256, 256)
    hw = (HWBuilder("GEMM").reshapeArray([16, 16], depth=16)
          .addCache(256).partitionBanks(2).build())
    choices = match(GEMM, wl)
    return wl, hw, choices


def test_candidate_value_direction():
    assert candidate_value(1.0, 1.0) == pytest.approx(1.0)
    assert candidate_value(2.0, 1.0) < candidate_value(1.5, 1.0)
    assert candidate_value(math.inf, 1.0) == 0.0


def test_top_k_orders_by_value():
    idx = top_k(["a", "b", "c"], [3.0, 1.0, 2.0], 2)
    assert idx == [1, 2]


def test_top_k_filters_infeasible():
    """Regression: when k exceeds the feasible count, infinite-latency
    (known-illegal) candidates must NOT pad the result — the refine budget
    would be spent revising them."""
    lats = [math.inf, 2.0, math.inf, 1.0, math.inf]
    idx = top_k(list("abcde"), lats, 4)
    assert idx == [3, 1]                      # only the two feasible, ranked
    assert top_k(list("ab"), [math.inf, math.inf], 2) == []
    # unchanged when feasible candidates are plentiful
    assert top_k(list("abc"), [3.0, 1.0, 2.0], 2) == [1, 2]


def _engines_agree(wl, choices, hw, *, seeds, pool_size, rounds, k):
    from repro.core.sw_dse import SearchSpec, run_searches
    specs = [SearchSpec(wl, choices, hw, seed=s) for s in seeds]
    ref = run_searches(specs, pool_size=pool_size, rounds=rounds, k=k,
                       engine="reference")
    bat = run_searches(specs, pool_size=pool_size, rounds=rounds, k=k,
                       engine="batched")
    for r, b in zip(ref, bat):
        assert r.schedule == b.schedule
        assert (r.latency_s == b.latency_s) or \
            (math.isinf(r.latency_s) and math.isinf(b.latency_s))
        assert r.history == b.history
        assert r.evaluations == b.evaluations
    return bat


def test_ragged_frontier_engine_parity(setup):
    """With small pools over a space where ~10% of random schedules are
    illegal, some rounds revise fewer than k candidates.  The lock-step
    engine's padded frontiers must stay bit-identical to the reference on
    every seed (RNG streams sized by the real counts, padded transitions
    masked out of training)."""
    wl, hw, choices = setup
    _engines_agree(wl, choices, hw, seeds=range(5), pool_size=6, rounds=4,
                   k=4)


def test_all_infeasible_space_survives_and_engines_agree(setup):
    """A hardware point whose cache fits nothing makes every schedule
    infeasible: frontiers are empty, the newest-n fallback bounds the pool,
    and both engines must agree without stalling or crashing."""
    from repro.core.hw_primitives import HWBuilder
    wl, _, choices = setup
    hw = (HWBuilder("GEMM").reshapeArray([16, 16], depth=16)
          .addCache(1).partitionBanks(1).build())
    res = _engines_agree(wl, choices, hw, seeds=[0, 1], pool_size=6,
                         rounds=3, k=4)
    assert all(math.isinf(r.latency_s) for r in res)


def test_moves_preserve_legality_domain(setup):
    wl, hw, choices = setup
    space = SoftwareSpace(wl, choices, hw)
    rng = np.random.default_rng(0)
    s = space.default_schedule()
    for move in space.moves:
        s2 = space.apply(s, move, rng)
        for l, t in s2.tiles:
            assert 1 <= t <= wl.extents[l]
        assert set(s2.order) == set(s.order)


def test_features_fixed_size(setup):
    wl, hw, choices = setup
    space = SoftwareSpace(wl, choices, hw)
    f = space.features(space.default_schedule())
    assert f.shape == (space.n_features,)
    assert np.all(np.isfinite(f))


def test_dqn_learns_preference():
    """A bandit with one clearly-best action: the DQN must discover it."""
    dqn = DQN(n_features=4, n_actions=3, hidden=16, seed=0)
    rng = np.random.default_rng(0)
    feat = np.ones(4, np.float32)
    for _ in range(300):
        a = int(rng.integers(3))
        r = 1.0 if a == 2 else -0.2
        dqn.record(feat, a, r, feat)
        dqn.train_step(batch=16)
    dqn.eps = 0.0
    assert dqn.select(feat) == 2


def test_optimize_beats_default(setup):
    wl, hw, choices = setup
    space = SoftwareSpace(wl, choices, hw)
    default_lat = space.latency(space.default_schedule())
    res = optimize(wl, choices, hw, pool_size=12, rounds=6, k=4, seed=0)
    assert res.latency_s <= default_lat
    assert res.history == sorted(res.history, reverse=True)  # monotone best


def test_optimize_set_shares_accelerator(setup):
    wl, hw, _ = setup
    wl2 = W.gemm(128, 128, 512, name="g2")
    from repro.core.matching import partition_space
    part = partition_space([GEMM], [wl, wl2])
    results = optimize_set([wl, wl2], part, hw, budget="small", seed=0)
    assert set(results) == {wl.name, "g2"}
    assert math.isfinite(total_latency(results))


def test_primitive_sequence_roundtrip(setup):
    wl, hw, choices = setup
    seq = [Primitive("split", ("i", 64)), Primitive("split", ("k", 32)),
           Primitive("reorder", (("j", "i", "k"),)),
           Primitive("tensorize", ("GEMM", ("i", "j", "k")))]
    s = schedule_from_primitives(wl, choices[0], seq)
    assert s.tile_map["i"] == 64 and s.tile_map["k"] == 32
    assert s.order == ("j", "i", "k")
    back = s.to_primitives(wl)
    kinds = [p.kind for p in back]
    assert kinds.count("tensorize") == 1 and "reorder" in kinds
    rep = evaluate(wl, s, hw)
    assert rep.legal
