"""Sharding lint (repro.analysis.sharding_lint, DESIGN.md §16.4).

  * shipped configs lint clean — every family's ``specs()`` /
    ``cache_specs()`` / ``paged_cache_specs()`` against the production
    meshes, with shapes coming from ``jax.eval_shape`` over the real
    initializers (the zero-false-positive half of the contract);
  * seeded defects — every rule fires on a minimal hand-built (spec,
    shape) tree: unknown axis, indivisible dim, rank/tree mismatch,
    duplicate axis, sharded pool rows, batch axes on pool leaves, and the
    full-replication memory-cliff warning.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.findings import errors, warnings
from repro.analysis.sharding_lint import lint_config, lint_tree
from repro.configs import ARCH_IDS, get_config

MESHES = [None, {"data": 2, "model": 4}]

# lint every family shape once; the CLI/CI gate covers the full matrix
SMALL = ["qwen3-8b", "gemma2-2b", "granite-moe-3b-a800m", "rwkv6-3b",
         "zamba2-2.7b", "hubert-xlarge", "internvl2-76b"]


def _leaf(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# shipped configs are clean (no error-severity findings)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mesh", MESHES,
                         ids=["no-mesh", "data2xmodel4"])
@pytest.mark.parametrize("arch", SMALL)
def test_shipped_config_lints_clean(arch, mesh):
    got = lint_config(get_config(arch), mesh)
    assert errors(got) == [], [str(f) for f in errors(got)]


def test_all_arch_ids_resolve():
    # the CI gate loops the full ARCH_IDS x MESHES matrix; make sure the
    # subset above is not silently stale
    assert set(SMALL) <= set(ARCH_IDS)


# ---------------------------------------------------------------------------
# seeded defects
# ---------------------------------------------------------------------------

def test_unknown_axis():
    got = lint_tree({"w": P("tensor")}, {"w": _leaf(8, 8)},
                    {"data": 2}, site="t")
    assert _rules(errors(got)) == {"sharding/unknown-axis"}


def test_indivisible_dim():
    got = lint_tree({"w": P("model")}, {"w": _leaf(6, 8)},
                    {"model": 4}, site="t")
    assert _rules(errors(got)) == {"sharding/indivisible-dim"}
    # same spec divides cleanly off-mesh and on model=2
    assert lint_tree({"w": P("model")}, {"w": _leaf(6, 8)}, None,
                     site="t") == []
    assert lint_tree({"w": P("model")}, {"w": _leaf(6, 8)}, {"model": 2},
                     site="t") == []


def test_axis_tuple_product_divisibility():
    spec = {"w": P(("pod", "data"), None)}
    got = lint_tree(spec, {"w": _leaf(12, 4)}, {"pod": 2, "data": 4},
                    site="t")
    assert _rules(errors(got)) == {"sharding/indivisible-dim"}  # 12 % 8
    assert lint_tree(spec, {"w": _leaf(16, 4)}, {"pod": 2, "data": 4},
                     site="t") == []


def test_rank_mismatch():
    got = lint_tree({"w": P("model", None, None)}, {"w": _leaf(8)},
                    None, site="t")
    assert _rules(errors(got)) == {"sharding/rank-mismatch"}


def test_duplicate_axis():
    got = lint_tree({"w": P("data", "data")}, {"w": _leaf(8, 8)},
                    {"data": 2}, site="t")
    assert "sharding/duplicate-axis" in _rules(errors(got))


def test_tree_mismatch():
    got = lint_tree({"a": P()}, {"a": _leaf(4), "b": _leaf(4)},
                    None, site="t")
    assert _rules(errors(got)) == {"sharding/tree-mismatch"}


def test_pool_rows_sharded():
    got = lint_tree({"k": P(None, "model", None)}, {"k": _leaf(2, 8, 4)},
                    {"model": 4}, site="t", pool_axes={"k": "pool"})
    assert "sharding/pool-rows-sharded" in _rules(errors(got))


def test_pool_batch_axis():
    got = lint_tree({"k": P(None, None, "data")}, {"k": _leaf(2, 8, 4)},
                    {"data": 2}, site="t", pool_axes={"k": "pool"})
    assert "sharding/pool-batch-axis" in _rules(errors(got))


def test_fully_replicated_warns_only_when_large_and_meshed():
    big, small = _leaf(2048, 2048), _leaf(64, 64)      # 16 MiB vs 16 KiB
    got = lint_tree({"w": P()}, {"w": big}, {"data": 2}, site="t",
                    warn_replicated=True)
    assert errors(got) == []
    assert _rules(warnings(got)) == {"sharding/fully-replicated"}
    # small leaves, single-device meshes, and cache trees stay silent
    assert lint_tree({"w": P()}, {"w": small}, {"data": 2}, site="t",
                     warn_replicated=True) == []
    assert lint_tree({"w": P()}, {"w": big}, None, site="t",
                     warn_replicated=True) == []
    assert lint_tree({"w": P()}, {"w": big}, {"data": 2}, site="t") == []
