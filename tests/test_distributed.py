"""Distribution substrate: spec pruning, batch specs, activation-sharding
context, and multi-device pipeline parallelism / elastic restore via a
subprocess that widens the host platform."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
from jax.sharding import PartitionSpec as P

from repro.distributed.context import (constrain_activations,
                                       set_activation_spec)
from repro.distributed.sharding import batch_specs, named, prune_specs

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_prune_specs_drops_missing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"a": P(("pod", "data"), "model"), "b": P("pod"), "c": P(None)}
    got = prune_specs(tree, mesh)
    assert got["a"] == P("data", "model")
    assert got["b"] == P(None)
    assert got["c"] == P(None)


def test_named_builds_shardings():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = named({"w": P("model", "data")}, mesh)
    assert sh["w"].mesh.shape == {"data": 1, "model": 1}


def test_batch_specs_families():
    from repro.configs import get_config
    assert "frames" in batch_specs(get_config("hubert-xlarge"))
    assert "patches" in batch_specs(get_config("internvl2-76b"))
    assert set(batch_specs(get_config("qwen3-8b"))) == {"tokens", "labels"}


def test_activation_context_noop_when_unset():
    import jax.numpy as jnp
    set_activation_spec(None)
    x = jnp.ones((2, 4, 8))
    assert constrain_activations(x) is x


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "__SRC__")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    # --- pipeline parallelism over 4 stages -----------------------------
    from repro.distributed.pipeline import pipeline_apply
    mesh = jax.make_mesh((4,), ("stage",))
    S, B, D = 4, 8, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    def layer(w, h):
        return jnp.tanh(h @ w)

    got = pipeline_apply(layer, ws, x, mesh=mesh, axis="stage")
    want = x
    for i in range(S):
        want = layer(ws[i], want)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-5), \\
        float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    print("pipeline OK")

    # --- elastic checkpoint restore across mesh shapes --------------------
    from repro.ft import CheckpointManager
    import tempfile
    tmp = tempfile.mkdtemp()
    mgr = CheckpointManager(tmp)
    mesh8 = jax.make_mesh((8, 1), ("data", "model"))
    sharding = jax.sharding.NamedSharding(mesh8, P("data", None))
    arr = jax.device_put(jnp.arange(32.0).reshape(8, 4), sharding)
    mgr.save(1, {"w": arr})
    mesh2 = jax.make_mesh((2, 1), ("data", "model"))
    got = mgr.restore(1, like={"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)},
                      mesh=mesh2, specs={"w": P("data", None)})
    assert got["w"].sharding.mesh.shape["data"] == 2
    assert np.allclose(np.asarray(got["w"]), np.arange(32.0).reshape(8, 4))
    print("elastic OK")

    # --- quantized/bf16 DP reduction path runs under shard_map ------------
    from jax.experimental.shard_map import shard_map
    def psum_bf16(g):
        return jax.lax.psum(g.astype(jnp.bfloat16), "data").astype(jnp.float32)
    f = shard_map(psum_bf16, mesh=mesh8, in_specs=P("data"), out_specs=P())
    r = f(jnp.ones((8, 4)))
    assert np.allclose(np.asarray(r), 8.0, atol=0.1)
    print("bf16 reduce OK")
""")


def test_multidevice_pipeline_and_elastic():
    script = _MULTIDEV.replace("__SRC__", SRC)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "pipeline OK" in proc.stdout
    assert "elastic OK" in proc.stdout
    assert "bf16 reduce OK" in proc.stdout
