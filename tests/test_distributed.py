"""Distribution substrate: spec pruning, batch specs, activation-sharding
context, and multi-device pipeline parallelism / elastic restore via a
subprocess that widens the host platform."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import context
from repro.distributed.context import (DEFAULT_TRAIN_SPEC, activation_spec,
                                       constrain_activations,
                                       get_activation_spec,
                                       set_activation_spec)
from repro.distributed.sharding import batch_specs, named, prune_specs

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_prune_specs_drops_missing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"a": P(("pod", "data"), "model"), "b": P("pod"), "c": P(None)}
    got = prune_specs(tree, mesh)
    assert got["a"] == P("data", "model")
    assert got["b"] == P(None)
    assert got["c"] == P(None)


def test_named_builds_shardings():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = named({"w": P("model", "data")}, mesh)
    assert sh["w"].mesh.shape == {"data": 1, "model": 1}


def test_batch_specs_families():
    from repro.configs import get_config
    assert "frames" in batch_specs(get_config("hubert-xlarge"))
    assert "patches" in batch_specs(get_config("internvl2-76b"))
    assert set(batch_specs(get_config("qwen3-8b"))) == {"tokens", "labels"}


def test_activation_context_noop_when_unset():
    import jax.numpy as jnp
    set_activation_spec(None)
    x = jnp.ones((2, 4, 8))
    assert constrain_activations(x) is x


def test_activation_spec_context_manager_scopes_and_restores():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    set_activation_spec(P("data", None, None), mesh)
    with activation_spec(DEFAULT_TRAIN_SPEC, mesh):
        # pruned against the install mesh's axes ('pod' dropped)
        assert get_activation_spec() == P("data", "model", None)
    assert get_activation_spec() == P("data", None, None)   # restored
    context.reset()
    assert get_activation_spec() is None


def test_activation_spec_installed_without_mesh_prunes_lazily():
    """Regression: ``set_activation_spec(spec)`` with no mesh used to store
    the raw spec, and ``constrain_activations`` then crashed on any mesh
    lacking the 'pod' axis DEFAULT_TRAIN_SPEC names.  The spec must prune
    at apply time against the mesh actually active."""
    import jax.numpy as jnp
    set_activation_spec(DEFAULT_TRAIN_SPEC)   # no mesh: raw spec stored
    mesh = jax.make_mesh((1, 1), ("data", "model"))   # podless
    x = jnp.ones((2, 4, 8))
    with mesh:
        out = jax.jit(constrain_activations)(x)
    assert out.shape == x.shape
    assert float(out.sum()) == float(x.sum())


def test_activation_context_fixture_installs():
    # paired with the test below: relies on pytest's in-file definition
    # order to verify the conftest autouse fixture resets between tests
    set_activation_spec(DEFAULT_TRAIN_SPEC)
    assert get_activation_spec() is not None


def test_activation_context_fixture_isolates():
    assert get_activation_spec() is None


def test_make_host_mesh_tp_factors_device_count():
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    mesh = make_host_mesh(tp=n)
    assert mesh.shape["model"] == n and mesh.shape["data"] == 1
    assert dict(make_host_mesh().shape) == {"data": n, "model": 1}
    with pytest.raises(ValueError):
        make_host_mesh(tp=n + 1)   # n + 1 never divides n
    with pytest.raises(ValueError):
        make_host_mesh(tp=0)


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "__SRC__")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    # --- pipeline parallelism over 4 stages -----------------------------
    from repro.distributed.pipeline import pipeline_apply
    mesh = jax.make_mesh((4,), ("stage",))
    S, B, D = 4, 8, 16
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    def layer(w, h):
        return jnp.tanh(h @ w)

    got = pipeline_apply(layer, ws, x, mesh=mesh, axis="stage")
    want = x
    for i in range(S):
        want = layer(ws[i], want)
    assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-5), \\
        float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    print("pipeline OK")

    # --- elastic checkpoint restore across mesh shapes --------------------
    from repro.ft import CheckpointManager
    import tempfile
    tmp = tempfile.mkdtemp()
    mgr = CheckpointManager(tmp)
    mesh8 = jax.make_mesh((8, 1), ("data", "model"))
    sharding = jax.sharding.NamedSharding(mesh8, P("data", None))
    arr = jax.device_put(jnp.arange(32.0).reshape(8, 4), sharding)
    mgr.save(1, {"w": arr})
    mesh2 = jax.make_mesh((2, 1), ("data", "model"))
    got = mgr.restore(1, like={"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)},
                      mesh=mesh2, specs={"w": P("data", None)})
    assert got["w"].sharding.mesh.shape["data"] == 2
    assert np.allclose(np.asarray(got["w"]), np.arange(32.0).reshape(8, 4))
    print("elastic OK")

    # --- quantized/bf16 DP reduction path runs under shard_map ------------
    from jax.experimental.shard_map import shard_map
    def psum_bf16(g):
        return jax.lax.psum(g.astype(jnp.bfloat16), "data").astype(jnp.float32)
    f = shard_map(psum_bf16, mesh=mesh8, in_specs=P("data"), out_specs=P())
    r = f(jnp.ones((8, 4)))
    assert np.allclose(np.asarray(r), 8.0, atol=0.1)
    print("bf16 reduce OK")
""")


def test_multidevice_pipeline_and_elastic():
    script = _MULTIDEV.replace("__SRC__", SRC)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "pipeline OK" in proc.stdout
    assert "elastic OK" in proc.stdout
    assert "bf16 reduce OK" in proc.stdout


_MESH_FAMILIES = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "__SRC__")
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.distributed.sharding import named, param_shardings, \\
        prune_specs
    from repro.launch.mesh import make_host_mesh
    from repro.models import family_module, reduced

    mesh = make_host_mesh(tp=4)
    assert dict(mesh.shape) == {"data": 2, "model": 4}
    axes = set(mesh.axis_names)

    def spec_leaves(tree):
        return jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, P))

    # every leaf of every family's specs prunes to the mesh's axes and the
    # real (tp-padded) arrays actually lay out on (data=2, model=4)
    for arch in ("qwen3-8b", "gemma2-2b", "zamba2-2.7b", "rwkv6-3b"):
        cfg = reduced(get_config(arch))
        mod = family_module(cfg)
        for tree in (mod.specs(cfg), mod.cache_specs(cfg),
                     mod.paged_cache_specs(cfg)):
            pruned = spec_leaves(prune_specs(tree, mesh))
            assert pruned, arch
            for spec in pruned:
                for entry in spec:
                    names = entry if isinstance(entry, tuple) else (entry,)
                    assert all(nm is None or nm in axes for nm in names), \\
                        (arch, spec)
        params = jax.device_put(
            mod.init(cfg, jax.random.PRNGKey(0), tp=4),
            param_shardings(mod, cfg, mesh))
        dense = jax.device_put(mod.init_cache(cfg, 4, 32, 4),
                               named(mod.cache_specs(cfg), mesh))
        paged = jax.device_put(mod.init_paged_cache(cfg, 4, 32, 32, 4),
                               named(mod.paged_cache_specs(cfg), mesh))
        jax.block_until_ready((params, dense, paged))
        print(arch, "layout OK")

    # sharded-vs-dense teacher-forced decode oracle (qwen3, f32 so the
    # collective's reassociation drift stays far below top-2 logit gaps)
    from repro.launch.steps import make_decode_step
    cfg = dataclasses.replace(reduced(get_config("qwen3-8b")),
                              dtype="float32")
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0), tp=4)
    step = jax.jit(make_decode_step(cfg, tp=4, impl="xla"))
    prompt = np.random.default_rng(1).integers(
        0, cfg.vocab, size=(2, 6)).astype(np.int32)

    def rollout(p, cache):
        outs = []
        for t in range(prompt.shape[1]):
            logits, cache = step(p, cache, jnp.asarray(prompt[:, t:t + 1]),
                                 jnp.int32(t))
            outs.append(np.asarray(logits[:, -1], np.float64))
        return np.stack(outs)

    ref = rollout(params, mod.init_cache(cfg, 2, 8, 4))
    got = rollout(
        jax.device_put(params, param_shardings(mod, cfg, mesh)),
        jax.device_put(mod.init_cache(cfg, 2, 8, 4),
                       named(mod.cache_specs(cfg), mesh)))
    err = float(np.abs(ref - got).max())
    assert err < 1e-3, err
    assert (ref.argmax(-1) == got.argmax(-1)).all()
    print("oracle OK", err)
""")


def test_mesh_layout_all_families_and_decode_oracle():
    script = _MESH_FAMILIES.replace("__SRC__", SRC)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    for arch in ("qwen3-8b", "gemma2-2b", "zamba2-2.7b", "rwkv6-3b"):
        assert f"{arch} layout OK" in proc.stdout
    assert "oracle OK" in proc.stdout
