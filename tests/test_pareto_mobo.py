"""Pareto/hypervolume/GP/MOBO machinery."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.hw_space import HWSpace
from repro.core.mobo import mobo, rescore_hv_history, shared_reference
from repro.core.nsga2 import nsga2
from repro.core.pareto import (_reference_hypervolume, _reference_pareto_mask,
                               default_reference, dominates, hvi_batch,
                               hypervolume, pareto_front, pareto_mask)
from repro.core.random_search import random_search
from repro.core.surrogate import GP


def test_dominates_basics():
    assert dominates(np.array([1, 1]), np.array([2, 2]))
    assert not dominates(np.array([1, 2]), np.array([2, 1]))
    assert not dominates(np.array([1, 1]), np.array([1, 1]))


@given(st.lists(st.tuples(st.floats(0, 10), st.floats(0, 10),
                          st.floats(0, 10)), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_pareto_mask_matches_bruteforce(pts):
    arr = np.array(pts)
    mask = pareto_mask(arr)
    for i in range(len(arr)):
        dominated = any(dominates(arr[j], arr[i]) for j in range(len(arr))
                        if j != i)
        assert mask[i] == (not dominated)


@st.composite
def _point_sets(draw, dmax=4, nmax=24):
    """Random (n, d) clouds in [0, 10]^d, d in {1, .., dmax}."""
    d = draw(st.integers(1, dmax))
    n = draw(st.integers(1, nmax))
    vals = draw(st.lists(st.floats(0, 10), min_size=n * d, max_size=n * d))
    return np.array(vals).reshape(n, d)


@given(_point_sets())
@settings(max_examples=60, deadline=None)
def test_vectorized_mask_matches_reference(pts):
    assert np.array_equal(pareto_mask(pts), _reference_pareto_mask(pts))


@given(_point_sets())
@settings(max_examples=60, deadline=None)
def test_vectorized_hypervolume_matches_reference(pts):
    ref = np.full(pts.shape[1], 11.0)
    assert hypervolume(pts, ref) == pytest.approx(
        _reference_hypervolume(pts, ref), rel=1e-9, abs=1e-9)


@given(_point_sets(dmax=3), _point_sets(dmax=3))
@settings(max_examples=40, deadline=None)
def test_hvi_batch_equals_recompute_deltas(front, cands):
    if front.shape[1] != cands.shape[1]:
        return
    ref = np.full(front.shape[1], 11.0)
    hv0 = hypervolume(front, ref)
    deltas = [hypervolume(np.vstack([front, c[None]]), ref) - hv0
              for c in cands]
    np.testing.assert_allclose(hvi_batch(front, ref, cands), deltas,
                               atol=1e-9)


def test_hypervolume_2d_exact():
    pts = np.array([[1.0, 3.0], [2.0, 2.0], [3.0, 1.0]])
    ref = np.array([4.0, 4.0])
    # union of three boxes = 3+2+1... exact: 3*1 + 2*1 + 1*1 = 6? compute:
    # sorted by x: (1,3):(4-1)*(4-3)=3; (2,2): (4-2)*(3-2)=2; (3,1):(4-3)*(2-1)=1
    assert hypervolume(pts, ref) == pytest.approx(6.0)


def test_hypervolume_3d_exact_cube():
    pts = np.array([[0.0, 0.0, 0.0]])
    ref = np.array([2.0, 3.0, 4.0])
    assert hypervolume(pts, ref) == pytest.approx(24.0)
    # adding a dominated point changes nothing
    pts2 = np.vstack([pts, [[1.0, 1.0, 1.0]]])
    assert hypervolume(pts2, ref) == pytest.approx(24.0)


def test_hypervolume_monotone_in_points():
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 1, (20, 3))
    ref = np.array([1.5, 1.5, 1.5])
    hv = [hypervolume(pts[:i], ref) for i in range(1, 21)]
    assert all(b >= a - 1e-12 for a, b in zip(hv, hv[1:]))


def test_gp_recovers_smooth_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (40, 2))
    y = np.sin(3 * X[:, 0]) + X[:, 1] ** 2
    gp = GP().fit(X, y)
    Xs = rng.uniform(0.1, 0.9, (10, 2))
    ys = np.sin(3 * Xs[:, 0]) + Xs[:, 1] ** 2
    mean, var = gp.predict(Xs)
    assert np.max(np.abs(mean - ys)) < 0.25
    assert np.all(var >= 0)


def _cheap_objectives(hw):
    """Synthetic 3-objective function over the hardware space."""
    lat = 1.0 / (n := hw.pe_rows * hw.pe_cols) + hw.burst_bytes * 1e-9
    pow_ = n * 1e-3 + hw.vmem_kib * 1e-4
    area = n * 10.0 + hw.vmem_kib * 5.0
    return (lat, pow_, area)


def test_mobo_beats_random_on_shared_ref():
    space = HWSpace("GEMM")
    res_m = mobo(space, _cheap_objectives, n_init=5, n_trials=18, seed=1)
    res_r = random_search(space, _cheap_objectives, n_trials=18, seed=1)
    ref = shared_reference([res_m, res_r])
    hv_m = rescore_hv_history(res_m, ref)[-1]
    hv_r = rescore_hv_history(res_r, ref)[-1]
    assert hv_m >= 0.9 * hv_r  # MOBO should at least keep pace


def test_nsga2_runs_and_respects_budget():
    space = HWSpace("GEMM")
    res = nsga2(space, _cheap_objectives, pop_size=5, n_trials=15, seed=0)
    assert res.evaluations <= 15
    assert len(res.hv_history) == res.evaluations
    assert res.pareto_ys.shape[1] == 3


def test_best_under_constraints():
    space = HWSpace("GEMM")
    res = random_search(space, _cheap_objectives, n_trials=10, seed=2)
    bound = float(np.median(res.ys[:, 1]))
    pick = res.best_under({1: bound})
    assert pick is not None
    hw, y = pick
    assert y[1] <= bound
