"""Jaxpr hot-path auditor (repro.analysis.jaxpr_audit, DESIGN.md §16.3).

  * shipped hot paths audit clean — ``audit_hot_paths`` over the real
    serve decode / chunked-prefill / slot-write / paged-decode / train-step
    programs must return no findings.  granite-moe pins the router-mask
    regression this auditor originally caught: the MoE padding mask was a
    weak-typed f32 constant, so a checkpoint round-trip (strong f32) vs a
    fresh init (weak f32) split the jit cache and silently recompiled
    every program that closed over it;
  * each detector fires on a minimal seeded program — host callbacks,
    state-dependent traces, silent recompiles, weak-typed args, scalar /
    large-array closure captures, and missed donations.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.findings import errors, warnings
from repro.analysis.jaxpr_audit import (audit_hot_paths, audit_jit_cache,
                                        audit_program, audit_retrace)
from repro.configs import get_config
from repro.models import reduced


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# shipped hot paths are clean (regression pin for the router-mask fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-8b", "granite-moe-3b-a800m"])
def test_shipped_hot_paths_audit_clean(arch):
    got = audit_hot_paths(reduced(get_config(arch)))
    assert got == [], [str(f) for f in got]


def test_encoder_only_audits_train_step_only():
    got = audit_hot_paths(reduced(get_config("hubert-xlarge")))
    assert got == [], [str(f) for f in got]


# ---------------------------------------------------------------------------
# seeded defects, one per detector
# ---------------------------------------------------------------------------

def test_host_callback_detected():
    def f(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    got = audit_program(f, jnp.zeros((4,), jnp.float32), site="t")
    assert "jaxpr/host-callback" in _rules(errors(got))


def test_clean_program_has_no_findings():
    def f(x, y):
        return x @ y

    got = audit_program(f, jnp.zeros((8, 8), jnp.float32),
                        jnp.zeros((8, 8), jnp.float32), site="t")
    assert got == []


def test_state_dependent_trace_detected():
    def step(toks, pos):
        return toks + pos

    toks = jnp.zeros((2,), jnp.int32)
    # engine state leaking through python scalars: the value class changes
    # the traced dtype, so consecutive ticks trace different programs
    got = audit_retrace(step, (toks, 0), (toks, 0.5), site="t")
    assert _rules(got) == {"jaxpr/state-dependent-trace"}
    assert got[0].severity == "error"
    # committed arrays carry the state through the arguments: clean
    assert audit_retrace(step, (toks, jnp.int32(0)), (toks, jnp.int32(1)),
                         site="t") == []


def test_silent_recompile_detected():
    jf = jax.jit(lambda x, s: x * s)
    z = jnp.zeros((4,), jnp.float32)
    got = audit_jit_cache(jf, [(z, 2), (z, 2.5)], site="t")
    assert _rules(got) == {"jaxpr/recompile"}
    jf2 = jax.jit(lambda x: x * 2)
    assert audit_jit_cache(jf2, [(z,), (z,), (z,)], site="t") == []


def test_weak_typed_arg_flagged():
    def f(x, s):
        return x * s

    got = audit_program(f, jnp.zeros((4,), jnp.float32), 2.0, site="t")
    assert "jaxpr/weak-type-arg" in _rules(warnings(got))
    assert audit_program(f, jnp.zeros((4,), jnp.float32),
                         jnp.float32(2.0), site="t") == []


def test_weak_scalar_closure_capture_detected():
    temperature = jnp.asarray(2.5)          # weak 0-d: the router-mask bug

    def f(x):
        return x * temperature

    got = audit_program(f, jnp.zeros((4,), jnp.float32), site="t")
    assert "jaxpr/scalar-capture" in _rules(errors(got))


def test_large_const_capture_flagged():
    table = np.ones((600, 600), np.float32)           # 1.44 MB

    def f(x):
        return x @ jnp.asarray(table)

    got = audit_program(f, jnp.zeros((600,), jnp.float32), site="t")
    assert "jaxpr/large-const-capture" in _rules(warnings(got))


def test_missed_donation_flagged_and_silenced_by_donation():
    def step(state, x):
        return state + x, (state * x).sum()

    state = jnp.zeros((256, 256), jnp.float32)        # 256 KiB
    x = jnp.float32(1.0)
    got = audit_program(step, state, x, site="t")
    assert "jaxpr/missed-donation" in _rules(warnings(got))
    assert audit_program(step, state, x, donate_argnums=(0,),
                         site="t") == []
