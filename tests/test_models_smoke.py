"""Per-architecture smoke tests: reduced same-family config, one forward /
train step / decode step on CPU; output shapes + finiteness; params/specs
tree agreement (the dry-run's sharding contract)."""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_train_step
from repro.models import family_module, reduced
from repro.optim import AdamW

KEY = jax.random.PRNGKey(0)


def make_inputs(cfg, b=2, s=16, with_labels=False):
    out = {}
    if cfg.embed_inputs:
        out["frames"] = jnp.ones((b, s, cfg.d_model), cfg.dtype)
    elif cfg.vis_tokens:
        out["tokens"] = jnp.ones((b, s - cfg.vis_tokens), jnp.int32)
        out["patches"] = jnp.ones((b, cfg.vis_tokens, cfg.d_model), cfg.dtype)
    else:
        out["tokens"] = jnp.ones((b, s), jnp.int32)
    if with_labels:
        n = s - cfg.vis_tokens if cfg.vis_tokens else s
        out["labels"] = jnp.ones((b, n), jnp.int32)
    return out


def spec_structure(tree):
    return jax.tree_util.tree_structure(jax.tree_util.tree_map(
        lambda _: 0, tree, is_leaf=lambda x: isinstance(x, P)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced(get_config(arch))
    mod = family_module(cfg)
    params = mod.init(cfg, KEY, tp=1)
    logits = mod.forward(params, cfg, make_inputs(cfg), tp=1, impl="xla")
    assert logits.shape[0] == 2 and logits.shape[-1] >= cfg.vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_structure(arch):
    cfg = reduced(get_config(arch))
    mod = family_module(cfg)
    params = mod.init(cfg, KEY, tp=1)
    assert (jax.tree_util.tree_structure(params)
            == spec_structure(mod.specs(cfg)))


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma2-2b",
                                  "granite-moe-3b-a800m", "rwkv6-3b",
                                  "zamba2-2.7b", "hubert-xlarge"])
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    mod = family_module(cfg)
    params = mod.init(cfg, KEY, tp=1)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt, tp=1))
    batch = make_inputs(cfg, with_labels=True)
    params, opt_state, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # a second step still works on the updated tree
    _, _, m2 = step(params, opt_state, batch)
    assert bool(jnp.isfinite(m2["loss"]))


DECODABLE = [a for a in ARCH_IDS if a != "hubert-xlarge"]


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "gemma2-2b",
                                  "moonshot-v1-16b-a3b", "rwkv6-3b",
                                  "zamba2-2.7b"])
def test_decode_consistency_with_prefill(arch):
    """Greedy decode over a teacher-forced prefix must match the full
    forward's next-token logits (cache correctness)."""
    cfg = reduced(get_config(arch))
    mod = family_module(cfg)
    params = mod.init(cfg, KEY, tp=1)
    b, s = 2, 8
    toks = (jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) * 7) % cfg.vocab
    full = mod.forward(params, cfg, {"tokens": toks}, tp=1, impl="xla")
    cache = mod.init_cache(cfg, b, s, tp=1)
    for t in range(s):
        logits, cache = mod.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                        jnp.int32(t), tp=1, impl="xla")
    got = logits[:, 0].astype(jnp.float32)
    want = full[:, -1].astype(jnp.float32)
    # same argmax and close logits on the real vocab
    assert jnp.allclose(got[:, :cfg.vocab], want[:, :cfg.vocab],
                        rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-3b", "zamba2-2.7b"])
def test_cache_specs_match_structure(arch):
    cfg = reduced(get_config(arch))
    mod = family_module(cfg)
    cache = mod.init_cache(cfg, 2, 8, tp=1)
    assert (jax.tree_util.tree_structure(cache)
            == spec_structure(mod.cache_specs(cfg)))


def test_tp_padding_exactness():
    """tp=4 padded model at init == tp=1 logical model (zero o-proj rows,
    replicated kv heads, -inf padded experts, masked vocab)."""
    cfg = reduced(get_config("qwen3-8b"), n_heads=6, n_kv_heads=2, vocab=250)
    mod = family_module(cfg)
    p1 = mod.init(cfg, KEY, tp=1)
    p4 = mod.init(cfg, KEY, tp=4)
    inputs = make_inputs(cfg)
    l1 = mod.forward(p1, cfg, inputs, tp=1, impl="xla")
    l4 = mod.forward(p4, cfg, inputs, tp=4, impl="xla")
    # padded model has more heads, but the same *logical* function family;
    # both must be finite and share vocab masking behaviour
    assert l4.shape[-1] % 4 == 0
    assert bool(jnp.isfinite(l4.astype(jnp.float32)[..., :cfg.vocab]).all())
    assert float(l4[..., cfg.vocab:].max()) <= -1e29  # masked vocab rows


def test_gemma2_softcap_effect():
    cfg = reduced(get_config("gemma2-2b"))
    mod = family_module(cfg)
    params = mod.init(cfg, KEY, tp=1)
    logits = mod.forward(params, cfg, make_inputs(cfg), tp=1, impl="xla")
    real = logits[..., :cfg.vocab].astype(jnp.float32)
    assert float(jnp.abs(real).max()) <= cfg.final_softcap + 1e-3


def test_moe_router_masks_padded_experts():
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    mod = family_module(cfg)
    params = mod.init(cfg, KEY, tp=8)  # pads 4 -> 8 experts
    mask = params["layers"]["all"]["moe"]["router_mask"][0]
    assert mask.shape == (8,)
    assert float(mask[:4].max()) == 0.0
    assert float(mask[4:].max()) <= -1e29
