"""Two-step matching: unit tests against the paper's worked examples and
hypothesis property tests on matching invariants."""
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import intrinsics as I
from repro.core import workloads as W
from repro.core.matching import legal_leaf_subsets, match, partition_space
from repro.core.tst import lca_kind, leaves, parse


def test_parse_gemm_structure():
    gm = W.gemm(64, 64, 64)
    ls = leaves(gm.body)
    assert [l.index for l in ls] == ["i", "k", "k", "j"]
    assert gm.reduced == {"k"}
    assert gm.flops() == 2 * 64 ** 3


def test_parse_conv_affine_dims():
    conv = W.conv2d(8, 8, 8, 8)
    ls = leaves(conv.body)
    assert len(ls) == 9  # paper: nine leaf nodes
    # y and s share an affine node; y and c share only the access node
    y = next(l for l in ls if l.index == "y")
    s = next(l for l in ls if l.index == "s" and l.tensor == "A")
    c = next(l for l in ls if l.index == "c" and l.tensor == "A")
    assert lca_kind(conv.body, y.path, s.path) == "affine"
    assert lca_kind(conv.body, y.path, c.path) == "access"


def test_gemm_on_conv_choices():
    """Paper §IV-B: the matcher must reject the affine-conflicting subsets
    ((x,r) and (y,s) pairs — the paper's own illegality example) and keep
    the k/{x,y}/{c,r,s} family."""
    conv = W.conv2d(64, 64, 56, 56)
    subsets = legal_leaf_subsets(I.GEMM, conv)
    assert len(subsets) == 4
    choices = match(I.GEMM, conv)
    assert len(choices) == 8  # straight + transposed orientation each
    for ch in choices:
        m = dict(ch.index_map)
        assert m["k"] in {"c", "r", "s"}          # reduced -> reduced
        assert {m["i"], m["j"]} <= {"x", "y", "k"}
        assert ch.accumulation                     # r/s/c stay in software


def test_gemv_on_gemm_matches_fig4():
    gm = W.gemm(32, 32, 32)
    choices = match(I.GEMV, gm)
    maps = {tuple(sorted(c.index_map)) for c in choices}
    # choice #1 (columns of N) and choice #3 (rows of M, transposed)
    assert (("i", "i"), ("j", "k")) in maps
    assert (("i", "j"), ("j", "k")) in maps
    # choice #2 (rows of N as vectors) is illegal: j would need to map to
    # both k and j -> rejected by index matching
    assert all(dict(c.index_map)["j"] == "k" for c in choices)


def test_dot_matches_everything_reduced():
    for w in (W.gemm(16, 16, 16), W.ttm(8, 8, 8, 8), W.conv2d(4, 4, 6, 6)):
        assert match(I.DOT, w), w.name


def test_gemm_on_mttkrp_requires_stages():
    """Paper §VII-B: GEMM cannot tile monolithic MTTKRP; stage 1 of the
    two-stage rewrite can be GEMM-accelerated; GEMV benefits both."""
    mt = W.mttkrp(32, 32, 32, 16)
    assert match(I.GEMM, mt) == []
    s1, s2 = W.mttkrp_stages(32, 32, 32, 16)
    assert match(I.GEMM, s1)
    assert match(I.GEMV, mt)
    assert match(I.GEMV, s1) and match(I.GEMV, s2)


def test_conv2d_intrinsic_identity_match():
    conv = W.conv2d(64, 64, 56, 56)
    choices = match(I.CONV2D, conv)
    assert any(dict(c.index_map) ==
               {"k": "k", "x": "x", "y": "y", "c": "c", "r": "r", "s": "s"}
               for c in choices)


def test_occurrence_count_rule():
    """An intrinsic index occurring once cannot map to a compute index
    occurring twice (the unmapped occurrence would vary inside the call)."""
    conv = W.conv2d(8, 8, 8, 8)
    for ch in match(I.GEMM, conv):
        m = dict(ch.index_map)
        assert m["i"] not in {"c", "r", "s"}
        assert m["j"] not in {"c", "r", "s"}


def test_partition_space_covers_table1():
    intr = [I.GEMM, I.GEMV, I.DOT]
    wl = [W.gemm(32, 32, 32), W.ttm(8, 8, 8, 8), W.conv2d(4, 4, 6, 6)]
    space = partition_space(intr, wl)
    assert all((w.name, "DOT") in space for w in wl)
    assert (wl[0].name, "GEMM") in space


# ---------------------------------------------------------------------------
# hypothesis: invariants over random einsum-like workloads
# ---------------------------------------------------------------------------

_IDX = "abcdefg"


@st.composite
def random_workload(draw):
    n_idx = draw(st.integers(3, 5))
    idx = list(_IDX[:n_idx])
    n_out = draw(st.integers(1, n_idx - 1))
    out = idx[:n_out]
    t1 = draw(st.lists(st.sampled_from(idx), min_size=2, max_size=3,
                       unique=True))
    t2 = draw(st.lists(st.sampled_from(idx), min_size=2, max_size=3,
                       unique=True))
    used = set(t1) | set(t2)
    out = [i for i in out if i in used] or [sorted(used)[0]]
    notation = (f"O[{','.join(out)}] = A[{','.join(t1)}] * B[{','.join(t2)}]")
    extents = {i: 8 for i in used}
    return parse(notation, extents, name="rand")


@given(random_workload())
@settings(max_examples=40, deadline=None)
def test_matching_invariants(wl):
    for intr in (I.GEMV, I.GEMM, I.DOT):
        for ch in match(intr, wl):
            m = dict(ch.index_map)
            # injective index map
            assert len(set(m.values())) == len(m)
            # software loops are exactly the unmapped indices
            assert set(ch.software_loops) == set(wl.all_indices()) - set(
                m.values())
            # intrinsic-reduced -> compute-reduced
            for q, c in m.items():
                if q in intr.reduced:
                    assert c in wl.reduced
            # occurrence counts agree
            q_occ = {l.index: 0 for l in leaves(intr.body)}
            for l in leaves(intr.body):
                q_occ[l.index] += 1
            c_occ = {}
            for l in leaves(wl.body):
                c_occ[l.index] = c_occ.get(l.index, 0) + 1
            for q, c in m.items():
                assert q_occ[q] == c_occ[c]
