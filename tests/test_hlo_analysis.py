"""HLO text parser: dots, collectives, while-trip rollup — on a synthetic
module with known ground truth."""
import pytest

from repro.launch.hlo_analysis import analyze, parse_computations

HLO = """\
HloModule test

%inner_body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %lhs = f32[8,32]{1,0} constant(0)
  %rhs = f32[32,16]{1,0} constant(0)
  %dot.1 = f32[8,16]{1,0} dot(%lhs, %rhs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[16,16]<=[256]
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ar, %ar)
}

%inner_cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]{1,0}) parameter(0)
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%c, %c), direction=LT
}

ENTRY %main (a: f32[8,32]) -> f32[8,16] {
  %a = f32[8,32]{1,0} parameter(0)
  %w = f32[32,16]{1,0} constant(0)
  %dot.0 = f32[8,16]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %init = (s32[], f32[8,16]{1,0}) tuple-whatever()
  %wh = (s32[], f32[8,16]{1,0}) while(%init), condition=%inner_cond, body=%inner_body, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[8,64]{1,0} all-gather(%dot.0), channel_id=2, replica_groups=[64,4]<=[256], dimensions={1}
  ROOT %r = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""

DOT_FLOPS = 2 * 8 * 16 * 32          # one dot, both inside and outside


def test_parse_finds_computations():
    comps = parse_computations(HLO)
    assert {"inner_body", "inner_cond", "main"} <= set(comps)
    assert comps["inner_body"].dot_flops == DOT_FLOPS
    assert comps["main"].dot_flops == DOT_FLOPS


def test_while_trip_multiplication():
    rep = analyze(HLO)
    # entry dot + 7 x body dot
    assert rep.dot_flops == DOT_FLOPS * (1 + 7)


def test_collective_bytes_and_groups():
    rep = analyze(HLO)
    ar = 8 * 16 * 4            # f32[8,16] bytes
    ag = 8 * 64 * 4
    assert rep.collective_bytes["all-reduce"] == pytest.approx(7 * ar)
    assert rep.collective_bytes["all-gather"] == pytest.approx(ag)
    assert rep.group_sizes["all-reduce"] == 16
    assert rep.group_sizes["all-gather"] == 4
    assert rep.n_collectives["all-reduce"] == 7


def test_wire_bytes_ring_model():
    rep = analyze(HLO)
    ar = 7 * 8 * 16 * 4
    ag = 8 * 64 * 4
    want = 2 * ar * 15 / 16 + ag * 3 / 4
    assert rep.wire_bytes() == pytest.approx(want)
