"""Paged-KV aliasing sanitizer (repro.analysis.kv_sanitizer, DESIGN.md §16.5).

  * seeded corruptions — every invariant the sanitizer models is broken
    explicitly (double-mapped row, leaked page, −1 wrap hazard, free∧held,
    foreign pages/rows, range violations) and must fire its exact rule id;
  * randomized trace replay — valid alloc/map/release/resume interleavings
    through :class:`TraceChecker` stay clean (deterministic tier always
    runs; hypothesis widens the seed space on CI, mirroring
    test_paged_kv.py);
  * live engine integration — a paged serve run under ``sanitize=True``
    completes with the per-tick assertion armed, and corrupting the live
    engine's page table makes the next tick raise :class:`PagedStateError`
    with the right rule.
"""
import numpy as np
import pytest

from repro.analysis import kv_sanitizer as kv
from repro.analysis.findings import errors

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover - CI has it
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# seeded corruptions against a known-good snapshot
# ---------------------------------------------------------------------------

def _state():
    """A valid 2-slot snapshot: slot0 holds pages {0,1} with rows 0..3
    mapped at pos 3; slot1 holds page {2} with rows 4,5 at pos 2; page 3
    free."""
    return dict(
        row_map=np.array([[0, 1, 2, 3], [4, 5, -1, -1]], np.int32),
        pos=np.array([3, 2]),
        pages=[[0, 1], [2]],
        n_pages=4, page_size=2,
        free_pages={3}, held_pages={0, 1, 2}, max_seq=4)


def _check(**over):
    s = _state()
    s.update(over)
    return kv.check_paged_state(
        s["row_map"], s["pos"], s["pages"], n_pages=s["n_pages"],
        page_size=s["page_size"], free_pages=s["free_pages"],
        held_pages=s["held_pages"], max_seq=s["max_seq"])


def _rules(findings):
    return {f.rule for f in findings}


def test_valid_state_is_clean():
    assert _check() == []


def test_double_mapped_row():
    rm = _state()["row_map"]
    rm[1, 0] = 1          # slot1 claims slot0's physical row 1
    got = _check(row_map=rm)
    assert "kv/row-double-owned" in _rules(got)


def test_leaked_page():
    got = _check(free_pages=set(), held_pages={0, 1, 2})
    assert _rules(got) == {"kv/page-leak"}
    assert any("page 3" in f.detail for f in got)


def test_negative_row_wrap_hazard():
    rm = _state()["row_map"]
    rm[0, 1] = -2         # would WRAP under scatter mode='drop'
    got = _check(row_map=rm)
    assert "kv/negative-row" in _rules(got)


def test_unmapped_row_below_write_position():
    rm = _state()["row_map"]
    rm[0, 1] = -1         # pos is 3: attention would read garbage at 1
    got = _check(row_map=rm)
    assert _rules(got) == {"kv/row-unmapped-live"}


def test_page_free_and_held():
    got = _check(free_pages={1, 3})
    assert _rules(got) == {"kv/page-free-and-held"}


def test_foreign_page():
    got = _check(held_pages={0, 1})   # allocator forgot slot1's page 2
    assert _rules(got) == {"kv/page-foreign"}


def test_row_out_of_range():
    rm = _state()["row_map"]
    rm[0, 0] = 8          # pool is 4 pages x 2 rows = 8 rows (0..7)
    got = _check(row_map=rm)
    assert "kv/row-out-of-range" in _rules(got)


def test_row_on_unheld_page():
    rm = _state()["row_map"]
    rm[1, 1] = 6          # row 6 lies on free page 3
    got = _check(row_map=rm)
    assert _rules(got) == {"kv/row-not-owned"}


def test_page_double_owned():
    got = _check(pages=[[0, 1], [1]], held_pages={0, 1},
                 free_pages={2, 3})
    assert "kv/page-double-owned" in _rules(got)


def test_pos_out_of_range():
    got = _check(pos=np.array([5, 2]))
    assert "kv/pos-out-of-range" in _rules(got)


def test_paged_state_error_message():
    rm = _state()["row_map"]
    rm[0, 1] = -2
    bad = errors(_check(row_map=rm))
    err = kv.PagedStateError(bad)
    assert err.findings == bad and "kv/negative-row" in str(err)


# ---------------------------------------------------------------------------
# trace replay: randomized valid traces stay clean
# ---------------------------------------------------------------------------

def _random_trace(rng, n_ops=120):
    """Generate a valid op sequence by simulating it on a scratch checker
    (asserting every intermediate snapshot is clean)."""
    tc = kv.TraceChecker(n_pages=8, page_size=2, slots=3, max_seq=6)
    ops = []
    for _ in range(n_ops):
        s = int(rng.integers(tc.slots))
        free = sorted(tc._free)
        if tc._pages[s] and (rng.random() < 0.35 or not free):
            op = {"op": "suspend" if rng.random() < 0.5 else "release",
                  "slot": s}
        elif free:
            k = int(rng.integers(1, min(len(free), 3) + 1))
            pages = [int(p) for p in rng.choice(free, size=k, replace=False)]
            if tc._pages[s]:
                op = {"op": "alloc", "slot": s, "pages": pages}
            else:
                op = {"op": "resume", "slot": s, "pages": pages,
                      "rows": int(rng.integers(0, k * tc.page_size + 1))}
        else:                                       # pragma: no cover
            continue
        ops.append(op)
        assert tc.apply(dict(op)) == [], f"generator produced a bad op {op}"
        if op["op"] in ("alloc", "resume") or tc._pages[s]:
            rows = int(rng.integers(0, tc._capacity(s) + 1))
            mop = {"op": "map", "slot": s, "rows": rows}
            ops.append(mop)
            assert tc.apply(dict(mop)) == []
    return ops


def _replay_clean(seed):
    ops = _random_trace(np.random.default_rng(seed))
    fresh = kv.TraceChecker(n_pages=8, page_size=2, slots=3, max_seq=6)
    assert fresh.check_trace(ops) == []


@pytest.mark.parametrize("seed", range(5))
def test_trace_checker_random_clean(seed):
    _replay_clean(seed)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_trace_checker_random_clean_hypothesis(seed):
        _replay_clean(seed)


def test_trace_checker_catches_double_alloc():
    tc = kv.TraceChecker(n_pages=4, page_size=2, slots=2, max_seq=4)
    ops = [{"op": "alloc", "slot": 0, "pages": [0, 1]},
           {"op": "map", "slot": 0, "rows": 3},
           {"op": "alloc", "slot": 1, "pages": [1]},     # page 1 stolen
           {"op": "map", "slot": 1, "rows": 1}]
    got = tc.check_trace(ops)
    bad = errors(got)
    assert bad and bad[0].rule == "kv/page-double-owned"
    assert bad[0].site.startswith("trace[2]:alloc")
    # replay stops at the first corrupting op: op 3 is never reached
    assert not any(f.site.startswith("trace[3]") for f in got)


def test_trace_checker_rejects_unknown_op():
    tc = kv.TraceChecker(n_pages=2, page_size=2, slots=1, max_seq=2)
    with pytest.raises(ValueError):
        tc.apply({"op": "warp", "slot": 0})


# ---------------------------------------------------------------------------
# live engine integration (sanitize=True debug mode)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def paged_setup():
    import jax

    from repro.configs import get_config
    from repro.launch.serve import PagedServeEngine, Request
    from repro.models import family_module, reduced

    cfg = reduced(get_config("qwen3-8b"))
    params = family_module(cfg).init(cfg, jax.random.PRNGKey(0), 1)
    return cfg, params, PagedServeEngine, Request


def _engine(paged_setup, **kw):
    cfg, params, PagedServeEngine, Request = paged_setup
    kw.setdefault("slots", 2)
    kw.setdefault("max_seq", 16)
    kw.setdefault("page_size", 4)
    kw.setdefault("n_pages", 8)
    eng = PagedServeEngine(cfg, params, sanitize=True, **kw)
    return eng, Request


def test_engine_sanitized_run_completes(paged_setup):
    eng, Request = _engine(paged_setup)
    done = []
    for i in range(4):
        eng.submit(Request(i, [1, 2, 3], 4))
    ticks = 0
    while eng.scheduler.has_work() and ticks < 200:
        done.extend(eng.step())   # asserts the paged state every tick
        ticks += 1
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]


def test_engine_corruption_trips_next_tick(paged_setup):
    eng, Request = _engine(paged_setup)
    eng.submit(Request(0, [1, 2, 3], 8))
    eng.step()
    live = int(np.argmax(eng.pos < eng.max_seq))
    eng.row_map[live, 0] = -2                     # seed the wrap hazard
    with pytest.raises(kv.PagedStateError) as ei:
        for _ in range(4):
            eng.step()
    assert any(f.rule == "kv/negative-row" for f in ei.value.findings)
