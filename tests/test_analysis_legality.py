"""Static legality verifier (repro.analysis.legality, DESIGN.md §16.2).

Three layers of assurance:

  * seeded-defect coverage — every defect class the verifier claims to
    catch is constructed explicitly (vmem overflow, intrinsic mismatch,
    design-space-illegal hardware, semantically broken tensorize choices)
    and must fire the *right* rule id;
  * the zero-false-positive contract — on space-legal hardware populations
    with sound matched choices, error-severity findings must agree exactly
    with ``cost_model.evaluate(...).legal`` (the verifier mirrors the
    reference evaluator's working-set formula line for line);
  * the shipped surfaces — the golden codesign snapshot verifies clean and
    the ``python -m repro.analysis`` CLI exits 0 over a shipped config,
    writing the findings JSON artifact the CI gate uploads.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.analysis import Finding, errors, rule, summarize
from repro.analysis.legality import is_legal, verify_candidate, verify_hw
from repro.core import workloads as W
from repro.core.cost_model import evaluate
from repro.core.hw_primitives import HWConfig
from repro.core.hw_space import HWSpace
from repro.core.intrinsics import ALL_INTRINSICS, GEMM
from repro.core.matching import match
from repro.core.sw_primitives import Schedule


@pytest.fixture
def gemm64():
    wl = W.gemm(64, 64, 64, name="g64")
    return wl, match(GEMM, wl)[0]


def _hw(rows=16, cols=16, depth=16, **kw):
    kw.setdefault("vmem_kib", 2048)
    return HWConfig(intrinsic="GEMM", pe_rows=rows, pe_cols=cols,
                    pe_depth=depth, **kw)


def _sched(wl, choice, tile):
    tiles = tuple(sorted((c, tile) for c in choice.mapped_compute_indices))
    return Schedule(choice, tiles, tuple(wl.all_indices()), 0)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# findings schema
# ---------------------------------------------------------------------------

def test_finding_schema():
    f = Finding("error", "legality/vmem-overflow", "site", "boom")
    assert f.to_dict() == {"severity": "error",
                           "rule": "legality/vmem-overflow",
                           "site": "site", "detail": "boom"}
    assert "legality/vmem-overflow" in str(f)
    with pytest.raises(ValueError):
        Finding("fatal", "legality/vmem-overflow", "s", "d")
    with pytest.raises(ValueError):
        rule("no-family-slug", "rule ids are namespaced")
    s = summarize([f])
    assert s["error"] == 1 and s["warning"] == 0


# ---------------------------------------------------------------------------
# seeded defects: each class fires its rule
# ---------------------------------------------------------------------------

def test_clean_candidate_has_no_findings(gemm64):
    wl, choice = gemm64
    got = verify_candidate(wl, _sched(wl, choice, 32), _hw())
    assert errors(got) == [] and is_legal(wl, _sched(wl, choice, 32), _hw())
    # tile 32 on 16-blocks: aligned, in-range knobs -> not even warnings
    assert got == []


def test_vmem_overflow_matches_cost_model(gemm64):
    wl, choice = gemm64
    hw = _hw(vmem_kib=16)         # 16 KiB scratchpad
    bad = _sched(wl, choice, 64)  # 49152 B working set
    got = errors(verify_candidate(wl, bad, hw))
    assert _rules(got) == {"legality/vmem-overflow"}
    assert not evaluate(wl, bad, hw).legal
    ok = _sched(wl, choice, 16)   # 3072 B: fits
    assert is_legal(wl, ok, hw) and evaluate(wl, ok, hw).legal


def test_intrinsic_mismatch(gemm64):
    wl, choice = gemm64
    hw = HWConfig(intrinsic="GEMV", pe_rows=16, pe_cols=16, pe_depth=16,
                  vmem_kib=2048)
    got = errors(verify_candidate(wl, _sched(wl, choice, 32), hw))
    assert "legality/intrinsic-mismatch" in _rules(got)
    assert not evaluate(wl, _sched(wl, choice, 32), hw).legal


def test_unknown_intrinsic():
    hw = HWConfig(intrinsic="FANCY", pe_rows=16, pe_cols=16, pe_depth=16)
    assert _rules(errors(verify_hw(hw))) == {"legality/unknown-intrinsic"}


def test_workload_mismatch(gemm64):
    wl, choice = gemm64
    other = W.gemm(32, 32, 32, name="other")
    got = errors(verify_candidate(other, _sched(other, choice, 16), _hw()))
    assert "legality/choice-workload-mismatch" in _rules(got)


def test_broken_choice_accumulation_flag(gemm64):
    wl, choice = gemm64
    bad = dataclasses.replace(choice, accumulation=not choice.accumulation)
    got = errors(verify_candidate(wl, _sched(wl, bad, 32), _hw()))
    assert "legality/accumulation-flag" in _rules(got)


def test_broken_choice_reduction_unsound(gemm64):
    wl, choice = gemm64
    intr_reduced = ALL_INTRINSICS["GEMM"].reduced
    im = dict(choice.index_map)
    red_q = next(q for q in im if q in intr_reduced)
    im[red_q] = next(c for c in wl.all_indices() if c not in wl.reduced)
    bad = dataclasses.replace(choice, index_map=tuple(im.items()))
    got = errors(verify_candidate(wl, _sched(wl, bad, 32), _hw()))
    assert "legality/reduction-unsound" in _rules(got)


def test_hw_space_illegal_points():
    # PE-local accumulator eats more than a quarter of VMEM
    got = errors(verify_hw(_hw(vmem_kib=128, local_accum_kib=1024)))
    assert _rules(got) == {"legality/local-accum-oversized"}
    # one minimal (double-buffered) intrinsic tile cannot fit its own VMEM
    got = errors(verify_hw(_hw(rows=512, cols=512, depth=512, vmem_kib=128)))
    assert _rules(got) == {"legality/min-tile-overflow"}


def test_misaligned_tile_warns_but_stays_legal(gemm64):
    wl, choice = gemm64
    got = verify_candidate(wl, _sched(wl, choice, 24), _hw())
    assert errors(got) == []
    assert "legality/tile-misaligned" in _rules(got)


# ---------------------------------------------------------------------------
# zero-false-positive contract: static == dynamic on random populations
# ---------------------------------------------------------------------------

def test_random_population_agrees_with_cost_model():
    wl = W.gemm(96, 80, 72, name="gp")
    choice = match(GEMM, wl)[0]
    rng = np.random.default_rng(0)
    checked = disagree = 0
    for hw in HWSpace("GEMM").sample(rng, 20):
        for tile in (8, 16, 48, 96):
            sched = _sched(wl, choice, tile)
            static = bool(errors(verify_candidate(wl, sched, hw)))
            dynamic = not evaluate(wl, sched, hw).legal
            checked += 1
            disagree += static != dynamic
    assert checked == 80 and disagree == 0


# ---------------------------------------------------------------------------
# shipped surfaces: golden snapshot + CLI gate
# ---------------------------------------------------------------------------

def test_golden_codesign_schedule_verifies_clean():
    from repro.analysis.__main__ import GOLDEN_DEFAULT, golden_findings
    assert GOLDEN_DEFAULT.exists()
    got = golden_findings(GOLDEN_DEFAULT)
    assert errors(got) == []
    assert len(got) >= 1     # padding observations are expected warnings


def test_cli_lints_shipped_config(tmp_path, capsys):
    from repro.analysis.__main__ import main
    out = tmp_path / "findings.json"
    rc = main(["--arch", "gemma2-2b", "--mesh", "none",
               "--mesh", "data=2,model=4", "--json", str(out)])
    assert rc == 0
    snap = json.loads(out.read_text())
    assert snap["errors"] == 0
    assert {"summary", "errors", "findings"} <= set(snap)
    assert "gemma2-2b" in capsys.readouterr().out


def test_cli_rules_catalog(capsys):
    from repro.analysis.__main__ import main
    assert main(["--rules"]) == 0
    text = capsys.readouterr().out
    for rid in ("legality/vmem-overflow", "sharding/indivisible-dim",
                "kv/row-double-owned", "jaxpr/host-callback"):
        assert rid in text


def test_cli_mesh_parsing():
    from repro.analysis.__main__ import parse_mesh
    assert parse_mesh("none") is None
    assert parse_mesh("data=2,model=4") == {"data": 2, "model": 4}
    with pytest.raises(SystemExit):
        parse_mesh("data=two")
