"""q-batch MOBO acquisition (DESIGN.md §9) + the shared_reference fix.

No hypothesis dependency — these run everywhere.
"""
import math

import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.codesign import Constraints, codesign
from repro.core.hw_space import HWSpace
from repro.core.mobo import (DSEResult, mobo, rescore_hv_history,
                             shared_reference)


def _toy(hw):
    """Synthetic 3-objective surface over the hardware space."""
    n = hw.pe_rows * hw.pe_cols
    return (1.0 / n + hw.burst_bytes * 1e-9,
            n * 1e-3 + hw.vmem_kib * 1e-4,
            n * 10.0 + hw.vmem_kib * 5.0)


@pytest.mark.parametrize("seed", [0, 1])
def test_q1_reproduces_reference_acquisition(seed):
    """Same seed, q=1: the vectorized engine must pick the exact same config
    sequence as the pre-engine per-candidate loops, with matching
    hypervolume histories."""
    space = HWSpace("GEMM")
    res_v = mobo(space, _toy, n_init=5, n_trials=12, seed=seed)
    res_r = mobo(space, _toy, n_init=5, n_trials=12, seed=seed,
                 acquisition="reference")
    assert ([c.encode() for c in res_v.configs]
            == [c.encode() for c in res_r.configs])
    np.testing.assert_allclose(res_v.hv_history, res_r.hv_history,
                               rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("seed", [2, 3])
def test_qbatch_never_duplicates_and_keeps_hv_at_equal_budget(seed):
    """Fixed toy space, equal 21-evaluation budget: q=4 must evaluate 21
    distinct configs and end at a hypervolume >= the q=1 run's."""
    space = HWSpace("GEMM")
    res1 = mobo(space, _toy, n_init=5, n_trials=21, seed=seed)
    res4 = mobo(space, _toy, n_init=5, n_trials=21, seed=seed, q=4)
    enc = [c.encode() for c in res4.configs]
    assert len(enc) == len(set(enc))
    assert res4.evaluations == 21 == len(res4.hv_history)
    assert res4.hv_history[-1] >= res1.hv_history[-1] - 1e-12


def test_qbatch_respects_trial_budget_midbatch():
    """The last round is clipped so q-batches never overshoot n_trials."""
    space = HWSpace("GEMM")
    res = mobo(space, _toy, n_init=4, n_trials=10, seed=0, q=4)
    assert res.evaluations == 10 and len(res.configs) == 10


def test_acquisition_engine_validation():
    space = HWSpace("GEMM")
    with pytest.raises(ValueError):
        mobo(space, _toy, acquisition="nope")
    with pytest.raises(ValueError):
        mobo(space, _toy, acquisition="reference", q=2)


def test_shared_reference_all_infeasible_returns_finite():
    ys = np.full((3, 3), math.inf)
    res = DSEResult([], ys, [0.0] * 3, 3, np.ones(3))
    ref = shared_reference([res, res])
    assert ref.shape == (3,) and np.all(np.isfinite(ref))
    assert rescore_hv_history(res, ref) == [0.0, 0.0, 0.0]
    assert np.all(np.isfinite(shared_reference([])))


def test_codesign_threads_q_through_hw_dse():
    wl = [W.gemm(128, 128, 128, name="g")]
    rep = codesign(wl, intrinsics=["GEMM"], n_trials=6, n_init=3, seed=0,
                   q=3)
    assert rep.solution is not None
    assert rep.per_intrinsic["GEMM"].evaluations == 6


def test_codesign_constraint_driven_extension():
    """Unsatisfiable constraints + max_dse_extensions: the hardware DSE is
    re-run at a doubled trial budget before giving up."""
    wl = [W.gemm(128, 128, 128, name="g")]
    rep = codesign(wl, intrinsics=["GEMM"], n_trials=3, n_init=2, seed=1,
                   constraints=Constraints(latency_s=1e-30),
                   max_dse_extensions=1, q=2)
    assert rep.solution is None
    assert rep.per_intrinsic["GEMM"].evaluations == 6   # 3 * 2**1
