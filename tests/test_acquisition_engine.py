"""Vectorized Pareto/hypervolume engine (DESIGN.md §9) vs the reference
scalar implementations, on seeded random point sets.

These run everywhere; the hypothesis-driven property variants live in
``test_pareto_mobo.py`` (skipped when hypothesis is absent).
"""
import numpy as np
import pytest

from repro.core.nsga2 import _crowding, _fast_nondominated_sort
from repro.core.pareto import (BoxDecomposition, IncrementalHV,
                               _reference_hypervolume, _reference_pareto_mask,
                               default_reference, hvi_batch, hypervolume,
                               pareto_front, pareto_mask)


def _random_sets(d, n_sets=25, seed=0):
    """Random point clouds in [0, 10]^d, some with duplicated rows."""
    rng = np.random.default_rng(seed + 97 * d)
    for t in range(n_sets):
        n = int(rng.integers(1, 40))
        pts = rng.uniform(0, 10, (n, d))
        if t % 3 == 0 and n > 1:  # duplicates exercise the tie handling
            pts[int(rng.integers(n))] = pts[int(rng.integers(n))]
        yield pts


@pytest.mark.parametrize("d", [1, 2, 3, 4])
def test_pareto_mask_matches_reference_exactly(d):
    for pts in _random_sets(d):
        assert np.array_equal(pareto_mask(pts), _reference_pareto_mask(pts))


@pytest.mark.parametrize("d", [1, 2, 3, 4])
def test_hypervolume_matches_reference(d):
    ref = np.full(d, 11.0)
    for pts in _random_sets(d):
        hv = hypervolume(pts, ref)
        hv_ref = _reference_hypervolume(pts, ref)
        assert hv == pytest.approx(hv_ref, rel=1e-9, abs=1e-9)


@pytest.mark.parametrize("d", [1, 2, 3])
def test_hvi_batch_equals_full_recompute_deltas(d):
    rng = np.random.default_rng(5 + d)
    ref = np.full(d, 11.0)
    for _ in range(15):
        front = rng.uniform(0, 10, (int(rng.integers(0, 25)), d))
        cands = rng.uniform(-2, 12, (16, d))  # some beyond ref / below front
        hvi = hvi_batch(front, ref, cands)
        hv0 = hypervolume(front, ref)
        deltas = [hypervolume(np.vstack([front, c[None]]), ref) - hv0
                  for c in cands]
        np.testing.assert_allclose(hvi, deltas, atol=1e-9)


def test_hvi_batch_mc_consistent_beyond_3d():
    """d > 3 falls back to Monte Carlo: deltas agree within sampling noise."""
    rng = np.random.default_rng(11)
    ref = np.full(4, 11.0)
    front = rng.uniform(0, 10, (12, 4))
    cands = rng.uniform(0, 10, (8, 4))
    hvi = hvi_batch(front, ref, cands, mc_samples=200_000)
    hv0 = hypervolume(front, ref)
    deltas = np.array([hypervolume(np.vstack([front, c[None]]), ref) - hv0
                       for c in cands])
    scale = max(np.abs(deltas).max(), 1e-9)
    assert np.abs(hvi - deltas).max() / scale < 0.05


def test_hvi_nonfinite_candidates_contribute_nothing():
    front = np.array([[1.0, 2.0], [2.0, 1.0]])
    ref = np.array([4.0, 4.0])
    cands = np.array([[np.inf, 0.0], [np.nan, 0.0], [0.5, 0.5]])
    hvi = hvi_batch(front, ref, cands)
    assert hvi[0] == 0.0 and hvi[1] == 0.0 and hvi[2] > 0.0


@pytest.mark.parametrize("d", [1, 2, 3])
def test_incremental_hv_matches_prefix_recompute(d):
    rng = np.random.default_rng(3 + d)
    ref = np.full(d, 11.0)
    pts = rng.uniform(0, 10, (30, d))
    tracker = IncrementalHV(ref)
    for i, y in enumerate(pts):
        tracker.add(y)
        assert tracker.hv == pytest.approx(hypervolume(pts[: i + 1], ref),
                                           rel=1e-9, abs=1e-9)
    # the maintained front is the Pareto front of everything seen
    np.testing.assert_allclose(np.sort(tracker.front, axis=0),
                               np.sort(pareto_front(pts), axis=0))


def test_incremental_hv_ignores_points_beyond_ref():
    tracker = IncrementalHV(np.array([1.0, 1.0]))
    tracker.add(np.array([0.5, 0.5]))
    hv = tracker.hv
    tracker.add(np.array([2.0, 0.1]))      # exceeds ref in dim 0
    tracker.add(np.array([np.inf, 0.0]))   # infeasible
    assert tracker.hv == hv and len(tracker.front) == 1


def test_box_decomposition_partitions_whole_region():
    """Σ box volumes (clipped to the bounding cell) + front hypervolume must
    equal the cell volume: the boxes tile the non-dominated region."""
    rng = np.random.default_rng(2)
    for d in (2, 3):
        pts = rng.uniform(0, 10, (20, d))
        ref = np.full(d, 11.0)
        front = pareto_front(pts)
        lo_f = front.min(axis=0)
        dec = BoxDecomposition(front, ref)
        clipped = np.clip(dec._hi - np.maximum(dec._lo, lo_f), 0, None)
        complement = clipped.prod(axis=1).sum()
        cell = np.prod(ref - lo_f)
        assert complement + hypervolume(front, ref) == pytest.approx(
            cell, rel=1e-9)


# ---------------------------------------------------------------------------
# NSGA-II vectorized sort / crowding vs brute force
# ---------------------------------------------------------------------------

def _bruteforce_ranks(ys):
    n = len(ys)
    dom = [[bool(np.all(ys[p] <= ys[q]) and np.any(ys[p] < ys[q]))
            for q in range(n)] for p in range(n)]
    rank = [-1] * n
    r = 0
    while -1 in rank:
        this = [q for q in range(n) if rank[q] == -1 and
                not any(dom[p][q] and rank[p] == -1 for p in range(n))]
        for q in this:
            rank[q] = r
        r += 1
    return rank


def test_nd_sort_matches_bruteforce():
    rng = np.random.default_rng(0)
    for _ in range(10):
        n = int(rng.integers(2, 30))
        ys = rng.uniform(0, 1, (n, 3))
        ys[rng.integers(n)] = ys[rng.integers(n)]  # duplicate row
        fronts = _fast_nondominated_sort(ys)
        want = _bruteforce_ranks(ys)
        got = [-1] * n
        for r, f in enumerate(fronts):
            for i in f:
                got[i] = r
        assert got == want


def test_crowding_matches_reference_loop():
    rng = np.random.default_rng(1)
    for _ in range(10):
        n = int(rng.integers(3, 20))
        ys = rng.uniform(0, 1, (n, 3))
        front = list(range(n))
        got = _crowding(ys, front)
        # the pre-vectorization per-objective loop
        want = {i: 0.0 for i in front}
        arr = ys[front]
        for m in range(ys.shape[1]):
            order = np.argsort(arr[:, m])
            span = arr[order[-1], m] - arr[order[0], m] or 1.0
            want[front[order[0]]] = np.inf
            want[front[order[-1]]] = np.inf
            for k in range(1, n - 1):
                if not np.isinf(want[front[order[k]]]):
                    want[front[order[k]]] += (arr[order[k + 1], m]
                                              - arr[order[k - 1], m]) / span
        for i in front:
            assert got[i] == pytest.approx(want[i])


def test_default_reference_unchanged():
    pts = np.array([[1.0, 5.0], [3.0, 2.0]])
    ref = default_reference(pts, margin=1.1)
    assert np.all(ref > pts.max(axis=0))
