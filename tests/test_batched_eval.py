"""Batched cost-model evaluation: elementwise agreement with the scalar
reference on random (hw, schedule) populations, cache semantics, and the
explorer-facing batch APIs."""
import math

import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.cost_model import (EvalCache, _evaluate_reference, evaluate,
                                   evaluate_batch, evaluate_batch_reports)
from repro.core.hw_space import HWSpace
from repro.core.intrinsics import ALL_INTRINSICS
from repro.core.matching import match
from repro.core.sw_primitives import Schedule
from repro.core.sw_space import SoftwareSpace

REPORT_FIELDS = ("latency_s", "energy_j", "power_w", "area_um2", "flops",
                 "useful_flops", "hbm_bytes", "compute_s", "memory_s")


def _population(wl, intrinsic, n, seed, n_hw=8):
    rng = np.random.default_rng(seed)
    choices = match(ALL_INTRINSICS[intrinsic], wl)
    hws = HWSpace(intrinsic).sample(rng, n_hw)
    space = SoftwareSpace(wl, choices, hws[0], "spatial")
    schedules = [space.random_schedule(rng) for _ in range(n)]
    hw_list = [hws[int(rng.integers(len(hws)))] for _ in range(n)]
    return hw_list, schedules


def _assert_report_matches(ref, got, ctx=""):
    for f in REPORT_FIELDS:
        a, b = getattr(ref, f), getattr(got, f)
        if math.isfinite(a) or math.isfinite(b):
            assert b == pytest.approx(a, rel=1e-9), f"{ctx}: {f} {a} != {b}"
        else:
            assert a == b or (math.isinf(a) and math.isinf(b)), \
                f"{ctx}: {f} {a} != {b}"
    assert ref.legal == got.legal, ctx
    assert ref.calls == got.calls, ctx
    assert ref.vmem_bytes == got.vmem_bytes, ctx
    assert ref.why_illegal == got.why_illegal, ctx


@pytest.mark.parametrize("case", [
    ("gemm", "GEMM"), ("gemm", "GEMV"), ("gemm", "DOT"),
    ("conv", "GEMM"), ("conv", "CONV2D"), ("ttm", "GEMM"),
])
@pytest.mark.parametrize("target", ["spatial", "tpu"])
def test_batch_matches_scalar_on_random_populations(case, target):
    """Property: evaluate_batch agrees elementwise with the scalar reference
    over random schedule × random hardware populations (legal, padded,
    vmem-overflow, and intrinsic-mismatch candidates all arise here)."""
    kind, intrinsic = case
    wl = {"gemm": W.gemm(512, 256, 128),
          "conv": W.conv2d(64, 32, 28, 28),
          "ttm": W.ttm(128, 64, 64, 64)}[kind]
    if not match(ALL_INTRINSICS[intrinsic], wl):
        pytest.skip(f"no {intrinsic} choices for {wl.name}")
    hw_list, schedules = _population(wl, intrinsic, 96, seed=0)
    reports = evaluate_batch_reports(wl, hw_list, schedules, target)
    ys = evaluate_batch(wl, hw_list, schedules, target)
    for i, (s, h) in enumerate(zip(schedules, hw_list)):
        ref = _evaluate_reference(wl, s, h, target)
        _assert_report_matches(ref, reports[i], f"{kind}/{intrinsic}[{i}]")
        for j, f in enumerate(("latency_s", "power_w", "area_um2")):
            a = getattr(ref, f)
            if math.isfinite(a):
                assert ys[i, j] == pytest.approx(a, rel=1e-9)
            else:
                assert not math.isfinite(ys[i, j])


def test_batch_handles_mixed_tensorize_choices():
    """One population mixing GEMM and GEMV tensorize choices of the same
    workload on a GEMM accelerator: GEMV-choice rows are illegal (intrinsic
    mismatch), GEMM rows score normally."""
    wl = W.gemm(256, 128, 64)
    choices = match(ALL_INTRINSICS["GEMM"], wl) \
        + match(ALL_INTRINSICS["GEMV"], wl)
    assert len({c.intrinsic_name for c in choices}) == 2
    hw = HWSpace("GEMM").sample(np.random.default_rng(0), 1)[0]
    rng = np.random.default_rng(1)
    pop = []
    for c in choices[:12]:
        tiles = tuple(sorted((l, max(1, wl.extents[l] // 2))
                             for l in c.mapped_compute_indices))
        order = list(wl.all_indices())
        rng.shuffle(order)
        pop.append(Schedule(c, tiles, tuple(order), 0))
    reports = evaluate_batch_reports(wl, hw, pop, "spatial")
    for s, got in zip(pop, reports):
        ref = _evaluate_reference(wl, s, hw, "spatial")
        _assert_report_matches(ref, got, s.choice.intrinsic_name)
        if s.choice.intrinsic_name != "GEMM":
            assert not got.legal


def test_single_hw_broadcast_and_wrapper_agree():
    wl = W.gemm(128, 128, 128)
    hw_list, schedules = _population(wl, "GEMM", 32, seed=2, n_hw=1)
    hw = hw_list[0]
    ys_b = evaluate_batch(wl, hw, schedules)          # broadcast single hw
    ys_l = evaluate_batch(wl, [hw] * 32, schedules)   # explicit list
    np.testing.assert_array_equal(ys_b, ys_l)
    for i, s in enumerate(schedules):
        rep = evaluate(wl, s, hw)
        if math.isfinite(rep.latency_s):
            assert ys_b[i, 0] == pytest.approx(rep.latency_s, rel=1e-9)


def test_cache_hits_skip_recomputation():
    """A repeated population is served entirely from the cache, and the memo
    is shared between the batched and scalar entry points."""
    wl = W.gemm(256, 256, 256)
    hw_list, schedules = _population(wl, "GEMM", 64, seed=3, n_hw=4)
    cache = EvalCache()
    ys1 = evaluate_batch(wl, hw_list, schedules, cache=cache)
    assert cache.hits == 0 and cache.misses == 64
    ys2 = evaluate_batch(wl, hw_list, schedules, cache=cache)
    assert cache.hits == 64, "second pass must be all hits"
    assert cache.misses == 64, "second pass must not recompute"
    np.testing.assert_array_equal(
        np.nan_to_num(ys1, posinf=1e300), np.nan_to_num(ys2, posinf=1e300))
    # scalar evaluate() sees the batch-populated memo
    before = cache.hits
    rep = evaluate(wl, schedules[0], hw_list[0], cache=cache)
    assert cache.hits == before + 1
    assert rep.objectives[0] == ys1[0, 0] or (
        math.isinf(rep.objectives[0]) and math.isinf(ys1[0, 0]))


def test_cache_distinguishes_targets_and_hw():
    wl = W.gemm(128, 128, 128)
    hw_list, schedules = _population(wl, "GEMM", 8, seed=4, n_hw=4)
    cache = EvalCache()
    evaluate_batch(wl, hw_list, schedules, "spatial", cache=cache)
    evaluate_batch(wl, hw_list, schedules, "tpu", cache=cache)
    assert cache.hits == 0 and cache.misses == 16


def test_latency_batch_matches_scalar_latency():
    """SoftwareSpace.latency_batch (what the software DSE drives) equals the
    scalar latency() per schedule."""
    wl = W.conv2d(32, 16, 14, 14)
    choices = match(ALL_INTRINSICS["GEMM"], wl)
    hw = HWSpace("GEMM").sample(np.random.default_rng(5), 1)[0]
    space = SoftwareSpace(wl, choices, hw, "spatial", cache=EvalCache())
    rng = np.random.default_rng(6)
    pop = [space.random_schedule(rng) for _ in range(48)]
    batched = space.latency_batch(pop)
    for s, lb in zip(pop, batched):
        ls = space.latency(s)
        if math.isfinite(ls):
            assert lb == pytest.approx(ls, rel=1e-9)
        else:
            assert not math.isfinite(lb)


def test_empty_batch():
    wl = W.gemm(64, 64, 64)
    hw = HWSpace("GEMM").sample(np.random.default_rng(0), 1)[0]
    assert evaluate_batch(wl, hw, []).shape == (0, 3)
