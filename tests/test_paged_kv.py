"""Paged KV-cache conformance (DESIGN.md §12).

Three tiers, least to most integrated:

  * :class:`PageAllocator` invariants — no double allocation, alloc/free
    round-trips restore the free list, page-major row maps.  Deterministic
    versions always run; hypothesis widens them to random op sequences when
    it is installed (CI), mirroring test_qlearning_props.py.
  * page-table gather == dense-cache layout: for random interleaved
    allocation orders (with slot retirement and page reuse),
    ``gather_pages`` must reproduce the exact dense ``(B, L, ...)`` view the
    non-paged engine carries, zeros in unmapped rows.
  * teacher-forced decode oracles on the paged model path — qwen3 (pure
    pool) and gemma2 sliding-window (dense ring layers × pool global layers,
    the riskiest interaction): greedy tokens through ``decode_step`` with a
    deliberately interleaved page layout must equal batch-1 dense decode
    bit-for-bit.  Plus a scatter-isolation regression: a parked lane
    (row_map −1) must not touch the pool — negative indices WRAP under
    scatter mode="drop", which silently corrupted the last pool row.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.paging import PageAllocator
from repro.models import family_module, layers as L, reduced

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover - CI has it
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# PageAllocator invariants (deterministic tier — always runs)
# ---------------------------------------------------------------------------

def _run_ops(alloc: PageAllocator, rng, n_ops: int):
    """Random alloc/free interleaving; returns the live allocations and
    checks the no-double-allocation invariant after every op."""
    live: list[list[int]] = []
    seen: set[int] = set()
    for _ in range(n_ops):
        if live and (rng.random() < 0.4 or alloc.n_free == 0):
            pages = live.pop(int(rng.integers(len(live))))
            alloc.free(pages)
            seen.difference_update(pages)
        elif alloc.n_free:
            pages = alloc.alloc(int(rng.integers(1, alloc.n_free + 1)))
            assert not seen & set(pages), "page handed out twice"
            assert len(set(pages)) == len(pages)
            seen.update(pages)
            live.append(pages)
        assert alloc.n_free + len(seen) == alloc.n_pages
    return live


def test_allocator_never_double_allocates():
    rng = np.random.default_rng(0)
    for seed in range(8):
        _run_ops(PageAllocator(11, 3), np.random.default_rng(seed), 60)


def test_alloc_free_round_trip_restores_free_list():
    alloc = PageAllocator(9, 4)
    initial = alloc.free_pages
    rng = np.random.default_rng(7)
    live = _run_ops(alloc, rng, 40)
    for pages in live:
        alloc.free(pages)
    assert alloc.free_pages == initial


def test_allocator_rejects_bad_ops():
    alloc = PageAllocator(4, 2)
    with pytest.raises(MemoryError, match="exceeds"):
        alloc.alloc(5)
    pages = alloc.alloc(2)
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.free([3])
    alloc.free(pages)
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.free(pages)                          # double free
    with pytest.raises(ValueError):
        PageAllocator(0, 2)
    with pytest.raises(ValueError):
        alloc.alloc(-1)


def test_pages_for_and_row_layout():
    alloc = PageAllocator(8, 4)
    assert [alloc.pages_for(r) for r in (0, 1, 4, 5, 8)] == [0, 1, 1, 2, 2]
    pages = alloc.alloc(2)                          # [0, 1] (lowest first)
    assert pages == [0, 1]
    assert alloc.rows(pages, 6) == [0, 1, 2, 3, 4, 5]   # page-major
    alloc.free([pages[0]])
    other = alloc.alloc(1)
    assert other == [0]                             # lowest free reused
    with pytest.raises(ValueError, match="exceed"):
        alloc.rows([1], 5)


# ---------------------------------------------------------------------------
# page-table gather == dense layout (deterministic tier)
# ---------------------------------------------------------------------------

def _random_paged_layout(rng, n_pages=6, page_size=3, slots=3, max_seq=12):
    """Grow slots in random interleaved order, with random retirement and
    page reuse, mirroring engine bookkeeping.  Returns (pool, row_map,
    dense, used) where dense is the ground-truth per-slot layout and used
    counts each slot's written rows (rows beyond it are don't-care)."""
    alloc = PageAllocator(n_pages, page_size)
    rows_total = n_pages * page_size
    pool = np.zeros((rows_total, 2, 2), np.float32)
    dense = np.zeros((slots, max_seq, 2, 2), np.float32)
    row_map = np.full((slots, max_seq), -1, np.int32)
    pages: list[list[int]] = [[] for _ in range(slots)]
    used = np.zeros(slots, np.int32)
    stamp = 1.0
    for _ in range(60):
        s = int(rng.integers(slots))
        if rng.random() < 0.15 and pages[s]:       # retire: free + clear
            alloc.free(pages[s])
            pages[s] = []
            used[s] = 0
            row_map[s, :] = -1
            dense[s] = 0.0
            continue
        if used[s] >= max_seq:
            continue
        if len(pages[s]) * page_size <= used[s]:   # grow one page
            if not alloc.n_free:
                continue
            pages[s] += alloc.alloc(1)
            mapped = min(len(pages[s]) * page_size, max_seq)
            row_map[s, :mapped] = alloc.rows(pages[s], mapped)
        val = np.full((2, 2), stamp, np.float32)
        stamp += 1.0
        pool[row_map[s, used[s]]] = val
        dense[s, used[s]] = val
        used[s] += 1
    return pool, row_map, dense, used


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_gather_pages_matches_dense_layout(seed):
    pool, row_map, dense, used = _random_paged_layout(
        np.random.default_rng(seed))
    view = np.asarray(L.gather_pages(jnp.asarray(pool),
                                     jnp.asarray(row_map)))
    # rows >= used are don't-care: mapped-but-unwritten rows of a reused
    # page may hold a retired request's stale KV, and attention masks them
    # out by pos — the invariant is equality on every *written* row, plus
    # zero-fill wherever the page table is unmapped
    max_seq = row_map.shape[1]
    written = np.arange(max_seq)[None, :] < used[:, None]
    np.testing.assert_array_equal(view[written], dense[written])
    np.testing.assert_array_equal(view[row_map < 0], 0.0)


# ---------------------------------------------------------------------------
# hypothesis tier (runs where hypothesis is installed, e.g. CI)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def allocator_runs(draw):
        n_pages = draw(st.integers(min_value=1, max_value=16))
        page_size = draw(st.integers(min_value=1, max_value=8))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        n_ops = draw(st.integers(min_value=1, max_value=80))
        return n_pages, page_size, seed, n_ops

    @given(allocator_runs())
    @settings(max_examples=60, deadline=None)
    def test_allocator_invariants_property(run):
        n_pages, page_size, seed, n_ops = run
        alloc = PageAllocator(n_pages, page_size)
        initial = alloc.free_pages
        live = _run_ops(alloc, np.random.default_rng(seed), n_ops)
        for pages in live:
            alloc.free(pages)
        assert alloc.free_pages == initial

    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.integers(min_value=1, max_value=8),
           st.integers(min_value=1, max_value=4))
    @settings(max_examples=15, deadline=None)
    def test_gather_pages_matches_dense_property(seed, n_pages, page_size):
        pool, row_map, dense, used = _random_paged_layout(
            np.random.default_rng(seed), n_pages=n_pages,
            page_size=page_size, slots=2, max_seq=8)
        view = np.asarray(L.gather_pages(jnp.asarray(pool),
                                         jnp.asarray(row_map)))
        written = np.arange(row_map.shape[1])[None, :] < used[:, None]
        np.testing.assert_array_equal(view[written], dense[written])
        np.testing.assert_array_equal(view[row_map < 0], 0.0)


# ---------------------------------------------------------------------------
# teacher-forced decode oracle on the paged model path
# ---------------------------------------------------------------------------

def _family(arch, **over):
    cfg = reduced(get_config(arch), **over)
    return cfg, family_module(cfg), family_module(cfg).init(cfg, KEY, tp=1)


def _dense_teacher_forced(cfg, mod, params, prompt, max_new, max_seq):
    """Batch-1 dense decode, one token at a time — the §11 oracle."""
    cache = mod.init_cache(cfg, 1, max_seq, 1)
    logits = None
    for t, tok in enumerate(prompt):
        logits, cache = mod.decode_step(
            params, cfg, cache, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([t], jnp.int32), tp=1, impl="xla")
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(toks) < max_new and pos < max_seq:
        logits, cache = mod.decode_step(
            params, cfg, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32), tp=1, impl="xla")
        toks.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return toks


def _paged_teacher_forced(cfg, mod, params, prompts, max_new, max_seq,
                          page_size):
    """Lockstep greedy decode of several prompts through the PAGED path:
    one shared pool, pages allocated on demand — slots growing in lockstep
    produce an interleaved (non-contiguous) physical layout, so any
    confusion between logical and physical rows shows up as a token flip."""
    slots = len(prompts)
    n_pages = -(-max_seq // page_size) * slots
    alloc = PageAllocator(n_pages, page_size)
    cache = mod.init_paged_cache(cfg, slots, n_pages * page_size, max_seq, 1)
    row_map = np.full((slots, max_seq), -1, np.int32)
    pages: list[list[int]] = [[] for _ in range(slots)]
    pos = np.zeros(slots, np.int64)
    outs: list[list[int]] = [[] for _ in range(slots)]
    has_pool = "pool" in jax.tree_util.tree_leaves(mod.paged_slot_axes(cfg))

    def live(s):
        return len(outs[s]) < max_new and pos[s] < max_seq

    while any(live(s) for s in range(slots)):
        toks = np.zeros((slots, 1), np.int32)
        step_pos = np.full(slots, max_seq, np.int64)
        for s, prompt in enumerate(prompts):
            if not live(s):
                continue
            if has_pool and len(pages[s]) * page_size < pos[s] + 1:
                pages[s] += alloc.alloc(1)
                mapped = min(len(pages[s]) * page_size, max_seq)
                row_map[s, :mapped] = alloc.rows(pages[s], mapped)
            toks[s, 0] = prompt[pos[s]] if pos[s] < len(prompt) \
                else outs[s][-1]
            step_pos[s] = pos[s]
        logits, cache = mod.decode_step(
            params, cfg, cache, jnp.asarray(toks),
            jnp.asarray(step_pos, jnp.int32), tp=1, impl="xla",
            row_map=jnp.asarray(row_map))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s, prompt in enumerate(prompts):
            if step_pos[s] == max_seq:
                continue
            pos[s] += 1
            if pos[s] >= len(prompt):               # prompt consumed: emit
                outs[s].append(int(nxt[s]))
    return outs


PAGED_ORACLE_CASES = [
    ("qwen3-8b", ()),
    # sliding window smaller than the prompts: dense per-slot rings on the
    # local layers share the step with paged pools on the global layers
    ("gemma2-2b", (("local_window", 5), ("n_layers", 4))),
]


@pytest.mark.parametrize("arch,over", PAGED_ORACLE_CASES,
                         ids=[c[0] for c in PAGED_ORACLE_CASES])
@pytest.mark.parametrize("page_size", [2, 5])
def test_paged_decode_matches_teacher_forced_oracle(arch, over, page_size):
    cfg, mod, params = _family(arch, **dict(over))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 3, 9)]
    max_new, max_seq = 5, 24
    oracle = [_dense_teacher_forced(cfg, mod, params, p, max_new, max_seq)
              for p in prompts]
    outs = _paged_teacher_forced(cfg, mod, params, prompts, max_new,
                                 max_seq, page_size)
    for s, (got, want) in enumerate(zip(outs, oracle)):
        assert got == want, f"{arch} ps={page_size}: slot {s} diverged"


def test_parked_lane_cannot_touch_the_pool():
    """Scatter isolation: a lane with an all-−1 page table and a parked
    position must leave the pool bit-identical.  Regression for the
    mode=\"drop\" negative-index WRAP, which routed parked-lane writes onto
    the last pool row and corrupted whichever request owned it."""
    cfg, mod, params = _family("qwen3-8b")
    max_seq, rows = 16, 16
    cache = mod.init_paged_cache(cfg, 2, rows, max_seq, 1)
    row_map = np.full((2, max_seq), -1, np.int32)
    row_map[0, :4] = [2, 3, 0, 1]                  # slot 0 maps 2 pages
    before = jax.tree_util.tree_map(np.asarray, cache)
    toks = jnp.asarray([[5], [9]], jnp.int32)
    pos = jnp.asarray([1, max_seq], jnp.int32)     # slot 1 parked
    _, cache = mod.decode_step(params, cfg, cache, toks, pos, tp=1,
                               impl="xla", row_map=jnp.asarray(row_map))

    def changed_rows(b, a):
        moved = np.asarray(b != np.asarray(a))
        return set(np.nonzero(moved.any(axis=tuple(range(1, moved.ndim)))
                              if moved.ndim > 1 else moved)[0].tolist())

    for name in ("k", "v"):
        for layer in range(before["all"][name].shape[0]):
            touched = changed_rows(before["all"][name][layer],
                                   cache["all"][name][layer])
            assert touched <= {3}, \
                f"layer {layer} {name}: parked lane wrote rows {touched}"
