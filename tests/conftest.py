import sys
from pathlib import Path

# tests run with PYTHONPATH=src; this mirrors that when invoked otherwise.
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

# NOTE: no --xla_force_host_platform_device_count here — smoke tests and
# benches must see the real (single) device; only launch/dryrun.py widens it.

import pytest


@pytest.fixture(autouse=True)
def _reset_activation_context():
    # the activation-sharding context is process state; a test that installs
    # a spec must never leak it into the next test
    yield
    from repro.distributed import context
    context.reset()
