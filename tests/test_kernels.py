"""Per-kernel shape/dtype sweeps against the ref.py oracles (interpret mode)
plus the pure-XLA implementations (chunked attention custom-VJP, chunked
linear recurrences)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import xla_attention as XA
from repro.kernels import xla_linear as XL

RNG = np.random.default_rng(42)


def arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


def close(a, b, rtol, atol):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=rtol,
                               atol=atol)


# ---------------------------------------------------------------------------
# GEMM / GEMV / DOT / CONV2D — the four paper intrinsics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(32, 32, 32), (96, 72, 80), (17, 129, 65),
                                   (256, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_sweep(m, n, k, dtype):
    a, b = arr((m, k), dtype), arr((k, n), dtype)
    got = ops.matmul(a, b, bm=32, bn=32, bk=32, implementation="interpret")
    want = ref.gemm_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    close(got, want, tol, tol * 10)


@pytest.mark.parametrize("m,k", [(64, 64), (96, 80), (33, 257)])
def test_gemv_sweep(m, k):
    a, x = arr((m, k)), arr((k,))
    close(ops.matvec(a, x, bm=32, bk=32, implementation="interpret"),
          ref.gemv_ref(a, x), 1e-5, 1e-4)


@pytest.mark.parametrize("k", [64, 80, 1000])
def test_dot_sweep(k):
    a, b = arr((k,)), arr((k,))
    close(ops.dot(a, b, bk=64, implementation="interpret"),
          ref.dot_ref(a, b), 1e-5, 1e-3)


@pytest.mark.parametrize("c,h,w,kk,r", [(8, 12, 14, 16, 3), (16, 18, 20, 24, 3),
                                        (4, 9, 9, 8, 1)])
def test_conv2d_sweep(c, h, w, kk, r):
    a, wgt = arr((c, h, w)), arr((kk, c, r, r))
    close(ops.conv2d(a, wgt, bk=8, implementation="interpret"),
          ref.conv2d_ref(a, wgt), 1e-4, 2e-3)


# ---------------------------------------------------------------------------
# Flash attention (Pallas) and chunked attention (XLA)
# ---------------------------------------------------------------------------

ATTN_CASES = [dict(), dict(softcap=20.0), dict(window=8),
              dict(causal=False), dict(softcap=30.0, window=16)]


@pytest.mark.parametrize("kw", ATTN_CASES)
@pytest.mark.parametrize("impl", ["interpret", "xla"])
def test_attention_sweep(kw, impl):
    q = arr((2, 40, 4, 32), scale=0.5)
    k = arr((2, 56, 2, 32), scale=0.5)
    v = arr((2, 56, 2, 32), scale=0.5)
    got = ops.attention(q, k, v, bq=16, bkv=16, implementation=impl, **kw)
    close(got, ref.attention_ref(q, k, v, **kw), 1e-3, 1e-3)


def test_attention_decode_single_query():
    q = arr((3, 1, 4, 16), scale=0.5)
    k = arr((3, 33, 4, 16), scale=0.5)
    v = arr((3, 33, 4, 16), scale=0.5)
    for impl in ("interpret", "xla"):
        close(ops.attention(q, k, v, bq=8, bkv=16, implementation=impl),
              ref.attention_ref(q, k, v), 1e-3, 1e-3)


def test_xla_attention_gradients_match_ref():
    q, k, v = (arr((2, 24, 4, 16), scale=0.5) for _ in range(3))
    k = k[:, :, :2]
    v = v[:, :, :2]
    do = arr((2, 24, 4, 16))

    def loss_x(q, k, v):
        return (XA.attention(q, k, v, softcap=15.0, chunk=8) * do).sum()

    def loss_r(q, k, v):
        return (ref.attention_ref(q, k, v, softcap=15.0) * do).sum()

    gx = jax.grad(loss_x, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gx, gr):
        close(a, b, 2e-3, 2e-3)


# ---------------------------------------------------------------------------
# RWKV6 / Mamba2 recurrences
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["interpret", "xla"])
@pytest.mark.parametrize("with_state", [False, True])
def test_rwkv6_sweep(impl, with_state):
    b, t, h, dk, dv = 2, 32, 3, 16, 24
    r, k = arr((b, t, h, dk)), arr((b, t, h, dk))
    v = arr((b, t, h, dv))
    w = jnp.asarray(-np.exp(RNG.standard_normal((b, t, h, dk)) * 0.5),
                    jnp.float32)
    u = arr((h, dk))
    st = arr((b, h, dk, dv)) if with_state else None
    got_o, got_s = ops.rwkv6(r, k, v, w, u, st, chunk=8, implementation=impl)
    want_o, want_s = ref.rwkv6_ref(r, k, v, w, u, st)
    close(got_o, want_o, 1e-3, 1e-3)
    close(got_s, want_s, 1e-3, 1e-3)


@pytest.mark.parametrize("impl", ["interpret", "xla"])
@pytest.mark.parametrize("with_state", [False, True])
def test_mamba2_sweep(impl, with_state):
    b, t, h, p, n = 2, 32, 3, 16, 8
    x = arr((b, t, h, p))
    a = jnp.asarray(-np.abs(RNG.standard_normal((b, t, h)) * 0.3), jnp.float32)
    bb, cc = arr((b, t, h, n)), arr((b, t, h, n))
    st = arr((b, h, n, p)) if with_state else None
    got_y, got_s = ops.mamba2(x, a, bb, cc, st, chunk=8, implementation=impl)
    want_y, want_s = ref.mamba2_ref(x, a, bb, cc, st)
    close(got_y, want_y, 1e-3, 1e-3)
    close(got_s, want_s, 1e-3, 1e-3)


def test_rwkv6_chunked_state_streaming():
    """Processing T tokens in one call == two chained half-calls."""
    b, t, h, dk, dv = 1, 32, 2, 8, 8
    r, k, v = arr((b, t, h, dk)), arr((b, t, h, dk)), arr((b, t, h, dv))
    w = jnp.asarray(-np.exp(RNG.standard_normal((b, t, h, dk)) * 0.3),
                    jnp.float32)
    u = arr((h, dk))
    o_full, s_full = XL.rwkv6(r, k, v, w, u, chunk=8)
    o1, s1 = XL.rwkv6(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, chunk=8)
    o2, s2 = XL.rwkv6(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, s1,
                      chunk=8)
    close(jnp.concatenate([o1, o2], axis=1), o_full, 1e-4, 1e-4)
    close(s2, s_full, 1e-4, 1e-4)


def test_tuned_matmul_uses_registry(tmp_path):
    from repro.core.codesign import Solution
    from repro.core.hw_primitives import HWBuilder
    from repro.core import solution as sol

    hw = HWBuilder("GEMM").reshapeArray([256, 384], depth=512).build()
    s = Solution(hw, {}, 1.0, 1.0, 1.0, "GEMM")
    path = tmp_path / "solutions.json"
    sol.save("myapp", s, path)
    bm, bn, bk = sol.kernel_blocks("myapp", path)
    assert (bm, bn, bk) == (256, 384, 512)
    assert sol.kernel_blocks("missing", path) == (256, 256, 512)
