"""Analytical cost model: legality, reuse-from-loop-order, padding waste,
dataflow consistency, and agreement with the kernels' useful FLOPs."""
import math

import pytest

from repro.core import workloads as W
from repro.core.cost_model import TARGETS, evaluate, n_pes
from repro.core.hw_primitives import HWBuilder
from repro.core.intrinsics import GEMM
from repro.core.matching import match
from repro.core.sw_primitives import Schedule
from repro.core.sw_space import SoftwareSpace


def hw(vmem_kib=256, banks=2, rows=16, cols=16, depth=16, df="OS"):
    return (HWBuilder("GEMM").reshapeArray([rows, cols], depth=depth)
            .addCache(vmem_kib).partitionBanks(banks).dataflow(df).build())


@pytest.fixture
def gemm512():
    return W.gemm(512, 512, 512)


def sched(gm, tiles, order=("i", "j", "k"), choice_idx=0):
    choices = match(GEMM, gm)
    return Schedule(choices[choice_idx], tuple(sorted(tiles.items())),
                    tuple(order), 0)


def test_legal_and_flops(gemm512):
    rep = evaluate(gemm512, sched(gemm512, {"i": 64, "j": 64, "k": 64}), hw())
    assert rep.legal
    assert rep.useful_flops == 2 * 512 ** 3
    assert rep.flops >= rep.useful_flops
    assert rep.latency_s > 0 and math.isfinite(rep.power_w)


def test_vmem_overflow_illegal(gemm512):
    big = sched(gemm512, {"i": 512, "j": 512, "k": 512})
    rep = evaluate(gemm512, big, hw(vmem_kib=64))
    assert not rep.legal and rep.latency_s == math.inf


def test_padding_waste(gemm512):
    """Tiles not aligned to the intrinsic size execute padded FLOPs —
    the paper's Fig. 7(b) redundant-computation effect."""
    aligned = evaluate(gemm512, sched(gemm512, {"i": 64, "j": 64, "k": 64}),
                       hw())
    ragged = evaluate(gemm512, sched(gemm512, {"i": 24, "j": 24, "k": 24}),
                      hw())
    assert aligned.utilization == 1.0
    assert ragged.utilization < 1.0
    assert ragged.flops > aligned.flops


def test_loop_order_changes_traffic(gemm512):
    """p1-vs-p2 (paper Fig. 2): same tiles, different order, different
    HBM traffic because stationarity changes."""
    t = {"i": 64, "j": 64, "k": 64}
    a = evaluate(gemm512, sched(gemm512, t, order=("i", "j", "k")), hw())
    b = evaluate(gemm512, sched(gemm512, t, order=("k", "j", "i")), hw())
    assert a.hbm_bytes != b.hbm_bytes


def test_banks_overlap_helps(gemm512):
    t = {"i": 64, "j": 64, "k": 64}
    one = evaluate(gemm512, sched(gemm512, t), hw(banks=1))
    two = evaluate(gemm512, sched(gemm512, t), hw(banks=2))
    assert two.latency_s < one.latency_s


def test_bigger_array_not_always_better():
    """Paper §VII-C ground truth: over-provisioned PE arrays pad small
    workloads and can lose."""
    small_wl = W.gemm(32, 32, 32)
    choices = match(GEMM, small_wl)
    s = Schedule(choices[0], (("i", 32), ("j", 32), ("k", 32)),
                 ("i", "j", "k"), 0)
    small_hw = hw(rows=16, cols=16, depth=16)
    big_hw = hw(rows=256, cols=256, depth=16, vmem_kib=2048)
    r_small = evaluate(small_wl, s, small_hw)
    r_big = evaluate(small_wl, s, big_hw)
    assert r_small.legal and r_big.legal
    assert r_big.utilization < r_small.utilization


def test_pe_budget_per_intrinsic():
    g = HWBuilder("GEMM").reshapeArray([8, 8], depth=64).build()
    v = HWBuilder("GEMV").reshapeArray([8, 8], depth=64).build()
    d = HWBuilder("DOT").reshapeArray([8, 8], depth=64).build()
    assert n_pes(g) == 64
    assert n_pes(v) == 8 * 64
    assert n_pes(d) == 64


def test_dataflow_consistency_penalty(gemm512):
    t = {"i": 64, "j": 64, "k": 64}
    # OS stationary = output (i,j): innermost k does not index it -> good
    good = evaluate(gemm512, sched(gemm512, t, order=("i", "j", "k")),
                    hw(df="OS"))
    bad = evaluate(gemm512, sched(gemm512, t, order=("k", "i", "j")),
                   hw(df="OS"))
    assert good.compute_s <= bad.compute_s


def test_tpu_target_mxu_alignment(gemm512):
    t = {"i": 128, "j": 128, "k": 128}
    tpu_ok = evaluate(gemm512, sched(gemm512, t),
                      hw(rows=128, cols=128, depth=128, vmem_kib=2048),
                      target="tpu")
    tpu_bad = evaluate(gemm512, sched(gemm512, t),
                       hw(rows=100, cols=100, depth=128, vmem_kib=2048),
                       target="tpu")
    assert tpu_ok.legal
    # misaligned blocks lose MXU efficiency -> more time per USEFUL flop
    assert (tpu_bad.compute_s / tpu_bad.useful_flops
            > tpu_ok.compute_s / tpu_ok.useful_flops)


def test_default_schedule_is_legal_everywhere():
    for wl in (W.gemm(256, 256, 256), W.conv2d(32, 16, 28, 28)):
        for intr in ("GEMM",):
            from repro.core.intrinsics import ALL_INTRINSICS
            choices = match(ALL_INTRINSICS[intr], wl)
            if not choices:
                continue
            space = SoftwareSpace(wl, choices, hw())
            rep = evaluate(wl, space.default_schedule(), hw())
            assert rep.legal
