"""The observability layer (DESIGN.md §13).

Layers under test:

  * tracer mechanics — span nesting/ordering/depth, ring wraparound with a
    correct dropped count, Chrome trace-event export shape;
  * metrics mechanics — counter/gauge basics, histogram bucket-edge
    semantics (half-open buckets, edge values open their bucket, quantiles
    clamped to observed min/max);
  * the disabled-mode contract — obs off records zero events and creates
    zero registry entries across a full serve run;
  * the non-interference gate — a traced PagedServeEngine run produces
    BIT-IDENTICAL outputs to an untraced one, and its trace replays every
    request lifecycle in order;
  * export/validation round-trip — telemetry documents validate, corrupt
    ones are rejected with specific defects;
  * satellites — serve stats latency summaries match np.percentile,
    measurement failures carry elapsed_s + error_type into the tuning DB,
    CalibratedCostModel forwards its attached cache's hit/miss counts.
"""
import functools
import json
import math

import numpy as np
import pytest

from repro import obs
from repro.obs.export import (snapshot, validate_telemetry,
                              validate_telemetry_file)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               geometric_edges, linear_edges)
from repro.obs.trace import ARGS, DEPTH, DUR, NAME, PH, TS, Tracer

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container ships without hypothesis
    HAVE_HYPOTHESIS = False


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with observability disabled — the module
    singleton must never leak across tests."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_order():
    tr = Tracer(64)
    with tr.span("outer"):
        with tr.span("inner"):
            tr.instant("tick", {"i": 1})
    evs = tr.events()
    # completion order: the instant fires first, then inner closes, then
    # outer — but depths record the *nesting* structure
    assert [(e[NAME], e[PH], e[DEPTH]) for e in evs] == [
        ("tick", "i", 2), ("inner", "X", 1), ("outer", "X", 0)]
    inner, outer = evs[1], evs[2]
    assert outer[TS] <= inner[TS]                    # outer opened first
    assert outer[DUR] >= inner[DUR]                  # and covers inner
    assert inner[TS] + inner[DUR] <= outer[TS] + outer[DUR] + 1e-6


def test_span_args_recorded():
    tr = Tracer(8)
    with tr.span("s", {"k": 3}):
        pass
    assert tr.events()[0][ARGS] == {"k": 3}


def test_ring_wraparound_keeps_newest_and_counts_dropped():
    tr = Tracer(8)
    for i in range(20):
        tr.instant("e", {"i": i})
    assert len(tr) == 8
    assert tr.recorded == 20
    assert tr.dropped == 12
    assert [e[ARGS]["i"] for e in tr.events()] == list(range(12, 20))


def test_chrome_export_schema_and_serializability():
    tr = Tracer(16)
    with tr.span("work", {"n": 2}):
        tr.instant("mark")
    doc = tr.to_chrome()
    json.dumps(doc)                                    # must serialize
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {e["name"] for e in evs} == {"work", "mark"}
    for e in evs:
        assert set(e) >= {"name", "ph", "ts", "pid", "tid", "args"}
        assert e["ph"] in ("X", "i")
        assert ("dur" in e) == (e["ph"] == "X")
        assert "depth" in e["args"]


def test_tracer_rejects_zero_capacity():
    with pytest.raises(ValueError, match="capacity"):
        Tracer(0)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_counter_and_gauge_basics():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = Gauge()
    for v in (4.0, -1.0, 2.0):
        g.set(v)
    assert (g.value, g.min, g.max, g.n_sets) == (2.0, -1.0, 4.0, 3)


def test_histogram_bucket_edges_are_half_open():
    h = Histogram([1.0, 2.0, 4.0])
    for v in (0.5, 1.0, 1.5, 2.0, 3.999, 4.0, 100.0):
        h.observe(v)
    # buckets: (-inf,1) [1,2) [2,4) [4,inf)
    assert h.counts == [1, 2, 2, 2]
    assert h.count == 7 and sum(h.counts) == h.count
    assert h.min == 0.5 and h.max == 100.0


def test_histogram_quantiles_clamped_and_monotone():
    h = Histogram(geometric_edges(1e-3, 10.0))
    vals = [0.01, 0.02, 0.05, 0.1, 0.5, 1.0, 2.0]
    for v in vals:
        h.observe(v)
    qs = [h.quantile(q) for q in (0.0, 0.25, 0.5, 0.75, 0.95, 1.0)]
    assert all(min(vals) <= q <= max(vals) for q in qs)
    assert qs == sorted(qs)
    assert math.isclose(h.mean, sum(vals) / len(vals))


def test_histogram_single_value_degenerate():
    h = Histogram([1.0, 2.0])
    h.observe(1.5)
    assert h.quantile(0.5) == 1.5 == h.quantile(0.99)


def test_edge_builders_validate():
    assert geometric_edges(1.0, 8.0, per_octave=1) == (1.0, 2.0, 4.0, 8.0)
    assert linear_edges(0.0, 1.0, 4) == (0.0, 0.25, 0.5, 0.75, 1.0)
    with pytest.raises(ValueError):
        geometric_edges(0.0, 1.0)
    with pytest.raises(ValueError):
        linear_edges(1.0, 1.0)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_histogram_properties_hypothesis():
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.floats(min_value=1e-6, max_value=1e3,
                              allow_nan=False), min_size=1, max_size=64),
           st.floats(min_value=0.0, max_value=1.0))
    def check(vals, q):
        h = Histogram(geometric_edges(1e-6, 1e3))
        for v in vals:
            h.observe(v)
        assert sum(h.counts) == h.count == len(vals)
        est = h.quantile(q)
        assert min(vals) <= est <= max(vals)
    check()


def test_registry_get_or_create():
    m = MetricsRegistry()
    assert m.counter("a") is m.counter("a")
    assert m.gauge("b") is m.gauge("b")
    assert m.histogram("c") is m.histogram("c")
    assert len(m) == 3
    snap = m.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}


# ---------------------------------------------------------------------------
# Disabled-mode contract
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop():
    assert not obs.enabled() and obs.state() is None
    s1 = obs.span("x")
    s2 = obs.span("y", {"k": 1})
    assert s1 is s2                                  # one shared object
    with s1:
        obs.instant("nothing", {"k": 2})             # silently dropped
    with pytest.raises(RuntimeError, match="disabled"):
        obs.snapshot()
    with pytest.raises(RuntimeError, match="disabled"):
        obs.export_telemetry()


def test_enable_disable_cycle():
    st_ = obs.enable(capacity=32)
    assert obs.enabled() and obs.state() is st_
    with obs.span("s"):
        pass
    assert len(st_.tracer) == 1
    obs.disable()
    assert obs.state() is None


# ---------------------------------------------------------------------------
# Export / validation round-trip
# ---------------------------------------------------------------------------

def test_telemetry_roundtrip_and_cli_validation(tmp_path):
    st_ = obs.enable(capacity=16)
    with obs.span("phase", {"n": 1}):
        obs.instant("ev")
    st_.metrics.counter("c").inc(3)
    st_.metrics.gauge("g").set(7.0)
    st_.metrics.histogram("h", [1.0, 2.0]).observe(1.5)

    doc = obs.snapshot()
    assert validate_telemetry(doc) == []
    p = obs.export_telemetry(tmp_path / "telemetry.json")
    assert validate_telemetry_file(p) == []
    loaded = json.loads(p.read_text())
    assert loaded["trace"]["recorded"] == 2
    assert loaded["metrics"]["counters"]["c"]["value"] == 3

    cpath = obs.export_chrome_trace(tmp_path / "trace.json")
    chrome = json.loads(cpath.read_text())
    assert {e["name"] for e in chrome["traceEvents"]} == {"phase", "ev"}


def test_validation_rejects_corruption(tmp_path):
    st_ = obs.enable(capacity=4)
    st_.metrics.histogram("h", [1.0]).observe(0.5)
    doc = snapshot(st_.tracer, st_.metrics)

    bad = dict(doc, schema_version=99)
    assert any("schema_version" in e for e in validate_telemetry(bad))

    bad = json.loads(json.dumps(doc))
    bad["metrics"]["histograms"]["h"]["counts"] = [1]      # wrong length
    assert any("len(edges) + 1" in e for e in validate_telemetry(bad))

    bad = json.loads(json.dumps(doc))
    bad["metrics"]["histograms"]["h"]["counts"] = [5, 0]   # sum != count
    assert any("sum" in e for e in validate_telemetry(bad))

    p = tmp_path / "junk.json"
    p.write_text("{nope")
    assert any("corrupt" in e for e in validate_telemetry_file(p))
    assert any("not found" in e
               for e in validate_telemetry_file(tmp_path / "missing.json"))


# ---------------------------------------------------------------------------
# Live-engine non-interference + lifecycle replay
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _family(arch):
    import jax

    from repro.configs import get_config
    from repro.models import family_module, reduced
    cfg = reduced(get_config(arch))
    mod = family_module(cfg)
    return cfg, mod.init(cfg, jax.random.PRNGKey(0), tp=1)


def _mixed(cfg, n=10):
    from repro.launch.serve import make_requests
    return make_requests(cfg, n, 4, seed=0, long_every=3,
                         priorities=(0, 1, 2))


def test_traced_serve_outputs_bit_identical_and_lifecycle_replay():
    from repro.launch.serve import serve_requests
    cfg, params = _family("qwen3-8b")
    kw = dict(slots=3, paged=True, page_size=4, n_pages=8, prefill_chunk=4)

    done0, stats0 = serve_requests(cfg, params, _mixed(cfg), **kw)
    assert obs.state() is None                 # untraced stayed untraced

    st_ = obs.enable()
    done1, stats1 = serve_requests(cfg, params, _mixed(cfg), **kw)
    assert [r.out for r in done1] == [r.out for r in done0]
    assert stats1["preemptions"] == stats0["preemptions"]

    # replay each request's lifecycle from the trace
    life: dict[int, list[str]] = {}
    for ev in st_.tracer.events():
        if ev[NAME].startswith("req."):
            life.setdefault(ev[ARGS]["rid"], []).append(
                ev[NAME].removeprefix("req."))
    by_rid = {r.rid: r for r in done1}
    assert set(life) == set(by_rid)
    for rid, seq in life.items():
        req = by_rid[rid]
        assert seq[0] == "submit" and seq[-1] == "retire"
        assert seq.count("preempt") == req.preemptions
        # every preemption is eventually resumed (all requests finished)
        assert seq.count("resume") == seq.count("preempt")
        assert "first_token" in seq
        # admitted exactly once as fresh; later placements are resumes
        assert seq.count("admit") == 1
        assert seq.index("admit") < seq.index("first_token") \
            < seq.index("retire")
    # the scenario must actually exercise preemption to gate anything
    assert stats1["preemptions"] > 0

    # engine-level spans + gauges landed too
    names = {e[NAME] for e in st_.tracer.events()}
    assert {"serve.step", "serve.decode_step", "serve.prefill_chunk"} \
        <= names
    assert st_.metrics.gauge("serve.pages_free").n_sets > 0
    assert st_.metrics.counter("serve.preemptions").value \
        == stats1["preemptions"]


def test_disabled_serve_creates_no_events_or_metrics():
    from repro.launch.serve import serve_requests
    cfg, params = _family("qwen3-8b")
    st_ = obs.enable()
    obs.disable()                    # session object kept, singleton cleared
    serve_requests(cfg, params, _mixed(cfg, n=4), slots=2, paged=True,
                   page_size=4, n_pages=8, prefill_chunk=4)
    assert len(st_.tracer) == 0 and st_.tracer.recorded == 0
    assert len(st_.metrics) == 0


def test_serve_stats_latency_summaries_match_percentiles():
    from repro.launch.serve import serve_requests
    cfg, params = _family("qwen3-8b")
    done, stats = serve_requests(cfg, params, _mixed(cfg, n=8), slots=2,
                                 paged=True, page_size=4, n_pages=8,
                                 prefill_chunk=4)
    for key, vals in (
            ("ttft_s", [r.queue_latency for r in done]),
            ("queue_wait_s", [r.admit_time - r.submit_time for r in done])):
        s = stats[key]
        assert s["count"] == len(done)
        # Histogram.quantile is an inverted-CDF estimator (first value whose
        # cumulative count reaches q*n, interpolated inside its bucket) — so
        # compare against the same definition; numpy's default linear method
        # interpolates BETWEEN order statistics, which a histogram cannot see
        ref = np.percentile(vals, [50, 95, 99], method="inverted_cdf")
        # 512 linear buckets over the observed range: interpolation error is
        # bounded by one bucket width
        tol = (max(vals) - min(vals)) / 512 + 1e-12
        assert abs(s["p50"] - ref[0]) <= tol
        assert abs(s["p95"] - ref[1]) <= tol
        assert abs(s["p99"] - ref[2]) <= tol
        assert math.isclose(s["mean"], float(np.mean(vals)))


# ---------------------------------------------------------------------------
# Satellites: measurement failure capture, DB persistence, cache forwarding
# ---------------------------------------------------------------------------

def _gemm_point(n=8):
    """A small gemm workload with a matching hw config + schedule."""
    from repro.core.hw_primitives import HWConfig
    from repro.core.intrinsics import GEMM
    from repro.core.matching import match
    from repro.core.sw_primitives import Schedule
    from repro.core.workloads import gemm

    w = gemm(n, n, n)
    choice = match(GEMM, w)[0]
    tiles = tuple(sorted((c, n) for c in choice.mapped_compute_indices))
    hw = HWConfig(intrinsic="GEMM", pe_rows=8, pe_cols=8, pe_depth=8,
                  vmem_kib=2048)
    return w, hw, Schedule(choice, tiles, tuple(w.all_indices()), 0)


def test_measure_failure_captures_elapsed_and_error_type():
    from repro.tuner.measure import MeasureOptions, measure_one

    w, hw, sched = _gemm_point()
    # impossible block-volume cap forces a ValueError in lower()
    res = measure_one(w, hw, sched, MeasureOptions(max_block_elems=1))
    assert not res.ok
    assert res.error_type == "ValueError"
    assert res.error.startswith("ValueError:")
    assert res.elapsed_s >= 0.0
    ok = measure_one(w, hw, sched, MeasureOptions())
    assert ok.ok and ok.elapsed_s > 0.0 and ok.error_type == ""


def test_tuning_db_failures_section_roundtrip(tmp_path):
    from repro.tuner.db import TuningDB
    p = tmp_path / "db.json"
    db = TuningDB(p)
    db.add_failures([{"workload": "w0", "error_type": "ValueError",
                      "error": "ValueError: boom", "elapsed_s": 0.1,
                      "backend": "interpret", "app": "t"}])
    db.save(p)

    back = TuningDB.load(p)
    assert len(back.failures) == 1
    assert back.failures[0]["error_type"] == "ValueError"
    # load + save again must not duplicate (content dedup)
    back.save(p)
    assert len(TuningDB.load(p).failures) == 1
    # old-reader tolerance: a malformed section loads as empty, warning only
    p2 = tmp_path / "bad.json"
    p2.write_text(json.dumps({"version": 1, "records": {},
                              "calibration": {}, "apps": {},
                              "failures": "nope"}))
    with pytest.warns(UserWarning, match="failures"):
        assert TuningDB.load(p2).failures == []


def test_measured_codesign_persists_failures(tmp_path):
    from repro.core.codesign import codesign
    from repro.core.workloads import gemm
    from repro.tuner.db import TuningDB
    from repro.tuner.measure import MeasureOptions

    p = tmp_path / "db.json"
    rep = codesign([gemm(8, 8, 8)], intrinsics=["GEMM"], n_trials=2,
                   n_init=2, seed=0, measure=True, measure_top_k=1,
                   measure_opts=MeasureOptions(max_block_elems=1),
                   db_path=p, app="failtest")
    assert rep.db_path == p
    fails = TuningDB.load(p).failures
    assert fails and all(f["app"] == "failtest" for f in fails)
    assert all(f["error_type"] == "ValueError" for f in fails)
    assert all(f["elapsed_s"] >= 0.0 for f in fails)


def test_evalcache_hit_rate_and_calibrated_model_forwarding():
    from repro.core.cost_model import EvalCache, evaluate
    from repro.tuner.calibrate import Calibration, CalibratedCostModel

    w, hw, sched = _gemm_point()
    cache = EvalCache()
    assert cache.hit_rate == 0.0

    model = CalibratedCostModel(Calibration(), target="spatial", cache=cache)
    r1 = model.evaluate(w, sched, hw)          # miss: attached cache used
    r2 = model.evaluate(w, sched, hw)          # hit
    assert r1.latency_s == r2.latency_s
    assert (model.cache_hits, model.cache_misses) == (1, 1)
    assert model.cache_hit_rate == 0.5
    assert cache.stats()["hit_rate"] == 0.5
    # an explicit per-call cache still overrides the attached one
    other = EvalCache()
    model.evaluate(w, sched, hw, cache=other)
    assert other.misses == 1 and model.cache_misses == 1

    # parity with the raw evaluate through the same cache protocol
    raw = evaluate(w, sched, hw, "spatial")
    assert math.isclose(r1.latency_s, raw.latency_s)


def test_codesign_emits_spans_and_cache_gauges():
    from repro.core.codesign import codesign
    from repro.core.workloads import gemm

    st_ = obs.enable()
    # n_trials must exceed n_init: the init design satisfies the first
    # n_init trials, and only the while-loop beyond them emits mobo.trial
    codesign([gemm(8, 8, 8)], intrinsics=["GEMM"], n_trials=4, n_init=2,
             seed=0)
    names = {e[NAME] for e in st_.tracer.events()}
    assert {"codesign.run", "codesign.intrinsic", "codesign.hw_dse",
            "codesign.refine", "mobo.trial", "mobo.fit_gps",
            "sw_dse.run_searches", "sw_dse.round"} <= names
    assert st_.metrics.gauge("evalcache.entries").value > 0
    assert st_.metrics.counter("mobo.trials").value > 0
    hv_evs = [e for e in st_.tracer.events() if e[NAME] == "mobo.hv"]
    assert hv_evs and all("hv" in e[ARGS] for e in hv_evs)
