"""Tests for the §Perf hillclimb features: windowed ring KV cache, int8 KV
quantization, ZeRO-1/pure-DP spec transforms, and grouped MoE dispatch
invariants (hypothesis)."""
import dataclasses

import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import family_module, reduced

KEY = jax.random.PRNGKey(0)


def _greedy_decode_matches_forward(cfg, s=16, b=2, rtol=6e-2):
    mod = family_module(cfg)
    params = mod.init(cfg, KEY, tp=1)
    toks = (jnp.arange(b * s, dtype=jnp.int32).reshape(b, s) * 7) % cfg.vocab
    full = mod.forward(params, cfg, {"tokens": toks}, tp=1, impl="xla")
    cache = mod.init_cache(cfg, b, s, tp=1)
    for t in range(s):
        logits, cache = mod.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                        jnp.int32(t), tp=1, impl="xla")
    got = np.asarray(logits[:, 0, :cfg.vocab], np.float32)
    want = np.asarray(full[:, -1, :cfg.vocab], np.float32)
    return got, want, cache


def test_ring_cache_smaller_and_exact():
    """Sliding-window layers carry only `window` slots; decode logits match
    the full forward bit-closely (the §Perf gemma2 iteration 1)."""
    cfg = reduced(get_config("gemma2-2b"), local_window=6, n_layers=4)
    got, want, cache = _greedy_decode_matches_forward(cfg)
    assert cache["local"]["k"].shape[2] == 6       # ring slots == window
    assert cache["global"]["k"].shape[2] == 16     # global keeps full depth
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
    assert (got.argmax(-1) == want.argmax(-1)).all()


def test_ring_cache_past_wraparound():
    """Decode far past the window: ring slots wrap and stay correct."""
    cfg = reduced(get_config("gemma2-2b"), local_window=4, n_layers=2)
    got, want, _ = _greedy_decode_matches_forward(cfg, s=14)
    assert (got.argmax(-1) == want.argmax(-1)).all()


def test_int8_kv_cache_close():
    """int8 KV (§Perf gemma2 iteration 2): small bounded logit error."""
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-8b")), kv_int8=True)
    got, want, cache = _greedy_decode_matches_forward(cfg)
    assert cache["all"]["k"].dtype == jnp.int8
    assert "k_scale" in cache["all"]
    np.testing.assert_allclose(got, want, rtol=0.1, atol=0.25)


def test_int8_cache_specs_match():
    cfg = dataclasses.replace(reduced(get_config("qwen3-8b")), kv_int8=True)
    mod = family_module(cfg)
    cache = mod.init_cache(cfg, 2, 8, tp=1)
    specs = mod.cache_specs(cfg)
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(jax.tree_util.tree_map(
                lambda _: 0, specs, is_leaf=lambda x: isinstance(x, P))))


# ---------------------------------------------------------------------------
# sharding-mode spec transforms
# ---------------------------------------------------------------------------

def test_zero1_strips_data_from_params():
    from repro.distributed.sharding import zero1_specs
    tree = {"w": P("data", "model"), "e": P(("pod", "data"), None),
            "n": P(None)}
    got = zero1_specs(tree)
    assert got["w"] == P(None, "model")
    assert got["e"] == P("pod", None)
    assert got["n"] == P(None)


def test_puredp_moves_model_to_fsdp():
    import os
    saved = os.environ.get("XLA_FLAGS")
    from repro.launch import dryrun  # module import sets XLA_FLAGS...
    # ...which must not leak into other tests' subprocess environments
    if saved is None:
        os.environ.pop("XLA_FLAGS", None)
    else:
        os.environ["XLA_FLAGS"] = saved
    tree = {"w": P("data", "model"), "kv": P(("pod", "data"), None, "model"),
            "n": P(None)}
    got = dryrun._puredp_specs(tree)
    assert got["w"] == P(("data", "model"), None)
    assert got["kv"] == P(("pod", "data", "model"), None, None)
    assert got["n"] == P(None)


# ---------------------------------------------------------------------------
# grouped MoE dispatch invariants
# ---------------------------------------------------------------------------

@given(st.integers(2, 5), st.integers(1, 3), st.integers(2, 6))
@settings(max_examples=15, deadline=None)
def test_moe_grouped_capacity_invariants(e_pow, k, t_pow):
    """Per-group dispatch: every kept token lands in a unique (expert, slot);
    positions are dense per expert; drops only happen beyond capacity."""
    from repro.models.layers import _dispatch_group
    e, t = 2 ** e_pow, 2 ** t_pow * 4
    k = min(k, e)
    rng = np.random.default_rng(e * 100 + t + k)
    x = jnp.asarray(rng.standard_normal((t, 8)), jnp.float32)
    logits = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    cap = max(8, int(np.ceil(t * k / e * 1.25)))
    buf, flat_e, slot, keep, gates = _dispatch_group(x, logits, k, cap)
    flat_e, slot = np.asarray(flat_e), np.asarray(slot)
    keep = np.asarray(keep)[:, 0] > 0
    assert buf.shape == (e, cap, 8)
    # kept (expert, slot) pairs are unique
    pairs = list(zip(flat_e[keep], slot[keep]))
    assert len(pairs) == len(set(pairs))
    # positions per expert are dense 0..n_kept-1
    for ee in range(e):
        slots = sorted(slot[keep][flat_e[keep] == ee])
        assert slots == list(range(len(slots)))
    # gates normalized per token
    gsum = np.asarray(gates).reshape(t, k).sum(-1)
    np.testing.assert_allclose(gsum, 1.0, rtol=1e-3)


def test_moe_grouped_matches_ungrouped_semantics():
    """With capacity ample, grouped dispatch == dense mixture of selected
    experts computed naively."""
    from repro.models.layers import moe, moe_init
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    p = moe_init(KEY, cfg, tp=1, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)) * 0.3,
                    jnp.float32)
    out = moe(p, cfg, x, tp=1)

    # naive reference: full top-k mixture, no capacity
    logits = (x.reshape(-1, cfg.d_model) @ p["router"] + p["router_mask"])
    top_vals, top_idx = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    x2 = x.reshape(-1, cfg.d_model)
    h_all = (jax.nn.silu(jnp.einsum("td,edf->tef", x2, p["w_gate"]))
             * jnp.einsum("td,edf->tef", x2, p["w_up"]))
    y_all = jnp.einsum("tef,efd->ted", h_all, p["w_down"])
    ref = jnp.einsum("tk,tkd->td", gates,
                     jnp.take_along_axis(y_all, top_idx[..., None], axis=1))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_moe_int8_dispatch_flag_runs():
    from repro.models.layers import moe, moe_init
    cfg = dataclasses.replace(reduced(get_config("granite-moe-3b-a800m")),
                              moe_int8_dispatch=True)
    p = moe_init(KEY, cfg, tp=1, dtype=jnp.float32)
    x = jnp.ones((1, 8, cfg.d_model), jnp.float32) * 0.1
    out = moe(p, cfg, x, tp=1)
    assert bool(jnp.isfinite(out).all())
