"""The continuous-batching serving engine (DESIGN.md §11).

Three layers:

  * FCFS scheduler unit tests — pure bookkeeping, no model: admission
    order, lowest-free-slot placement, slot reuse after retirement,
    concurrency caps, request validation.
  * per-request budget semantics on a live engine.
  * the staggered-admission parity gate: per-request outputs from the
    continuous-batched engine must be BIT-IDENTICAL to a sequential
    single-request reference (fresh slots=1 engine per request) for every
    admission pattern, on every decode-capable family — including a
    gemma2-style ring-buffer-window case whose prompts overflow the window.
    This is the invariant the old serving loop violated five different ways
    (shared scalar pos, zero-token prefill pollution, cross-request pos
    desync, clamped last row, stale-KV leaks).
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import FCFSScheduler, Request, ServeEngine, \
    serve_requests
from repro.models import family_module, reduced

KEY = jax.random.PRNGKey(0)


def _req(rid, n=3, max_new=4, **kw):
    return Request(rid, np.arange(1, n + 1, dtype=np.int32), max_new, **kw)


# ---------------------------------------------------------------------------
# Request validation (satellite: real next_token field, no empty prompts)
# ---------------------------------------------------------------------------

def test_request_rejects_empty_prompt():
    with pytest.raises(ValueError, match="non-empty"):
        Request(0, np.array([], np.int32), 4)
    with pytest.raises(ValueError, match="1-D"):
        Request(0, np.ones((2, 2), np.int32), 4)
    with pytest.raises(ValueError, match="max_new"):
        _req(0, max_new=0)


def test_request_next_token_is_a_real_field():
    r = _req(0)
    assert r.next_token == -1                  # not a getattr default
    assert "next_token" in {f.name for f in
                            __import__("dataclasses").fields(Request)}


# ---------------------------------------------------------------------------
# FCFS scheduler (model-free)
# ---------------------------------------------------------------------------

def test_fcfs_admission_order_and_lowest_slot_first():
    s = FCFSScheduler(3)
    for i in range(5):
        s.submit(_req(i))
    placed = s.admit()
    assert [(slot, r.rid) for slot, r in placed] == [(0, 0), (1, 1), (2, 2)]
    assert [r.rid for r in s.queue] == [3, 4]
    assert s.admit() == []                     # full: nothing placed


def test_fcfs_slot_reuse_after_retirement():
    s = FCFSScheduler(2)
    for i in range(4):
        s.submit(_req(i))
    s.admit()
    done = s.retire(0)
    assert done.rid == 0 and s.n_active == 1
    placed = s.admit()                         # rid 2 lands in freed slot 0
    assert [(slot, r.rid) for slot, r in placed] == [(0, 2)]
    s.retire(1)
    assert [(slot, r.rid) for slot, r in s.admit()] == [(1, 3)]
    s.retire(1)
    with pytest.raises(ValueError, match="not occupied"):
        s.retire(1)


def test_fcfs_concurrency_cap():
    s = FCFSScheduler(4, max_concurrency=1)
    for i in range(3):
        s.submit(_req(i))
    assert len(s.admit()) == 1                 # sequential baseline mode
    assert s.admit() == []
    s.retire(0)
    placed = s.admit()
    assert len(placed) == 1 and placed[0][1].rid == 1


def test_fcfs_has_work():
    s = FCFSScheduler(1)
    assert not s.has_work()
    s.submit(_req(0))
    assert s.has_work()
    s.admit()
    assert s.has_work()
    s.retire(0)
    assert not s.has_work()


# ---------------------------------------------------------------------------
# live-engine lifecycle
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _family(arch, **over):
    cfg = reduced(get_config(arch), **dict(over))
    mod = family_module(cfg)
    return cfg, mod.init(cfg, KEY, tp=1)


def test_per_request_budget_retires_early():
    """A request's own max_seq budget retires it even when max_new and the
    engine-wide max_seq would allow more: prompt P=3, budget B=6 -> one
    prefill token + (B-P) decode tokens."""
    cfg, params = _family("qwen3-8b")
    eng = ServeEngine(cfg, params, slots=2, max_seq=32)
    eng.submit(_req(0, n=3, max_new=50, max_seq=6))
    eng.submit(_req(1, n=3, max_new=4))
    done = eng.run()
    assert len(done[0].out) == 1 + (6 - 3)
    assert len(done[1].out) == 4
    # prompt must leave room under its budget
    with pytest.raises(ValueError, match="room"):
        eng.submit(_req(2, n=6, max_new=2, max_seq=6))


def test_max_new_one_finishes_at_prefill():
    cfg, params = _family("qwen3-8b")
    eng = ServeEngine(cfg, params, slots=1, max_seq=16)
    eng.submit(_req(0, max_new=1))
    done = eng.run()
    assert len(done[0].out) == 1 and eng.decode_steps == 0


# ---------------------------------------------------------------------------
# staggered-admission parity (the tentpole gate)
# ---------------------------------------------------------------------------

def _reference_outputs(cfg, params, requests, max_seq):
    """Sequential single-request reference: each request decoded alone in a
    fresh one-slot engine — nothing to be polluted by."""
    out = {}
    for r in requests:
        eng = ServeEngine(cfg, params, slots=1, max_seq=max_seq)
        eng.submit(Request(r.rid, r.prompt.copy(), r.max_new))
        out[r.rid] = eng.run()[0].out
    return out


def _teacher_forced_outputs(cfg, params, requests, max_seq):
    """Independent oracle sharing NOTHING with the engine's admission path:
    no one-shot prefill, no pack_slot_cache, no slot scatter — just the
    prompt fed one token at a time through decode_step at incremental
    positions.  A bug in the prefill/ring-fold machinery would cancel out
    between the engine and the single-slot reference above; it cannot
    cancel out here."""
    import jax.numpy as jnp

    from repro.models import family_module

    mod = family_module(cfg)
    out = {}
    for r in requests:
        cache = mod.init_cache(cfg, 1, max_seq, 1)
        for t, tok in enumerate(r.prompt):
            logits, cache = mod.decode_step(
                params, cfg, cache, jnp.asarray([[tok]], jnp.int32),
                jnp.asarray([t], jnp.int32), tp=1, impl="xla")
        toks = [int(jnp.argmax(logits[0, -1]))]
        pos = len(r.prompt)
        while len(toks) < r.max_new and pos < max_seq:
            logits, cache = mod.decode_step(
                params, cfg, cache, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32), tp=1, impl="xla")
            toks.append(int(jnp.argmax(logits[0, -1])))
            pos += 1
        out[r.rid] = toks
    return out


def _make_requests(cfg, n, max_new, seed):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(2, 9)))
                    .astype(np.int32), max_new) for i in range(n)]


# admission patterns: {step index -> how many queued requests to submit}
PATTERN_BURST = {0: 6}                        # all up front, 6 reqs > 4 slots
PATTERN_TRICKLE = {0: 3, 2: 2, 5: 1}          # arrivals join mid-decode

FAMILY_CASES = [
    ("qwen3-8b", (), [PATTERN_BURST, PATTERN_TRICKLE]),
    ("rwkv6-3b", (), [PATTERN_BURST, PATTERN_TRICKLE]),
    ("zamba2-2.7b", (), [PATTERN_TRICKLE]),
    # ring-buffer sliding window smaller than most prompts: the repacked
    # ring must equal what sequential decode would have left in it
    ("gemma2-2b", (("local_window", 5), ("n_layers", 4)),
     [PATTERN_BURST, PATTERN_TRICKLE]),
]


@pytest.mark.parametrize("arch,over,patterns", FAMILY_CASES,
                         ids=[c[0] for c in FAMILY_CASES])
def test_staggered_parity_bit_identical(arch, over, patterns):
    cfg, params = _family(arch, **dict(over))
    max_seq, max_new = 32, 6
    base = _make_requests(cfg, 6, max_new, seed=1)
    ref = _reference_outputs(cfg, params, base, max_seq)
    for pattern in patterns:
        eng = ServeEngine(cfg, params, slots=4, max_seq=max_seq)
        pending = [Request(r.rid, r.prompt.copy(), r.max_new) for r in base]
        done, step = [], 0
        while pending or eng.scheduler.has_work():
            for _ in range(pattern.get(step, 0)):
                eng.submit(pending.pop(0))
            done.extend(eng.step())
            step += 1
        assert sorted(r.rid for r in done) == [r.rid for r in base]
        for r in done:
            assert r.out == ref[r.rid], \
                f"{arch}: request {r.rid} diverged under pattern {pattern}"


@pytest.mark.parametrize("arch,over", [("qwen3-8b", ()),
                                       ("gemma2-2b", (("local_window", 5),
                                                      ("n_layers", 4)))],
                         ids=["qwen3-8b", "gemma2-2b-ring"])
def test_one_shot_prefill_matches_teacher_forced_decode(arch, over):
    """The admission path (one-shot prefill + pack_slot_cache + slot
    scatter) against an oracle that never uses it: token-by-token
    teacher-forced decode.  Catches prefill/ring-fold bugs that would
    cancel out between the engine and the single-slot reference."""
    cfg, params = _family(arch, **dict(over))
    reqs = _make_requests(cfg, 4, 5, seed=2)
    oracle = _teacher_forced_outputs(cfg, params, reqs, max_seq=32)
    eng = ServeEngine(cfg, params, slots=4, max_seq=32)
    for r in reqs:
        eng.submit(r)
    for r in eng.run():
        assert r.out == oracle[r.rid], f"{arch}: request {r.rid} diverged"


def test_sequential_mode_matches_batched_outputs():
    """max_concurrency=1 (the benchmark baseline) must produce the same
    per-request outputs — batching changes wall-clock, never content."""
    cfg, params = _family("qwen3-8b")
    base = _make_requests(cfg, 5, 5, seed=3)
    copy = lambda: [Request(r.rid, r.prompt.copy(), r.max_new) for r in base]
    batched, stats_b = serve_requests(cfg, params, copy(), slots=4,
                                      max_seq=32)
    seq, stats_s = serve_requests(cfg, params, copy(), slots=4, max_seq=32,
                                  max_concurrency=1)
    assert [r.out for r in batched] == [r.out for r in seq]
    assert stats_b["generated"] == stats_s["generated"]
    assert stats_b["decode_steps"] < stats_s["decode_steps"]
