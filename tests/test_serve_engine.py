"""The continuous-batching serving engine (DESIGN.md §11).

Three layers:

  * FCFS scheduler unit tests — pure bookkeeping, no model: admission
    order, lowest-free-slot placement, slot reuse after retirement,
    concurrency caps, request validation.
  * per-request budget semantics on a live engine.
  * the staggered-admission parity gate: per-request outputs from the
    continuous-batched engine must be BIT-IDENTICAL to a sequential
    single-request reference (fresh slots=1 engine per request) for every
    admission pattern, on every decode-capable family — including a
    gemma2-style ring-buffer-window case whose prompts overflow the window.
    This is the invariant the old serving loop violated five different ways
    (shared scalar pos, zero-token prefill pollution, cross-request pos
    desync, clamped last row, stale-KV leaks).
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.paging import PriorityScheduler
from repro.launch.serve import FCFSScheduler, PagedServeEngine, Request, \
    ServeEngine, make_requests, serve_requests
from repro.models import family_module, reduced

KEY = jax.random.PRNGKey(0)


def _req(rid, n=3, max_new=4, **kw):
    return Request(rid, np.arange(1, n + 1, dtype=np.int32), max_new, **kw)


# ---------------------------------------------------------------------------
# Request validation (satellite: real next_token field, no empty prompts)
# ---------------------------------------------------------------------------

def test_request_rejects_empty_prompt():
    with pytest.raises(ValueError, match="non-empty"):
        Request(0, np.array([], np.int32), 4)
    with pytest.raises(ValueError, match="1-D"):
        Request(0, np.ones((2, 2), np.int32), 4)
    with pytest.raises(ValueError, match="max_new"):
        _req(0, max_new=0)


def test_request_next_token_is_a_real_field():
    r = _req(0)
    assert r.next_token == -1                  # not a getattr default
    assert "next_token" in {f.name for f in
                            __import__("dataclasses").fields(Request)}


# ---------------------------------------------------------------------------
# FCFS scheduler (model-free)
# ---------------------------------------------------------------------------

def test_fcfs_admission_order_and_lowest_slot_first():
    s = FCFSScheduler(3)
    for i in range(5):
        s.submit(_req(i))
    placed = s.admit()
    assert [(slot, r.rid) for slot, r in placed] == [(0, 0), (1, 1), (2, 2)]
    assert [r.rid for r in s.queue] == [3, 4]
    assert s.admit() == []                     # full: nothing placed


def test_fcfs_slot_reuse_after_retirement():
    s = FCFSScheduler(2)
    for i in range(4):
        s.submit(_req(i))
    s.admit()
    done = s.retire(0)
    assert done.rid == 0 and s.n_active == 1
    placed = s.admit()                         # rid 2 lands in freed slot 0
    assert [(slot, r.rid) for slot, r in placed] == [(0, 2)]
    s.retire(1)
    assert [(slot, r.rid) for slot, r in s.admit()] == [(1, 3)]
    s.retire(1)
    with pytest.raises(ValueError, match="not occupied"):
        s.retire(1)


def test_fcfs_concurrency_cap():
    s = FCFSScheduler(4, max_concurrency=1)
    for i in range(3):
        s.submit(_req(i))
    assert len(s.admit()) == 1                 # sequential baseline mode
    assert s.admit() == []
    s.retire(0)
    placed = s.admit()
    assert len(placed) == 1 and placed[0][1].rid == 1


def test_fcfs_has_work():
    s = FCFSScheduler(1)
    assert not s.has_work()
    s.submit(_req(0))
    assert s.has_work()
    s.admit()
    assert s.has_work()
    s.retire(0)
    assert not s.has_work()


# ---------------------------------------------------------------------------
# live-engine lifecycle
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _family(arch, **over):
    cfg = reduced(get_config(arch), **dict(over))
    mod = family_module(cfg)
    return cfg, mod.init(cfg, KEY, tp=1)


def test_per_request_budget_retires_early():
    """A request's own max_seq budget retires it even when max_new and the
    engine-wide max_seq would allow more: prompt P=3, budget B=6 -> one
    prefill token + (B-P) decode tokens."""
    cfg, params = _family("qwen3-8b")
    eng = ServeEngine(cfg, params, slots=2, max_seq=32)
    eng.submit(_req(0, n=3, max_new=50, max_seq=6))
    eng.submit(_req(1, n=3, max_new=4))
    done = eng.run()
    assert len(done[0].out) == 1 + (6 - 3)
    assert len(done[1].out) == 4
    # prompt must leave room under its budget: graceful rejection, not a
    # raise (DESIGN.md §14) — the request turns terminal immediately
    rej = _req(2, n=6, max_new=2, max_seq=6)
    assert eng.submit(rej) is False
    assert rej.status == "REJECTED"
    assert eng.run() == [rej]          # reported exactly once via run()


def test_max_new_one_finishes_at_prefill():
    cfg, params = _family("qwen3-8b")
    eng = ServeEngine(cfg, params, slots=1, max_seq=16)
    eng.submit(_req(0, max_new=1))
    done = eng.run()
    assert len(done[0].out) == 1 and eng.decode_steps == 0


# ---------------------------------------------------------------------------
# staggered-admission parity (the tentpole gate)
# ---------------------------------------------------------------------------

def _reference_outputs(cfg, params, requests, max_seq):
    """Sequential single-request reference: each request decoded alone in a
    fresh one-slot engine — nothing to be polluted by."""
    out = {}
    for r in requests:
        eng = ServeEngine(cfg, params, slots=1, max_seq=max_seq)
        eng.submit(Request(r.rid, r.prompt.copy(), r.max_new))
        out[r.rid] = eng.run()[0].out
    return out


def _teacher_forced_outputs(cfg, params, requests, max_seq):
    """Independent oracle sharing NOTHING with the engine's admission path:
    no one-shot prefill, no pack_slot_cache, no slot scatter — just the
    prompt fed one token at a time through decode_step at incremental
    positions.  A bug in the prefill/ring-fold machinery would cancel out
    between the engine and the single-slot reference above; it cannot
    cancel out here."""
    import jax.numpy as jnp

    from repro.models import family_module

    mod = family_module(cfg)
    out = {}
    for r in requests:
        cache = mod.init_cache(cfg, 1, max_seq, 1)
        for t, tok in enumerate(r.prompt):
            logits, cache = mod.decode_step(
                params, cfg, cache, jnp.asarray([[tok]], jnp.int32),
                jnp.asarray([t], jnp.int32), tp=1, impl="xla")
        toks = [int(jnp.argmax(logits[0, -1]))]
        pos = len(r.prompt)
        while len(toks) < r.max_new and pos < max_seq:
            logits, cache = mod.decode_step(
                params, cfg, cache, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray([pos], jnp.int32), tp=1, impl="xla")
            toks.append(int(jnp.argmax(logits[0, -1])))
            pos += 1
        out[r.rid] = toks
    return out


def _make_requests(cfg, n, max_new, seed):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(2, 9)))
                    .astype(np.int32), max_new) for i in range(n)]


# admission patterns: {step index -> how many queued requests to submit}
PATTERN_BURST = {0: 6}                        # all up front, 6 reqs > 4 slots
PATTERN_TRICKLE = {0: 3, 2: 2, 5: 1}          # arrivals join mid-decode

FAMILY_CASES = [
    ("qwen3-8b", (), [PATTERN_BURST, PATTERN_TRICKLE]),
    ("rwkv6-3b", (), [PATTERN_BURST, PATTERN_TRICKLE]),
    ("zamba2-2.7b", (), [PATTERN_TRICKLE]),
    # ring-buffer sliding window smaller than most prompts: the repacked
    # ring must equal what sequential decode would have left in it
    ("gemma2-2b", (("local_window", 5), ("n_layers", 4)),
     [PATTERN_BURST, PATTERN_TRICKLE]),
]


@pytest.mark.parametrize("arch,over,patterns", FAMILY_CASES,
                         ids=[c[0] for c in FAMILY_CASES])
def test_staggered_parity_bit_identical(arch, over, patterns):
    cfg, params = _family(arch, **dict(over))
    max_seq, max_new = 32, 6
    base = _make_requests(cfg, 6, max_new, seed=1)
    ref = _reference_outputs(cfg, params, base, max_seq)
    for pattern in patterns:
        eng = ServeEngine(cfg, params, slots=4, max_seq=max_seq)
        pending = [Request(r.rid, r.prompt.copy(), r.max_new) for r in base]
        done, step = [], 0
        while pending or eng.scheduler.has_work():
            for _ in range(pattern.get(step, 0)):
                eng.submit(pending.pop(0))
            done.extend(eng.step())
            step += 1
        assert sorted(r.rid for r in done) == [r.rid for r in base]
        for r in done:
            assert r.out == ref[r.rid], \
                f"{arch}: request {r.rid} diverged under pattern {pattern}"


@pytest.mark.parametrize("arch,over", [("qwen3-8b", ()),
                                       ("gemma2-2b", (("local_window", 5),
                                                      ("n_layers", 4)))],
                         ids=["qwen3-8b", "gemma2-2b-ring"])
def test_one_shot_prefill_matches_teacher_forced_decode(arch, over):
    """The admission path (one-shot prefill + pack_slot_cache + slot
    scatter) against an oracle that never uses it: token-by-token
    teacher-forced decode.  Catches prefill/ring-fold bugs that would
    cancel out between the engine and the single-slot reference."""
    cfg, params = _family(arch, **dict(over))
    reqs = _make_requests(cfg, 4, 5, seed=2)
    oracle = _teacher_forced_outputs(cfg, params, reqs, max_seq=32)
    eng = ServeEngine(cfg, params, slots=4, max_seq=32)
    for r in reqs:
        eng.submit(r)
    for r in eng.run():
        assert r.out == oracle[r.rid], f"{arch}: request {r.rid} diverged"


def test_sequential_mode_matches_batched_outputs():
    """max_concurrency=1 (the benchmark baseline) must produce the same
    per-request outputs — batching changes wall-clock, never content."""
    cfg, params = _family("qwen3-8b")
    base = _make_requests(cfg, 5, 5, seed=3)
    copy = lambda: [Request(r.rid, r.prompt.copy(), r.max_new) for r in base]
    batched, stats_b = serve_requests(cfg, params, copy(), slots=4,
                                      max_seq=32)
    seq, stats_s = serve_requests(cfg, params, copy(), slots=4, max_seq=32,
                                  max_concurrency=1)
    assert [r.out for r in batched] == [r.out for r in seq]
    assert stats_b["generated"] == stats_s["generated"]
    assert stats_b["decode_steps"] < stats_s["decode_steps"]


# ---------------------------------------------------------------------------
# Request priority validation (satellite)
# ---------------------------------------------------------------------------

def test_request_priority_validation():
    with pytest.raises(ValueError, match="priority"):
        _req(0, priority=-1)
    with pytest.raises(ValueError, match="priority"):
        _req(0, priority=1.5)
    with pytest.raises(ValueError, match="priority"):
        _req(0, priority=True)
    assert _req(0, priority=np.int64(2)).priority == 2


def test_make_requests_heterogeneous_mix():
    cfg, _ = _family("qwen3-8b")
    reqs = make_requests(cfg, 11, 6, seed=0, long_every=11,
                         long_lengths=(24, 33), priorities=(0, 2),
                         max_new_spread=2)
    assert len(reqs[10].prompt) >= 24          # every 11th is long
    assert all(len(r.prompt) < 24 for r in reqs[:10])
    assert [r.priority for r in reqs[:4]] == [0, 2, 0, 2]
    assert {r.max_new for r in reqs} <= set(range(4, 9))
    assert len({r.max_new for r in reqs}) > 1  # actually heterogeneous


# ---------------------------------------------------------------------------
# PriorityScheduler conformance (model-free)
# ---------------------------------------------------------------------------

def test_preempt_requeue_preserves_fifo_within_class():
    s = PriorityScheduler(2, age_steps=0)
    reqs = [_req(i, priority=1) for i in range(4)]
    for r in reqs:
        s.submit(r)
    assert s.place(s.peek()) == 0              # rid 0
    assert s.place(s.peek()) == 1              # rid 1
    s.preempt(0)
    # the preempted request re-enters at its original submit position:
    # ahead of rids 2/3 that were submitted after it
    assert [r.rid for r in s.queues[1]] == [0, 2, 3]
    assert s.peek().rid == 0
    assert reqs[0].preemptions == 1


def test_priority_order_fifo_within_class():
    s = PriorityScheduler(1, age_steps=0)
    for rid, prio in [(0, 2), (1, 0), (2, 2), (3, 0)]:
        s.submit(_req(rid, priority=prio))
    order = []
    while s.n_waiting:
        r = s.peek()
        s.place(r)
        order.append(r.rid)
        s.retire(0)
    assert order == [1, 3, 0, 2]               # class order, FIFO inside


def test_aging_lets_low_priority_overtake():
    s = PriorityScheduler(1, age_steps=2)
    low = _req(100, priority=3)
    s.submit(low)
    for i in range(6):
        s.submit(_req(i, priority=0))
        s.tick()
    # waited 6 ticks -> effective 3 - 6//2 = 0; oldest submit wins the tie
    assert s.effective_priority(low) == 0
    assert s.peek().rid == 100


# ---------------------------------------------------------------------------
# paged engine: admission gates, preemption, no starvation
# ---------------------------------------------------------------------------

def test_paged_admission_blocked_at_zero_pages_resumes_on_retirement():
    """Admission is driven by free pages: a free slot alone is not enough.
    r0's growth drains the pool to zero free pages; r1 (same class, so no
    preemption) must wait until r0 retires, then run to the exact same
    tokens a fresh engine would produce."""
    cfg, params = _family("qwen3-8b")
    eng = PagedServeEngine(cfg, params, slots=2, max_seq=32, page_size=4,
                           n_pages=3, prefill_chunk=16, age_steps=0)
    rng = np.random.default_rng(5)
    p0 = rng.integers(0, cfg.vocab, size=5).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab, size=7).astype(np.int32)
    eng.submit(Request(0, p0.copy(), 8))       # peak 12 rows = whole pool
    done = list(eng.step())                    # prefill commits first step
    assert not eng._prefills and eng.alloc.n_free == 1
    eng.submit(Request(1, p1.copy(), 4))       # needs 2 free pages to start
    saw_blocked_at_zero = False
    while eng.scheduler.slots[0] is not None:
        assert eng.scheduler.n_active == 1     # r1 never co-admitted
        saw_blocked_at_zero |= (eng.alloc.n_free == 0
                                and eng.scheduler.n_waiting == 1)
        done.extend(eng.step())
    assert saw_blocked_at_zero                 # the pool really hit zero
    while eng.scheduler.has_work():
        done.extend(eng.step())
    assert sorted(r.rid for r in done) == [0, 1]
    ref = ServeEngine(cfg, params, slots=1, max_seq=32)
    ref.submit(Request(1, p1.copy(), 4))
    assert next(r for r in done if r.rid == 1).out == ref.run()[0].out
    assert eng.alloc.n_free == eng.alloc.n_pages   # everything returned


def test_paged_preemption_under_pressure_is_bit_exact():
    """Tight pool + two priority classes: low-priority requests get swapped
    out under page pressure and later resumed.  Every request must still
    produce exactly the tokens a fresh single-request engine produces, and
    same-class completion follows submit order (FIFO requeue)."""
    cfg, params = _family("qwen3-8b")
    rng = np.random.default_rng(9)
    reqs = [Request(i, rng.integers(0, cfg.vocab, size=4).astype(np.int32),
                    4, priority=(0 if i % 2 == 0 else 2)) for i in range(8)]
    ref = _reference_outputs(cfg, params, reqs, max_seq=32)
    eng = PagedServeEngine(cfg, params, slots=4, max_seq=32, page_size=2,
                           n_pages=8, prefill_chunk=4, age_steps=0)
    for r in reqs:
        eng.submit(Request(r.rid, r.prompt.copy(), r.max_new,
                           priority=r.priority))
    done, finish_order = [], []
    while eng.scheduler.has_work():
        for r in eng.step():
            done.append(r)
            finish_order.append(r.rid)
    assert sorted(finish_order) == [r.rid for r in reqs]
    assert eng.preemptions > 0                 # the scenario exercised it
    for r in done:
        assert r.out == ref[r.rid], f"request {r.rid} diverged after " \
            f"{r.preemptions} preemption(s)"
    # equal prompt lengths + equal max_new: within a class, completion
    # order == admission order == submit order (FIFO requeue)
    for cls in (0, 1):
        order = [rid for rid in finish_order if rid % 2 == cls]
        assert order == sorted(order)


def test_commit_time_page_pressure_restarts_prefill_cleanly():
    """Regression: a request whose prefill finishes while the pool is too
    full to commit must requeue as a plain prefill restart.  The old path
    swapped it out through ``_preempt`` with the slot's idle ``pos``
    sentinel (``max_seq`` rows — 16 pages against an 8-page pool, so the
    request could never be admitted again: a livelock with the whole pool
    free), and its already-emitted first token would have been duplicated
    by the rerun.  Long prompts on a tight pool hit this reliably."""
    cfg, params = _family("qwen3-8b")
    reqs = make_requests(cfg, 10, 4, seed=0, long_every=3,
                         priorities=(0, 1, 2))
    ref = _reference_outputs(cfg, params, reqs, max_seq=64)
    eng = PagedServeEngine(cfg, params, slots=3, page_size=4, n_pages=8,
                           prefill_chunk=4)
    for r in reqs:
        eng.submit(Request(r.rid, r.prompt.copy(), r.max_new,
                           priority=r.priority))
    done = []
    for _ in range(200):                       # livelocked forever before
        done.extend(eng.step())
        if len(done) == len(reqs):
            break
    assert len(done) == len(reqs), (
        f"engine stalled: {len(done)}/{len(reqs)} finished, "
        f"{eng.alloc.n_free} pages free")
    assert eng.preemptions > 0                 # pressure actually fired
    for r in done:
        assert r.out == ref[r.rid] and len(r.out) == r.max_new


def test_paged_low_priority_is_not_starved():
    """Sustained high-priority load on one slot: aging must eventually
    admit (and keep, unpreempted) the low-priority request before the
    high-priority stream drains."""
    cfg, params = _family("qwen3-8b")
    eng = PagedServeEngine(cfg, params, slots=1, max_seq=32, page_size=4,
                           prefill_chunk=16, age_steps=4)
    rng = np.random.default_rng(11)
    prompt = lambda: rng.integers(0, cfg.vocab, size=3).astype(np.int32)
    low = Request(100, prompt(), 3, priority=3)
    eng.submit(low)
    finished, rid = [], 0
    for step in range(60):
        if step % 3 == 0 and rid < 10:         # two fresh highs per window
            eng.submit(Request(rid, prompt(), 3, priority=0))
            rid += 1
        finished.extend(eng.step())
        if low.rid in {r.rid for r in finished}:
            break
    assert low.rid in {r.rid for r in finished}, "low priority starved"
    unfinished_high = rid - sum(1 for r in finished if r.rid != low.rid)
    assert unfinished_high > 0 or rid < 10     # it beat part of the stream
    while eng.scheduler.has_work():            # drain; everyone completes
        finished.extend(eng.step())
    assert len(finished) == rid + 1
