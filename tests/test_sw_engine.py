"""Lock-step batched software-DSE engine (DESIGN.md §10).

Parity tier: the batched engine must reproduce ``engine="reference"``
(sequential per-search :func:`optimize`) bit-for-bit — same best schedules,
same latencies, same best-so-far curves — because every search keeps its own
RNG streams and DQN slot.  Runs across gemm/conv2d/mttkrp workloads on
heterogeneous accelerators, with and without Q-learning/EvalCache, at both
budget tiers (the full tier exercises the vmapped train scan: replay warms
past the minibatch size, so network weights actually evolve).
"""
import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.cost_model import EvalCache
from repro.core.hw_primitives import HWBuilder
from repro.core.intrinsics import ALL_INTRINSICS
from repro.core.matching import match
from repro.core.qlearning import DQN, DQNBank
from repro.core.sw_dse import (BUDGETS, SearchSpec, optimize, run_searches)


def _mixed_specs(seed: int) -> list[SearchSpec]:
    """gemm + conv2d on a GEMM array, mttkrp on a GEMV engine: one batch of
    heterogeneous (workload, intrinsic, hw) searches."""
    wl_g = W.gemm(256, 256, 128, name="g")
    wl_c = W.conv2d(32, 16, 14, 14, name="c")
    wl_m = W.mttkrp(64, 32, 64, 32, name="m")
    hw_g = (HWBuilder("GEMM").reshapeArray([16, 16], depth=16)
            .addCache(256).partitionBanks(2).build())
    hw_v = (HWBuilder("GEMV").reshapeArray([32], depth=64)
            .addCache(128).partitionBanks(2).build())
    return [
        SearchSpec(wl_g, match(ALL_INTRINSICS["GEMM"], wl_g), hw_g, seed),
        SearchSpec(wl_c, match(ALL_INTRINSICS["GEMM"], wl_c), hw_g,
                   seed + 17),
        SearchSpec(wl_m, match(ALL_INTRINSICS["GEMV"], wl_m), hw_v,
                   seed + 34),
    ]


def _assert_identical(ref, bat):
    assert len(ref) == len(bat)
    for r, b in zip(ref, bat):
        assert r.schedule == b.schedule
        assert r.latency_s == b.latency_s          # bit-exact, not approx
        assert r.evaluations == b.evaluations
        assert r.history == b.history


@pytest.mark.parametrize("seed", range(5))
def test_batched_matches_reference_small_budget(seed):
    specs = _mixed_specs(seed)
    ref = run_searches(specs, engine="reference", **BUDGETS["small"])
    bat = run_searches(specs, engine="batched", **BUDGETS["small"])
    _assert_identical(ref, bat)


@pytest.mark.parametrize("seed", [0, 3])
def test_batched_matches_reference_full_budget_with_training(seed):
    """72 transitions per search: the replay crosses the 32-sample minibatch
    threshold, so the per-search DQNs train — the vmapped scan must evolve
    each slot's weights exactly as the reference per-transition loop."""
    specs = _mixed_specs(seed)
    ref = run_searches(specs, engine="reference", **BUDGETS["full"])
    bat = run_searches(specs, engine="batched", **BUDGETS["full"])
    _assert_identical(ref, bat)


def test_batched_matches_reference_without_qlearning():
    specs = _mixed_specs(1)
    ref = run_searches(specs, engine="reference", use_qlearning=False,
                       **BUDGETS["small"])
    bat = run_searches(specs, engine="batched", use_qlearning=False,
                       **BUDGETS["small"])
    _assert_identical(ref, bat)


def test_batched_matches_reference_with_shared_cache():
    """A shared EvalCache changes who computes a report first, never its
    value — parity must survive cross-search cache hits."""
    specs = _mixed_specs(2) + _mixed_specs(2)   # duplicate searches: maximal
    ref = run_searches(specs, engine="reference",   # cache cross-talk
                       cache=EvalCache(), **BUDGETS["small"])
    bat = run_searches(specs, engine="batched", cache=EvalCache(),
                       **BUDGETS["small"])
    _assert_identical(ref, bat)


def test_single_search_equals_optimize():
    """N=1 lock-step degenerates to exactly one optimize() call."""
    sp = _mixed_specs(4)[0]
    direct = optimize(sp.workload, sp.choices, sp.hw, seed=sp.seed,
                      **BUDGETS["small"])
    [bat] = run_searches([sp], engine="batched", **BUDGETS["small"])
    _assert_identical([direct], [bat])


def test_run_searches_validates_engine_and_empty():
    assert run_searches([], engine="batched") == []
    with pytest.raises(ValueError):
        run_searches(_mixed_specs(0), engine="nope")


def test_bank_slots_match_standalone_dqns():
    """Each DQNBank slot replicates a standalone DQN(seed) bit-for-bit:
    same init, same epsilon-greedy stream, same weights after training."""
    seeds = [7, 11, 13]
    n_feat, n_act, k = 6, 5, 4
    bank = DQNBank(n_feat, n_act, seeds)
    dqns = [DQN(n_feat, n_act, seed=s) for s in seeds]
    rng = np.random.default_rng(0)
    for _ in range(12):   # 48 transitions/slot: crosses the train threshold
        feats = rng.random((len(seeds), k, n_feat)).astype(np.float32)
        acts_b = bank.select_round(feats)
        acts_r = np.stack([d.select_batch(f) for d, f in zip(dqns, feats)])
        assert np.array_equal(acts_b, acts_r)
        s2 = rng.random((len(seeds), k, n_feat)).astype(np.float32)
        rewards = rng.uniform(-1, 1, (len(seeds), k))
        for si, d in enumerate(dqns):
            for j in range(k):
                d.record(feats[si, j], int(acts_r[si, j]),
                         float(rewards[si, j]), s2[si, j])
                d.train_step()
        bank.train_round(feats, acts_b, rewards, s2)
    for si, d in enumerate(dqns):
        assert bank.eps[si] == d.eps
        assert int(np.asarray(bank.t)[si]) == d.t
    stacked = bank.params
    for li in range(len(dqns[0].params)):
        for si, d in enumerate(dqns):
            assert np.array_equal(np.asarray(stacked[li]["w"][si]),
                                  np.asarray(d.params[li]["w"]))
            assert np.array_equal(np.asarray(stacked[li]["b"][si]),
                                  np.asarray(d.params[li]["b"]))
