"""Fault tolerance (checkpoint/watchdog), data pipeline, optimizer and
gradient compression."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.ft import CheckpointManager, Watchdog
from repro.optim import AdamW, cosine_schedule
from repro.optim.compression import ef_compress, ef_init


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"w": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "step": jnp.int32(7)}}


def test_checkpoint_roundtrip_bf16(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(3, tree)
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
    got = mgr.restore(3, like=like)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(9, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 9


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _tree())
    assert not list(tmp_path.glob("tmp-*"))


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_detects_dead_and_stragglers():
    wd = Watchdog(n_workers=4, dead_after_s=10.0, straggler_factor=2.0,
                  cordon_after=2)
    now = 1000.0
    for step in range(6):
        for w in range(3):  # worker 3 never beats -> dead
            dt = 1.0 if w != 1 else (5.0 if step >= 3 else 1.0)
            wd.beat(w, step, now=now + step, step_time_s=dt)
    health = wd.check(now=now + 6)
    assert health["dead"] == [3]
    assert 1 in health["cordoned"] or 1 in health["stragglers"]
    assert 0 not in health["stragglers"]


def test_watchdog_elastic_target():
    wd = Watchdog(n_workers=8, dead_after_s=1.0)
    now = 0.0
    for w in range(6):
        wd.beat(w, 0, now=now)
    assert wd.healthy_mesh_size(8, now=0.5) == 4  # 6 healthy -> pow2 = 4


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_step_determinism():
    d = SyntheticLM(vocab=100, seq_len=8, global_batch=4, seed=3)
    a, b = d.batch(5), d.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_shifted_tokens():
    d = SyntheticLM(vocab=100, seq_len=8, global_batch=2, seed=0)
    b = d.batch(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 8)
    assert (b["tokens"] < 100).all() and (b["labels"] < 100).all()


def test_prefetcher_order():
    seen = []

    def fn(step):
        seen.append(step)
        return {"x": step}

    pf = Prefetcher(fn, start_step=2)
    s1, b1 = pf.get()
    s2, b2 = pf.get()
    assert (s1, s2) == (2, 3)
    assert b1["x"] == 2 and b2["x"] == 3


def test_data_global_arrays_shard_over_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    d = SyntheticLM(vocab=50, seq_len=4, global_batch=4, seed=1)
    arrs = d.global_arrays(0, mesh)
    assert arrs["tokens"].shape == (4, 4)
    np.testing.assert_array_equal(np.asarray(arrs["tokens"]),
                                  d.batch(0)["tokens"])


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"x": 2.0 * params["x"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 0.15


def test_adamw_clipping_bounds_update():
    opt = AdamW(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"x": jnp.array([1e4, 0.0, 0.0])}, state, params)
    assert float(gnorm) == pytest.approx(1e4)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(jnp.int32(0))) == pytest.approx(0.0)
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


def test_ef_compression_error_feedback_unbiased():
    """With constant gradients, EF-int8 compressed sums converge to the true
    sum — the residual never escapes."""
    g = {"w": jnp.asarray(np.linspace(-1, 1, 64), jnp.float32) * 0.37}
    ef = ef_init(g)
    total = jnp.zeros(64)
    for _ in range(50):
        cg, ef = ef_compress(g, ef)
        total = total + cg["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"]) * 50,
                               rtol=2e-2, atol=2e-2)
