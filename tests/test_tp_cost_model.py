"""Tensor-parallel dimension of the cost model and the codesign space.

``HWConfig.tp`` replicates the chip: peak compute and aggregate HBM scale
with the degree, area/static power scale with the chip count, and every
interface call pays a ring all-reduce of its partial outputs over
``Target.link_gbps``.  tp=1 must leave every number bit-identical to the
single-chip model (the seeded goldens enforce that side)."""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.codesign import codesign
from repro.core.cost_model import (SPATIAL, _evaluate_reference,
                                   evaluate_batch_reports)
from repro.core.hw_primitives import HWBuilder, HWConfig
from repro.core.hw_space import PARALLELISM_AXES, HWSpace
from repro.core.intrinsics import ALL_INTRINSICS
from repro.core.matching import match
from repro.core.sw_space import SoftwareSpace

REPORT_FIELDS = ("latency_s", "energy_j", "power_w", "area_um2", "flops",
                 "useful_flops", "hbm_bytes", "compute_s", "memory_s")


def _tp_space(intrinsic: str) -> HWSpace:
    base = HWSpace(intrinsic)
    return HWSpace(intrinsic, axes={**base.axes, **PARALLELISM_AXES})


def _population(wl, intrinsic, n, seed, n_hw=8):
    rng = np.random.default_rng(seed)
    choices = match(ALL_INTRINSICS[intrinsic], wl)
    hws = _tp_space(intrinsic).sample(rng, n_hw)
    assert len({h.tp for h in hws}) > 1, "population must mix TP degrees"
    space = SoftwareSpace(wl, choices, hws[0], "spatial")
    schedules = [space.random_schedule(rng) for _ in range(n)]
    hw_list = [hws[int(rng.integers(len(hws)))] for _ in range(n)]
    return hw_list, schedules


def _legal_schedule(wl, hw, seed=0):
    rng = np.random.default_rng(seed)
    choices = match(ALL_INTRINSICS[hw.intrinsic], wl)
    space = SoftwareSpace(wl, choices, hw, "spatial")
    for _ in range(64):
        s = space.random_schedule(rng)
        if math.isfinite(_evaluate_reference(wl, s, hw, "spatial").latency_s):
            return s
    raise AssertionError("no legal schedule found")


def test_hwconfig_tp_field():
    hw = HWBuilder("GEMM").reshapeArray([128, 128]).parallelize(4).build()
    assert hw.tp == 4
    assert hw.encode()[-1] == 4
    assert HWConfig().tp == 1
    with pytest.raises(ValueError):
        HWConfig(tp=0)


@pytest.mark.parametrize("target", ["spatial", "tpu"])
def test_tp_batch_matches_scalar_on_random_populations(target):
    """The scalar/batch parity contract extends to mixed-TP populations."""
    wl = W.gemm(512, 256, 128)
    hw_list, schedules = _population(wl, "GEMM", 96, seed=0)
    reports = evaluate_batch_reports(wl, hw_list, schedules, target)
    for i, (s, h) in enumerate(zip(schedules, hw_list)):
        ref = _evaluate_reference(wl, s, h, target)
        got = reports[i]
        for f in REPORT_FIELDS:
            a, b = getattr(ref, f), getattr(got, f)
            if math.isfinite(a) or math.isfinite(b):
                assert b == pytest.approx(a, rel=1e-9), \
                    f"tp={h.tp}[{i}]: {f} {a} != {b}"
            else:
                assert math.isinf(a) and math.isinf(b), f"[{i}]: {f}"
        assert ref.legal == got.legal


def test_tp_scales_area_and_charges_the_link():
    """tp=8 costs 8x the silicon; whether it *helps* latency depends
    entirely on the interconnect: a near-free link makes the 8-way chip
    faster, a dead-slow link makes the all-reduce dominate."""
    wl = W.gemm(1024, 512, 256)
    hw1 = HWConfig(intrinsic="GEMM")
    hw8 = dataclasses.replace(hw1, tp=8)
    s = _legal_schedule(wl, hw1)

    r1 = _evaluate_reference(wl, s, hw1, SPATIAL)
    r8 = _evaluate_reference(wl, s, hw8, SPATIAL)
    assert r8.area_um2 == pytest.approx(8 * r1.area_um2)

    fast = dataclasses.replace(SPATIAL, link_gbps=1e9)
    slow = dataclasses.replace(SPATIAL, link_gbps=1e-6)
    assert _evaluate_reference(wl, s, hw8, fast).latency_s \
        < _evaluate_reference(wl, s, hw1, fast).latency_s
    assert _evaluate_reference(wl, s, hw8, slow).latency_s \
        > _evaluate_reference(wl, s, hw1, slow).latency_s
    # tp=1 never touches the link: link bandwidth cannot change its cost
    assert _evaluate_reference(wl, s, hw1, slow).latency_s \
        == _evaluate_reference(wl, s, hw1, fast).latency_s


def test_codesign_tp_aware_commits_different_solution():
    """The acceptance gate: the same seeded search over (chip × TP degree)
    must commit a different, TP-aware solution than the TP-blind search —
    the interconnect term is what lets it trade chips for latency."""
    wl = W.table1_gemm()[:2]
    kw = dict(intrinsics=["GEMM"], n_trials=8, n_init=4, seed=0, q=2)
    blind = codesign(wl, **kw).solution
    aware = codesign(wl, space_axes=PARALLELISM_AXES, **kw).solution
    assert blind is not None and aware is not None
    assert blind.hw.tp == 1                    # tp is opt-in: default space
    assert aware.hw.tp > 1
    assert aware.hw.encode() != blind.hw.encode()
    assert aware.latency_s < blind.latency_s
