"""Solution-registry / tuning-DB persistence: exact round-trip recovery,
merge-on-save across apps and runs, and corrupt-artifact hardening."""
import json
import math

import pytest

from repro.core import solution as S
from repro.core import workloads as W
from repro.core.codesign import Solution
from repro.core.hw_primitives import HWBuilder
from repro.core.intrinsics import GEMM
from repro.core.matching import match
from repro.core.sw_primitives import Schedule
from repro.tuner.calibrate import Calibration, Correction
from repro.tuner.db import TuningDB, TuningRecord


def _solution(latency=1e-3, rows=32, cols=64, depth=128):
    wl = W.gemm(64, 64, 64, name="g")
    choice = match(GEMM, wl)[0]
    sched = Schedule(choice,
                     tuple(sorted((c, 32)
                                  for c in choice.mapped_compute_indices)),
                     tuple(wl.all_indices()), 0)
    hw = (HWBuilder("GEMM").reshapeArray([rows, cols], depth=depth)
          .addCache(2048).partitionBanks(2).build())
    return Solution(hw, {"g": sched}, latency, 2.0, 1e8, "GEMM")


# ---------------------------------------------------------------------------
# registry round trip + merge
# ---------------------------------------------------------------------------

def test_registry_round_trip_exact_recovery(tmp_path):
    path = tmp_path / "solutions.json"
    sol = _solution(rows=24, cols=136, depth=144)
    S.save("app1", sol, path)
    hw = S.load_hw("app1", path)
    assert hw == sol.hw                      # exact config recovery
    # kernel_blocks clamps to MXU-legal multiples of (8, 128, 128)
    assert S.kernel_blocks("app1", path) == (24, 128, 128)
    assert S.kernel_blocks("nope", path) == (256, 256, 512)


def test_registry_merge_on_save_two_apps(tmp_path):
    path = tmp_path / "solutions.json"
    S.save("app1", _solution(rows=16), path)
    S.save("app2", _solution(rows=64), path)
    assert S.load_hw("app1", path).pe_rows == 16
    assert S.load_hw("app2", path).pe_rows == 64
    data = json.loads(path.read_text())
    assert set(data) == {"app1", "app2"}
    assert "schedules" in data["app1"] and "g" in data["app1"]["schedules"]


def test_registry_corrupt_and_missing_are_nonfatal(tmp_path):
    missing = tmp_path / "absent.json"
    assert S.load_hw("x", missing) is None
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{not json!!")
    with pytest.warns(UserWarning, match="corrupt JSON"):
        assert S.load_hw("x", corrupt) is None
    assert S.kernel_blocks("x", corrupt) == (256, 256, 512)
    # a list where an object is expected is also survivable
    corrupt.write_text("[1, 2, 3]")
    with pytest.warns(UserWarning, match="expected an object"):
        assert S.load_hw("x", corrupt) is None


def test_registry_save_recovers_corrupt_file_atomically(tmp_path):
    path = tmp_path / "solutions.json"
    path.write_text("garbage{{{")
    with pytest.warns(UserWarning, match="corrupt JSON"):
        S.save("app1", _solution(), path)
    assert S.load_hw("app1", path) is not None
    assert json.loads(path.read_text())      # valid JSON again
    assert not list(tmp_path.glob("*.tmp"))  # no stray temp files


def test_registry_malformed_hw_entry_warns_and_returns_none(tmp_path):
    path = tmp_path / "solutions.json"
    path.write_text(json.dumps({"app": {"hw": {"bogus_field": 1}}}))
    with pytest.warns(UserWarning, match="malformed hw entry"):
        assert S.load_hw("app", path) is None


# ---------------------------------------------------------------------------
# tuning-DB round trip + merge-on-save
# ---------------------------------------------------------------------------

def _rec(op="gemm", shape=(64, 64, 64), measured=1e-4, app="a",
         blocks=None):
    return TuningRecord(op, shape, "float32", "interpret",
                        blocks or {"bm": 32, "bn": 32, "bk": 32},
                        measured, 2e-4, app)


def test_db_round_trip_best_config(tmp_path):
    path = tmp_path / "db.json"
    db = TuningDB(path)
    db.record(_rec(blocks={"bm": 16, "bn": 64, "bk": 32}))
    db.set_calibration(Calibration(
        {"gemm": Correction("offset", offset=1.5, n_samples=8)}))
    db.set_app("a", {"hw": {"pe_rows": 16}, "intrinsic": "GEMM"})
    db.save()

    back = TuningDB.load(path)
    assert back.best_config("gemm", (64, 64, 64)) == \
        {"bm": 16, "bn": 64, "bk": 32}           # exact config recovery
    assert back.best_config("gemm", (64, 64, 65)) is None
    assert back.best_config("gemv", (64, 64, 64)) is None
    corr = back.calibration.for_op("gemm")
    assert corr.kind == "offset" and corr.offset == 1.5 and corr.n_samples == 8
    assert back.apps["a"]["intrinsic"] == "GEMM"


def test_db_record_keeps_best_measured(tmp_path):
    db = TuningDB(tmp_path / "db.json")
    assert db.record(_rec(measured=2e-4))
    assert db.record(_rec(measured=1e-4, blocks={"bm": 64, "bn": 64,
                                                 "bk": 64}))
    assert not db.record(_rec(measured=5e-4))    # worse: rejected
    assert db.best_config("gemm", (64, 64, 64))["bm"] == 64


def test_db_merge_on_save_two_runs(tmp_path):
    """Two tuning runs (different apps/shapes) saving to one artifact
    union their records; the better measured config wins shared keys."""
    path = tmp_path / "db.json"
    run1 = TuningDB(path)
    run1.record(_rec(shape=(64, 64, 64), measured=2e-4, app="a"))
    run1.set_app("a", {"intrinsic": "GEMM"})
    run1.save()

    run2 = TuningDB(path)                        # fresh, unaware of run1
    run2.record(_rec(shape=(128, 128, 128), measured=3e-4, app="b"))
    run2.record(_rec(shape=(64, 64, 64), measured=1e-4, app="b",
                     blocks={"bm": 64, "bn": 64, "bk": 64}))
    run2.set_app("b", {"intrinsic": "GEMV"})
    run2.save()

    merged = TuningDB.load(path)
    assert set(merged.apps) == {"a", "b"}
    assert merged.best_config("gemm", (128, 128, 128)) is not None
    # run2's better measurement displaced run1's record for the shared key
    assert merged.best_config("gemm", (64, 64, 64))["bm"] == 64


def test_db_corrupt_artifact_loads_empty_with_warning(tmp_path):
    path = tmp_path / "db.json"
    path.write_text("}{ not json")
    with pytest.warns(UserWarning, match="corrupt JSON"):
        db = TuningDB.load(path)
    assert not db.records and not db.apps
    # and a save over it recovers a valid artifact
    db.record(_rec())
    db.save()
    assert TuningDB.load(path).best_config("gemm", (64, 64, 64)) is not None


def test_db_schema_invalid_sections_load_empty(tmp_path):
    """Valid JSON with wrong-typed sections (hand edits, version skew) must
    load as empty-with-warning, never raise — and a launch-time configure()
    over a malformed app entry must fall back to defaults, not crash."""
    from repro.kernels import ops

    path = tmp_path / "db.json"
    for payload in ('{"records": []}', '{"calibration": {"gemm": [1, 2]}}',
                    '{"apps": {"myapp": "oops"}}'):
        path.write_text(payload)
        with pytest.warns(UserWarning):
            db = TuningDB.load(path)
        assert not db.records and not db.apps
        assert not db.calibration.corrections

    path.write_text('{"apps": {"myapp": "oops"}}')
    ops.reset_dispatch()
    ops.set_tuning_db(path)
    try:
        with pytest.warns(UserWarning):
            assert ops.configure(app="myapp") == {}
    finally:
        ops.reset_dispatch()


def test_db_malformed_record_dropped_not_fatal(tmp_path):
    path = tmp_path / "db.json"
    good = _rec().to_dict()
    path.write_text(json.dumps({
        "version": 1,
        "records": {"bad": {"op": "gemm"},       # missing required fields
                    "gemm|64x64x64|float32|interpret": good}}))
    with pytest.warns(UserWarning, match="malformed record"):
        db = TuningDB.load(path)
    assert db.best_config("gemm", (64, 64, 64)) is not None
    assert len(db.records) == 1
