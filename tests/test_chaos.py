"""Chaos conformance suite (DESIGN.md §14).

Deterministic fault injection (``ft/inject.py``) drives failures through
the stack's real failure points, and this suite asserts the three graceful-
degradation guarantees the robustness layer promises:

  * **bit-identical survivors** — requests that complete under an injected
    fault schedule produce exactly the tokens a fault-free run produces
    (page faults degrade to preemption, per-request prefill faults are
    isolated by the per-slot position contract);
  * **leak-free pool** — after any interleaving of faults, cancellations,
    expiries, and completions the PageAllocator's free list is exactly
    restored (hypothesis widens this to random op sequences where
    installed, mirroring test_paged_kv.py);
  * **no hang** — a persistent fault schedule turns into
    :class:`EngineStalledError` via the progress watchdog, never an
    infinite loop.

Plus the rest of §14's surface: exactly-once terminal statuses, measured-
autotuning retry/quarantine, codesign kill/resume bit-identity, and the
chaos telemetry counters in the exported artifact.
"""
import functools
import json
import math

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.core import workloads as W
from repro.core.codesign import Constraints, codesign
from repro.core.hw_primitives import HWConfig
from repro.core.intrinsics import GEMM
from repro.core.matching import match
from repro.core.sw_primitives import Schedule
from repro.ft import CheckpointManager, ProgressWatchdog, inject
from repro.launch.paging import PageAllocator
from repro.launch.serve import (EngineStalledError, PagedServeEngine,
                                Request, ServeEngine, make_requests,
                                serve_requests)
from repro.models import family_module, reduced
from repro.obs.export import validate_telemetry_file
from repro.tuner import measure as M
from repro.tuner.db import TuningDB

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
    # the autouse disarm fixture is pure teardown — safe across examples
    _CHAOS_SETTINGS = dict(
        deadline=None,
        suppress_health_check=[HealthCheck.function_scoped_fixture])
except ImportError:                                # pragma: no cover - CI has it
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _no_plan_leaks():
    """A test that arms a fault plan must never leak it into the next."""
    yield
    inject.disarm()


def _req(rid, n=3, max_new=4, **kw):
    return Request(rid, np.arange(1, n + 1, dtype=np.int32), max_new, **kw)


@functools.lru_cache(maxsize=None)
def _family(arch):
    cfg = reduced(get_config(arch))
    mod = family_module(cfg)
    return cfg, mod.init(cfg, KEY, tp=1)


class _Clock:
    """Controllable engine clock: deadlines expire when the test says so."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# FaultPlan determinism (model-free)
# ---------------------------------------------------------------------------

def test_fault_schedule_is_pure_function_of_seed_site_index():
    def drive(plan):
        return [plan.fire("a") for _ in range(40)]

    assert drive(inject.FaultPlan(seed=7, rates={"a": 0.3})) == \
        drive(inject.FaultPlan(seed=7, rates={"a": 0.3}))
    assert drive(inject.FaultPlan(seed=7, rates={"a": 0.3})) != \
        drive(inject.FaultPlan(seed=8, rates={"a": 0.3}))


def test_fault_sites_have_independent_streams():
    """Interleaving calls at another site must not perturb a site's
    schedule — the property the bit-exactness gates build on."""
    lone = inject.FaultPlan(seed=3, rates={"a": 0.4})
    a_alone = [lone.fire("a") for _ in range(30)]
    mixed = inject.FaultPlan(seed=3, rates={"a": 0.4, "b": 0.9})
    a_mixed = []
    for i in range(30):
        for _ in range(i % 3):          # irregular traffic at site b
            mixed.fire("b")
        a_mixed.append(mixed.fire("a"))
    assert a_alone == a_mixed


def test_fault_exact_indices_and_cap():
    plan = inject.FaultPlan(seed=0, at={"s": [1, 4, 5]}, max_faults=2)
    hits = [i for i in range(8) if plan.fire("s")]
    assert hits == [1, 4]               # cap turned index 5 into a no-fault
    assert plan.calls["s"] == 8 and plan.fired["s"] == 2


def test_disarmed_check_is_a_noop():
    inject.disarm()
    for _ in range(5):
        inject.check("page.alloc", MemoryError)   # must not raise
    assert inject.fire("page.alloc") is False


def test_progress_watchdog_trips_only_on_flat_signature():
    dog = ProgressWatchdog(stall_limit=3)
    for sig in [(1, 0), (2, 0), (2, 0), (2, 1)]:   # progress keeps resetting
        dog.beat(sig)
    assert not dog.stalled
    for _ in range(3):
        dog.beat((2, 1))
    assert dog.stalled


# ---------------------------------------------------------------------------
# allocator leak-freedom under injected faults (model-free)
# ---------------------------------------------------------------------------

def _alloc_chaos(n_pages, page_size, seed, n_ops, rate):
    """Random alloc/free interleaving with page.alloc faults armed; the
    free list must be exactly restored once everything is freed."""
    inject.arm(seed=seed, rates={"page.alloc": rate})
    try:
        alloc = PageAllocator(n_pages, page_size)
        rng = np.random.default_rng(seed)
        live = []
        for _ in range(n_ops):
            if live and rng.random() < 0.45:
                alloc.free(live.pop(int(rng.integers(len(live)))))
            else:
                try:
                    live.append(alloc.alloc(int(rng.integers(1, 4))))
                except MemoryError:
                    continue            # injected or genuine: both recoverable
        held = [p for pages in live for p in pages]
        assert len(held) == len(set(held))          # no double allocation
        assert len(held) + alloc.n_free == n_pages  # conservation mid-run
        for pages in live:
            alloc.free(pages)
        assert alloc.n_free == alloc.n_pages
        assert alloc.free_pages == tuple(range(n_pages))
    finally:
        inject.disarm()


def test_allocator_leak_free_under_faults_deterministic():
    for seed in range(6):
        _alloc_chaos(n_pages=12, page_size=2, seed=seed, n_ops=60, rate=0.3)


if HAVE_HYPOTHESIS:

    @given(st.integers(1, 16), st.integers(1, 8),
           st.integers(0, 2**31 - 1), st.integers(1, 80))
    @settings(max_examples=40, **_CHAOS_SETTINGS)
    def test_allocator_leak_free_under_faults_hypothesis(
            n_pages, page_size, seed, n_ops):
        _alloc_chaos(n_pages, page_size, seed, n_ops, rate=0.25)


# ---------------------------------------------------------------------------
# graceful degradation without model work (fake clock; both engines)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_degraded_terminals_reported_exactly_once(paged):
    cfg, params = _family("qwen3-8b")
    clk = _Clock()
    if paged:
        eng = PagedServeEngine(cfg, params, slots=2, max_seq=16, page_size=4,
                               clock=clk)
    else:
        eng = ServeEngine(cfg, params, slots=2, max_seq=16, clock=clk)
    rej = _req(0, n=16, max_new=2)                 # prompt fills the budget
    assert eng.submit(rej) is False and rej.status == "REJECTED"
    late = _req(1, deadline_s=5.0)
    assert eng.submit(late) is True and late.deadline_at == 5.0
    vic = _req(2)
    eng.submit(vic)
    assert eng.cancel(2) is True and vic.status == "CANCELLED"
    assert eng.cancel(2) is False                  # already terminal
    assert eng.cancel(99) is False                 # unknown rid
    clk.t = 10.0                                   # past the deadline
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2]
    assert {r.rid: r.status for r in done} == \
        {0: "REJECTED", 1: "EXPIRED", 2: "CANCELLED"}
    assert eng.terminal == []                      # drained, not re-reported
    assert eng.run() == []


def test_paged_rejects_request_that_can_never_fit():
    cfg, params = _family("qwen3-8b")
    eng = PagedServeEngine(cfg, params, slots=2, max_seq=16, page_size=4,
                           n_pages=2)
    big = _req(0, n=5, max_new=10)                 # peak 14 rows > 8-row pool
    assert eng.submit(big) is False and big.status == "REJECTED"
    assert eng.alloc.n_free == eng.alloc.n_pages


def _terminal_fates(paged, fates):
    """Submit one request per fate (reject/cancel/expire) in order; drain;
    -> rid -> status.  Never admits anything, so no model work runs."""
    cfg, params = _family("qwen3-8b")
    clk = _Clock()
    eng = (PagedServeEngine(cfg, params, slots=2, max_seq=16, page_size=4,
                            clock=clk) if paged
           else ServeEngine(cfg, params, slots=2, max_seq=16, clock=clk))
    for rid, fate in enumerate(fates):
        if fate == "reject":
            eng.submit(_req(rid, n=16, max_new=2))
        elif fate == "cancel":
            eng.submit(_req(rid))
            assert eng.cancel(rid)
        else:
            eng.submit(_req(rid, deadline_s=1.0))
    clk.t = 2.0
    done = eng.run()
    assert [r.rid for r in done] == list(range(len(fates)))
    return {r.rid: r.status for r in done}


FATE_STATUS = {"reject": "REJECTED", "cancel": "CANCELLED",
               "expire": "EXPIRED"}


def test_every_fate_mix_reports_exactly_once_deterministic():
    rng = np.random.default_rng(0)
    fates = list(FATE_STATUS)
    for paged in (False, True):
        for _ in range(4):
            mix = [fates[int(i)] for i in rng.integers(0, 3, size=6)]
            got = _terminal_fates(paged, mix)
            assert got == {i: FATE_STATUS[f] for i, f in enumerate(mix)}


if HAVE_HYPOTHESIS:

    @given(st.booleans(),
           st.lists(st.sampled_from(sorted(FATE_STATUS)), min_size=1,
                    max_size=8))
    @settings(max_examples=20, **_CHAOS_SETTINGS)
    def test_every_fate_mix_reports_exactly_once_hypothesis(paged, mix):
        got = _terminal_fates(paged, mix)
        assert got == {i: FATE_STATUS[f] for i, f in enumerate(mix)}


# ---------------------------------------------------------------------------
# paged serving under chaos: bit-identical survivors, leak-free, no hang
# ---------------------------------------------------------------------------

def _copies(base):
    return [Request(r.rid, r.prompt.copy(), r.max_new, priority=r.priority)
            for r in base]


def _run_paged(cfg, params, reqs, **kw):
    eng = PagedServeEngine(cfg, params, slots=3, max_seq=32, page_size=2,
                           n_pages=12, prefill_chunk=4, age_steps=0, **kw)
    for r in reqs:
        eng.submit(r)
    return eng, eng.run()


@pytest.fixture(scope="module")
def paged_baseline():
    """Fault-free reference run: the outputs every chaos run's OK-status
    survivors are compared against, bit-for-bit."""
    cfg, params = _family("qwen3-8b")
    base = make_requests(cfg, 5, 4, seed=3, priorities=(0, 2))
    eng, done = _run_paged(cfg, params, _copies(base))
    assert all(r.status == "OK" for r in done)
    assert eng.alloc.n_free == eng.alloc.n_pages
    return cfg, params, base, {r.rid: list(r.out) for r in done}


def test_page_faults_never_change_outputs(paged_baseline):
    """Injected allocation failures degrade exactly like page pressure
    (bit-exact preempt + retry): every request still completes OK with the
    fault-free tokens, and the pool is leak-free."""
    cfg, params, base, ref = paged_baseline
    plan = inject.arm(seed=11, rates={"page.alloc": 0.3})
    try:
        eng, done = _run_paged(cfg, params, _copies(base))
    finally:
        inject.disarm()
    assert plan.total_fired > 0                    # chaos actually happened
    assert {r.rid: r.status for r in done} == {r.rid: "OK" for r in base}
    for r in done:
        assert r.out == ref[r.rid], f"request {r.rid} diverged"
    assert eng.alloc.n_free == eng.alloc.n_pages


def test_mixed_chaos_survivors_bit_identical(paged_baseline):
    """Prefill fault (per-request fail-stop) + transient decode-tick faults
    + page faults, all in one seeded plan: exactly one request FAILs, every
    survivor's output is bit-identical to the fault-free run, every request
    reaches exactly one terminal status, and nothing leaks."""
    cfg, params, base, ref = paged_baseline
    plan = inject.arm(seed=5, rates={"page.alloc": 0.15},
                      at={"serve.prefill": [1], "serve.decode": [0, 2]})
    try:
        eng, done = _run_paged(cfg, params, _copies(base))
    finally:
        inject.disarm()
    assert plan.fired.get("serve.prefill") == 1
    assert plan.fired.get("serve.decode") == 2
    statuses = [r.status for r in done]
    assert sorted(r.rid for r in done) == [r.rid for r in base]
    assert statuses.count("FAILED") == 1
    assert statuses.count("OK") == len(base) - 1
    for r in done:
        if r.status == "OK":
            assert r.out == ref[r.rid], f"survivor {r.rid} diverged"
    assert eng.alloc.n_free == eng.alloc.n_pages


def test_persistent_fault_schedule_fails_stop_not_hang():
    cfg, params = _family("qwen3-8b")
    inject.arm(seed=0, rates={"serve.decode": 1.0})
    try:
        eng = PagedServeEngine(cfg, params, slots=1, max_seq=16, page_size=4,
                               prefill_chunk=8, stall_limit=6)
        eng.submit(_req(0, n=3, max_new=3))
        with pytest.raises(EngineStalledError) as ei:
            eng.run()
    finally:
        inject.disarm()
    diag = ei.value.diagnostics
    assert diag["stall_limit"] == 6
    assert diag["active"] == {0: 0}                # the stuck request
    assert "pages_free" in diag and "preemptions" in diag


def test_serve_requests_counts_every_status_exactly_once():
    cfg, params = _family("qwen3-8b")
    reqs = make_requests(cfg, 3, 3, seed=4) + \
        [Request(3, np.arange(1, 40, dtype=np.int32), 2)]   # over budget
    done, stats = serve_requests(cfg, params, reqs, slots=2, max_seq=32)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    assert stats["status_counts"] == {"OK": 3, "REJECTED": 1}
    assert sum(stats["status_counts"].values()) == len(reqs)


# ---------------------------------------------------------------------------
# measured autotuning: bounded retry + persistent quarantine
# ---------------------------------------------------------------------------

def _gemm_candidate():
    wl = W.gemm(32, 32, 32, name="g32")
    choice = match(GEMM, wl)[0]
    tiles = tuple(sorted((c, 16) for c in choice.mapped_compute_indices))
    sched = Schedule(choice, tiles, tuple(wl.all_indices()), 0)
    hw = HWConfig(intrinsic="GEMM", pe_rows=8, pe_cols=8, pe_depth=8,
                  vmem_kib=2048)
    return wl, hw, sched


_FAST_RETRY = dict(warmup=0, repeats=1, max_retries=2,
                   retry_backoff_s=0.0)


def test_measure_retry_recovers_from_transient_fault():
    wl, hw, sched = _gemm_candidate()
    plan = inject.arm(seed=0, at={"measure.kernel": [0]})
    res = M.measure_one(wl, hw, sched, M.MeasureOptions(**_FAST_RETRY))
    assert res.ok and res.latency_s > 0
    assert plan.calls["measure.kernel"] == 2       # fault, then the retry


def test_measure_retry_exhaustion_then_quarantine_roundtrip(tmp_path):
    wl, hw, sched = _gemm_candidate()
    plan = inject.arm(seed=0, rates={"measure.kernel": 1.0})
    res = M.measure_one(wl, hw, sched, M.MeasureOptions(**_FAST_RETRY))
    inject.disarm()
    assert not res.ok and res.error_type == "InjectedFault"
    assert res.point is not None                   # timing, not lowering
    assert plan.calls["measure.kernel"] == 3       # 1 + max_retries attempts

    # retry-exhausted failures join the DB quarantine and survive a save/
    # load cycle; future measurement runs skip the candidate unrun
    key = M.quarantine_key(res.point)
    db = TuningDB(tmp_path / "db.json")
    assert db.quarantine_candidate(key, {"error_type": res.error_type})
    assert not db.quarantine_candidate(key)        # idempotent
    db.save()
    quarantined = TuningDB.load(tmp_path / "db.json").quarantined_keys()
    assert key in quarantined

    skipped = M.measure_one(wl, hw, sched, M.MeasureOptions(**_FAST_RETRY),
                            quarantine=quarantined)
    assert not skipped.ok and skipped.error_type == "Quarantined"
    assert skipped.times_s == () and skipped.elapsed_s == 0.0  # never run


def test_structural_lowering_errors_are_not_retried():
    plan = inject.arm(seed=0, rates={"measure.kernel": 1.0})
    wl, hw, sched = _gemm_candidate()
    res = M.measure_one(W.ttm(8, 8, 8, 8), hw, sched,
                        M.MeasureOptions(**_FAST_RETRY))
    assert not res.ok and "no kernel lowering" in res.error
    assert plan.calls.get("measure.kernel", 0) == 0   # never reached timing


# ---------------------------------------------------------------------------
# codesign kill/resume: bit-identical committed solution
# ---------------------------------------------------------------------------

def _mini_codesign(**kw):
    wl = [W.gemm(64, 64, 64, name="g0")]
    return codesign(wl, intrinsics=["GEMM", "DOT"], n_trials=3, n_init=2,
                    seed=0, constraints=Constraints(power_w=1e4), **kw)


def _sol_key(rep):
    s = rep.solution
    return (s.intrinsic, s.hw, s.latency_s, s.power_w,
            sorted(s.schedules.items()))


def test_codesign_kill_resume_is_bit_identical(tmp_path):
    ref = _mini_codesign()
    assert ref.solution is not None

    ckdir = tmp_path / "ck"
    full = _mini_codesign(checkpoint_dir=ckdir)
    assert _sol_key(full) == _sol_key(ref)         # checkpointing is passive
    mgr = CheckpointManager(ckdir, keep=8)
    assert mgr.payload_steps() == [1, 2]           # one per intrinsic

    # simulate a kill after the first intrinsic: drop the final checkpoint,
    # then resume — the second intrinsic re-runs, the first is restored
    (ckdir / "state-000000000002.pkl").unlink()
    resumed = _mini_codesign(resume_from=ckdir)
    assert _sol_key(resumed) == _sol_key(ref)
    assert math.isfinite(resumed.solution.latency_s)


def test_codesign_resume_rejects_foreign_checkpoint(tmp_path):
    ckdir = tmp_path / "ck"
    _mini_codesign(checkpoint_dir=ckdir)
    wl = [W.gemm(64, 64, 64, name="g0")]
    with pytest.warns(UserWarning, match="signature"):
        rep = codesign(wl, intrinsics=["GEMM", "DOT"], n_trials=3, n_init=2,
                       seed=1, constraints=Constraints(power_w=1e4),
                       resume_from=ckdir)         # different seed: fresh run
    assert rep.solution is not None


def test_codesign_resume_from_empty_dir_starts_fresh(tmp_path):
    rep = _mini_codesign(resume_from=tmp_path / "nothing-here")
    assert _sol_key(rep) == _sol_key(_mini_codesign())


# ---------------------------------------------------------------------------
# chaos telemetry: the §14 counters land in the exported artifact
# ---------------------------------------------------------------------------

def test_chaos_counters_exported_and_schema_valid(tmp_path):
    cfg, params = _family("qwen3-8b")
    obs.enable()
    try:
        inject.arm(seed=0, rates={"page.alloc": 1.0})
        with pytest.raises(MemoryError):
            PageAllocator(4, 2).alloc(1)           # -> faults.injected
        inject.disarm()

        clk = _Clock()
        eng = PagedServeEngine(cfg, params, slots=2, max_seq=16, page_size=4,
                               clock=clk)
        eng.submit(_req(0, n=16, max_new=2))       # -> requests_rejected
        eng.submit(_req(1, deadline_s=1.0))        # -> requests_expired
        eng.submit(_req(2))
        eng.cancel(2)                              # -> requests_cancelled
        clk.t = 5.0
        eng.run()

        wl, hw, sched = _gemm_candidate()
        inject.arm(seed=0, at={"measure.kernel": [0]})
        assert M.measure_one(wl, hw, sched,
                             M.MeasureOptions(**_FAST_RETRY)).ok
        inject.disarm()

        path = obs.export_telemetry(tmp_path / "telemetry.json")
        assert validate_telemetry_file(path) == []
        doc = json.loads(path.read_text())
        counters = doc["metrics"]["counters"]
        for name in ("faults.injected", "serve.requests_rejected",
                     "serve.requests_cancelled", "serve.requests_expired",
                     "tuner.measure_retries"):
            assert counters.get(name, {}).get("value", 0) >= 1, name
        events = {ev["name"] for ev in doc["trace"]["events"]}
        assert {"fault.inject", "req.degrade",
                "tuner.measure_retry"} <= events
    finally:
        obs.disable()
        inject.disarm()
