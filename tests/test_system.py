"""End-to-end system behaviour: train with failure injection + auto-resume,
batched serving, and a real multi-pod dry-run cell — each via subprocess so
device-count env vars stay isolated."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")


def run(args, timeout=540):
    env = {**os.environ, "PYTHONPATH": SRC}
    # never inherit a widened device count from in-process imports of
    # launch.dryrun; the dryrun subprocess sets its own XLA_FLAGS
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m", *args], text=True,
                          capture_output=True, timeout=timeout, env=env,
                          cwd=ROOT)


def test_train_failure_injection_and_resume(tmp_path):
    ck = str(tmp_path / "ck")
    first = run(["repro.launch.train", "--arch", "qwen3-8b", "--smoke",
                 "--steps", "12", "--checkpoint-every", "4",
                 "--checkpoint-dir", ck, "--inject-failure-at", "6"])
    assert "injected failure at step 6" in (first.stdout + first.stderr)
    second = run(["repro.launch.train", "--arch", "qwen3-8b", "--smoke",
                  "--steps", "12", "--checkpoint-every", "4",
                  "--checkpoint-dir", ck])
    assert second.returncode == 0, second.stderr[-2000:]
    assert "resumed from checkpoint step 4" in second.stdout
    assert "step   11" in second.stdout


def test_train_with_grad_compression(tmp_path):
    out = run(["repro.launch.train", "--arch", "granite-moe-3b-a800m",
               "--smoke", "--steps", "4", "--checkpoint-dir",
               str(tmp_path / "ck2"), "--grad-compression"])
    assert out.returncode == 0, out.stderr[-2000:]


def test_serve_batched_requests():
    out = run(["repro.launch.serve", "--arch", "gemma2-2b", "--smoke",
               "--requests", "5", "--slots", "3", "--max-new", "4"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "5 requests" in out.stdout


def test_serve_rejects_encoder():
    out = run(["repro.launch.serve", "--arch", "hubert-xlarge", "--smoke"])
    assert "encoder-only" in (out.stdout + out.stderr)


@pytest.mark.parametrize("mesh_flag", [[], ["--multi-pod"]])
def test_dryrun_cell_compiles(mesh_flag):
    """The real deliverable: lower+compile on the production meshes."""
    out = run(["repro.launch.dryrun", "--arch", "gemma2-2b",
               "--shape", "decode_32k", *mesh_flag])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK   gemma2-2b x decode_32k" in out.stdout


def test_dryrun_skip_reason():
    out = run(["repro.launch.dryrun", "--arch", "qwen3-8b",
               "--shape", "long_500k"])
    assert "SKIP" in out.stdout


def test_roofline_report_builds():
    art = ROOT / "artifacts" / "dryrun"
    if not any(art.glob("*.json")):
        pytest.skip("no dry-run artifacts yet")
    out = run(["repro.launch.roofline"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "dominant" in out.stdout
