"""Measured-autotuning subsystem (repro.tuner): lowering + timing,
failure capture, calibration (held-out rank improvement), tuning-DB
integration with kernel dispatch, and the measured codesign loop."""
import math

import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.codesign import Constraints, codesign
from repro.core.cost_model import evaluate_batch, evaluate_batch_reports
from repro.core.hw_primitives import HWBuilder, HWConfig
from repro.core.intrinsics import GEMM
from repro.core.matching import match
from repro.core.sw_primitives import Schedule
from repro.tuner import calibrate as C
from repro.tuner import measure as M
from repro.tuner.db import TuningDB


@pytest.fixture
def gemm64():
    wl = W.gemm(64, 64, 64, name="g64")
    return wl, match(GEMM, wl)[0]


def _hw(rows=16, cols=16, depth=16, **kw):
    kw.setdefault("vmem_kib", 2048)
    return HWConfig(intrinsic="GEMM", pe_rows=rows, pe_cols=cols,
                    pe_depth=depth, **kw)


def _sched(wl, choice, tile, order=None):
    tiles = tuple(sorted((c, tile) for c in choice.mapped_compute_indices))
    return Schedule(choice, tiles, tuple(order or wl.all_indices()), 0)


# ---------------------------------------------------------------------------
# classification + lowering
# ---------------------------------------------------------------------------

def test_classify_families():
    assert M.classify(W.gemm(8, 8, 8))[0] == "gemm"
    assert M.classify(W.gemv(8, 8))[0] == "gemv"
    assert M.classify(W.conv2d(4, 4, 6, 6))[0] == "conv2d"
    assert M.classify(W.ttm(4, 4, 4, 4)) is None     # no kernel family
    assert M.classify(W.mttkrp(4, 4, 4, 4)) is None


def test_measure_one_gemm_interpret(gemm64):
    wl, choice = gemm64
    res = M.measure_one(wl, _hw(), _sched(wl, choice, 32),
                        M.MeasureOptions(warmup=1, repeats=3))
    assert res.ok and res.latency_s > 0
    assert res.point.op == "gemm" and res.point.shape == (64, 64, 64)
    # tiles of 32 on a 16-block hw pad to 32 exactly
    assert res.point.block_map == {"bm": 32, "bn": 32, "bk": 32}
    assert len(res.times_s) == 3


def test_measure_failure_capture_no_lowering():
    wl = W.ttm(8, 8, 8, 8)
    gm = W.gemm(8, 8, 8)
    choice = match(GEMM, gm)[0]
    res = M.measure_one(wl, _hw(), _sched(gm, choice, 8))
    assert not res.ok and math.isinf(res.latency_s)
    assert "no kernel lowering" in res.error


def test_measure_batch_dedups_identical_lowerings(gemm64):
    wl, choice = gemm64
    hw = _hw()
    # two schedules, same padded blocks -> one measurement shared
    pop = [_sched(wl, choice, 32),
           _sched(wl, choice, 32, order=reversed(wl.all_indices())),
           _sched(wl, choice, 64)]
    out = M.measure_batch(wl, hw, pop, M.MeasureOptions(warmup=1, repeats=3))
    assert all(r.ok for r in out)
    assert out[0].times_s == out[1].times_s      # served from the memo
    assert out[2].point != out[0].point


# ---------------------------------------------------------------------------
# static legality gate (repro.analysis.legality ahead of lowering)
# ---------------------------------------------------------------------------

def test_measure_one_skips_statically_illegal(gemm64):
    wl, choice = gemm64
    hw = _hw(vmem_kib=16)                        # 16 KiB scratchpad
    res = M.measure_one(wl, hw, _sched(wl, choice, 64))   # 48 KiB tiles
    assert not res.ok and math.isinf(res.latency_s)
    assert res.error_type == "Illegal"
    assert res.point is None and res.times_s == ()
    assert "legality/vmem-overflow" in res.error
    # same hw point is inside the design space: a fitting tile measures
    ok = M.measure_one(wl, hw, _sched(wl, choice, 16),
                       M.MeasureOptions(warmup=1, repeats=2))
    assert ok.ok and ok.error_type == ""


def test_measure_batch_lowers_only_legal_candidates(gemm64):
    wl, choice = gemm64
    hw = _hw(vmem_kib=16)
    pop = [_sched(wl, choice, 16),               # legal
           _sched(wl, choice, 64),               # statically illegal
           _sched(wl, choice, 16,                # legal dup -> memo-served
                  order=reversed(wl.all_indices()))]
    out = M.measure_batch(wl, hw, pop, M.MeasureOptions(warmup=1, repeats=2))
    assert out[0].ok and out[2].ok
    assert out[2].times_s == out[0].times_s      # dedup still works
    assert out[1].error_type == "Illegal" and out[1].point is None
    s = M.summarize_batch(out)
    assert s["candidates"] == 3 and s["illegal"] == 1
    assert s["measured"] == 2 and s["deduped"] == 1 and s["failed"] == 0


def test_illegal_skip_never_retried_or_quarantined(gemm64, monkeypatch):
    wl, choice = gemm64
    calls = []
    monkeypatch.setattr(M, "lower",
                        lambda *a, **k: calls.append(a) or (_ for _ in ()).throw(
                            AssertionError("illegal candidate was lowered")))
    res = M.measure_one(wl, _hw(vmem_kib=16), _sched(wl, choice, 64),
                        quarantine={("gemm", (64, 64, 64))})
    assert res.error_type == "Illegal" and calls == []


def test_measure_batch_mixes_failures_and_successes(gemm64):
    wl, choice = gemm64
    good = _sched(wl, choice, 32)
    opts = M.MeasureOptions(warmup=0, repeats=1, max_block_elems=8)
    out = M.measure_batch(wl, _hw(), [good], opts)   # volume cap trips
    assert len(out) == 1 and not out[0].ok and "max_block_elems" in out[0].error


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_calibrated_model_identity_matches_evaluate_batch(gemm64):
    wl, choice = gemm64
    hw = _hw()
    pop = [_sched(wl, choice, t) for t in (16, 32, 64)]
    raw = evaluate_batch(wl, hw, pop, "tpu")
    model = C.CalibratedCostModel(C.Calibration())
    np.testing.assert_allclose(model.evaluate_batch(wl, hw, pop, "tpu"), raw)


def test_calibrated_model_offset_scales_latency_only(gemm64):
    wl, choice = gemm64
    hw = _hw()
    pop = [_sched(wl, choice, t) for t in (16, 32)]
    raw = evaluate_batch(wl, hw, pop, "tpu")
    cal = C.Calibration({"gemm": C.Correction("offset", offset=math.log(3.0),
                                              n_samples=4)})
    ys = C.CalibratedCostModel(cal).evaluate_batch(wl, hw, pop, "tpu")
    np.testing.assert_allclose(ys[:, 0], raw[:, 0] * 3.0, rtol=1e-12)
    np.testing.assert_allclose(ys[:, 1:], raw[:, 1:])


def test_fit_degrades_gracefully_with_few_samples(gemm64):
    wl, choice = gemm64
    reports = evaluate_batch_reports(wl, _hw(), [_sched(wl, choice, 32)],
                                     "tpu")
    cal = C.fit([("gemm", reports[0], 1e-3)] * 2)
    assert cal.for_op("gemm").kind == "offset"
    assert cal.for_op("gemv").kind == "identity"
    assert C.fit([]).for_op("gemm").kind == "identity"


def test_spearman_basics():
    assert C.spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert C.spearman([1, 2, 3], [3, 2, 1]) == pytest.approx(-1.0)
    assert math.isnan(C.spearman([1.0], [2.0]))


def test_calibration_improves_heldout_spearman(gemm64):
    """The acceptance gate: on a GEMM candidate population, fitting the
    per-op correction on a train split improves the Spearman rank
    correlation between predicted and *measured* (interpret-mode) latency
    on the held-out split.  The population varies hardware knobs the
    interpreter cannot see (banks, dataflow, burst) so the raw analytical
    ordering is meaningfully scrambled."""
    wl, choice = gemm64
    rng = np.random.default_rng(7)
    loops = list(choice.mapped_compute_indices)
    hws, pop = [], []
    for _ in range(48):
        hws.append(HWConfig(
            intrinsic="GEMM", pe_rows=int(rng.choice([8, 16, 32])),
            pe_cols=int(rng.choice([8, 16, 32])),
            pe_depth=int(rng.choice([8, 16, 32])),
            vmem_kib=int(rng.choice([256, 1024, 4096])),
            banks=int(rng.choice([1, 2])),
            burst_bytes=int(rng.choice([256, 1024, 4096])),
            dataflow=str(rng.choice(["OS", "WS", "IS"]))))
        tiles = tuple(sorted((c, int(rng.choice([16, 32, 64])))
                             for c in loops))
        order = list(wl.all_indices())
        rng.shuffle(order)
        pop.append(Schedule(choice, tiles, tuple(order), 0))

    reports = evaluate_batch_reports(wl, hws, pop, "tpu")
    meas = M.measure_batch(wl, hws, pop,
                           M.MeasureOptions(warmup=2, repeats=9))
    assert all(r.ok for r in meas)
    pred = np.array([r.latency_s for r in reports])
    truth = np.array([m.latency_s for m in meas])

    # two-fold cross-fit (fit on one half, score on the other, average):
    # halves the variance wall-clock rank noise injects on shared runners
    half = len(pop) // 2
    folds = [(slice(0, half), slice(half, None)),
             (slice(half, None), slice(0, half))]
    befores, afters = [], []
    for fit_sl, eval_sl in folds:
        cal = C.fit(C.collect_samples(wl, reports[fit_sl], meas[fit_sl]))
        assert cal.for_op("gemm").kind == "linear"
        corrected = C.CalibratedCostModel(cal).predict_latency(
            wl, reports[eval_sl])
        befores.append(C.spearman(pred[eval_sl], truth[eval_sl]))
        afters.append(C.spearman(corrected, truth[eval_sl]))

    before, after = float(np.mean(befores)), float(np.mean(afters))
    assert after > before, (befores, afters)
    assert after >= 0.4, (befores, afters)


# ---------------------------------------------------------------------------
# dispatch integration + measured codesign end-to-end
# ---------------------------------------------------------------------------

def test_ops_dispatch_defaults_without_db(tmp_path):
    from repro.kernels import ops

    ops.reset_dispatch()
    ops.set_tuning_db(tmp_path / "missing.json")
    try:
        blk = ops.resolve_blocks("gemm", (64, 64, 64), np.float32,
                                 "interpret", bm=None, bn=None, bk=None)
        assert blk == ops.DEFAULT_BLOCKS["gemm"]
        # explicit arguments always win
        blk = ops.resolve_blocks("gemm", (64, 64, 64), np.float32,
                                 "interpret", bm=8, bn=None, bk=None)
        assert blk["bm"] == 8
    finally:
        ops.reset_dispatch()


def test_codesign_measure_end_to_end(tmp_path):
    """codesign --measure produces a tuning DB; dispatch picks the tuned
    block shapes from it; the calibrated model is produced."""
    import jax.numpy as jnp

    from repro.kernels import ops

    db_path = tmp_path / "tuning_db.json"
    wl = [W.gemm(64, 64, 64, name="g0")]
    rep = codesign(wl, intrinsics=["GEMM"], n_trials=4, n_init=2, seed=0,
                   target="tpu", measure=True, measure_top_k=2,
                   measure_opts=M.MeasureOptions(warmup=1, repeats=3),
                   db_path=db_path, app="e2e")
    assert rep.solution is not None
    assert math.isfinite(rep.solution.latency_s)
    assert rep.measured and rep.measured["GEMM"]["measured"] > 0
    # the mixed-total flag always rides the summary; a winner measured on
    # every workload must report False (no analytical stand-ins inside)
    s = rep.measured["GEMM"]
    assert "best_has_fallbacks" in s
    assert isinstance(s["best_has_fallbacks"], bool)
    if s["fallbacks"] == 0:
        assert s["best_has_fallbacks"] is False
    assert rep.calibration is not None and rep.calibration.corrections

    # the DB landed, with a gemm record for the workload's shape + the app
    db = TuningDB.load(db_path)
    blocks = db.best_config("gemm", (64, 64, 64), "float32", "interpret")
    assert blocks and set(blocks) == {"bm", "bn", "bk"}
    assert "e2e" in db.apps and db.calibration.corrections

    # dispatch resolves exactly those measured-best blocks
    ops.reset_dispatch()
    ops.set_tuning_db(db_path)
    try:
        resolved = ops.resolve_blocks("gemm", (64, 64, 64), jnp.float32,
                                      "interpret", bm=None, bn=None, bk=None)
        assert resolved == blocks
        # and the kernel actually runs with them
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        got = ops.matmul(a, b, implementation="interpret")
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(a) @ np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
        # app-level startup pickup (serve/train path)
        installed = ops.configure(app="e2e", db_path=db_path)
        assert installed and set(installed) == set(ops.DEFAULT_BLOCKS)
    finally:
        ops.reset_dispatch()


def test_measure_rerank_flags_mixed_totals(monkeypatch):
    """Regression: when the winning candidate's total contains analytical
    stand-ins (measurement failed / no lowering), the summary must say so —
    best_measured_total_s is then NOT wall-clock truth."""
    from repro.tuner import measure as M_

    def always_fail(w, hw, sched, opts, quarantine=None):
        return M_.MeasureResult(latency_s=math.inf, error="forced failure")

    monkeypatch.setattr(M_, "measure_one", always_fail)
    wl = [W.gemm(64, 64, 64, name="g0")]
    rep = codesign(wl, intrinsics=["GEMM"], n_trials=4, n_init=2, seed=0,
                   target="tpu", measure=True, measure_top_k=2,
                   measure_opts=M.MeasureOptions(warmup=1, repeats=1))
    s = rep.measured["GEMM"]
    assert s["measured"] == 0 and s["fallbacks"] > 0
    assert s["best_has_fallbacks"] is True


def test_codesign_without_measure_unchanged(tmp_path):
    """measure=False keeps the analytical path and writes nothing."""
    wl = [W.gemm(64, 64, 64, name="g0")]
    rep = codesign(wl, intrinsics=["GEMM"], n_trials=3, n_init=2, seed=0)
    assert rep.measured is None and rep.calibration is None
    assert rep.db_path is None
    assert not list(tmp_path.iterdir())
