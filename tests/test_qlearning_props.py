"""Property tests for the DQN machinery (hypothesis-gated, like
test_pareto_mobo.py's property tier): Replay ring-buffer invariants and
epsilon-greedy ``select_batch`` bounds."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.qlearning import DQN, Replay


@st.composite
def replay_runs(draw):
    capacity = draw(st.integers(min_value=1, max_value=16))
    n_add = draw(st.integers(min_value=1, max_value=40))
    d = draw(st.integers(min_value=1, max_value=6))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    return capacity, n_add, d, seed


def _fill(capacity: int, n_add: int, d: int):
    """Add n_add distinguishable transitions; returns (replay, transitions)."""
    rep = Replay(capacity)
    trans = []
    for i in range(n_add):
        s = np.full(d, float(i), np.float32)
        s2 = np.full(d, float(-i), np.float32)
        rep.add(s, i, 0.5 * i, s2, done=(i % 2 == 0))
        trans.append((s, i, 0.5 * i, s2, float(i % 2 == 0)))
    return rep, trans


@given(replay_runs())
@settings(max_examples=60, deadline=None)
def test_replay_wraparound_keeps_last_capacity_items(run):
    capacity, n_add, d, _ = run
    rep, trans = _fill(capacity, n_add, d)
    assert rep.n == min(n_add, capacity)
    assert rep.ptr == n_add % capacity
    # the ring holds exactly the most recent `capacity` transitions, each at
    # index (insertion order) % capacity
    for age in range(rep.n):
        i = n_add - 1 - age                       # original insertion index
        s, a, r, s2, done = trans[i]
        slot = i % capacity
        assert np.array_equal(rep.s[slot], s)
        assert rep.a[slot] == a
        assert rep.r[slot] == np.float32(r)
        assert np.array_equal(rep.s2[slot], s2)
        assert rep.done[slot] == done


@given(replay_runs())
@settings(max_examples=60, deadline=None)
def test_replay_sample_only_returns_stored_transitions(run):
    capacity, n_add, d, seed = run
    rep, trans = _fill(capacity, n_add, d)
    rng = np.random.default_rng(seed)
    s, a, r, s2, done = rep.sample(rng, batch=8)
    live = {int(rep.a[i]) for i in range(rep.n)}   # actions id transitions
    for j in range(8):
        assert int(a[j]) in live                  # n < capacity: only the
        i = int(a[j])                             # filled region is sampled
        assert np.array_equal(s[j], trans[i][0])
        assert np.array_equal(s2[j], trans[i][3])
        assert r[j] == np.float32(trans[i][2])


@given(replay_runs())
@settings(max_examples=40, deadline=None)
def test_replay_dtype_and_shape_invariants(run):
    capacity, n_add, d, _ = run
    rep, _ = _fill(capacity, n_add, d)
    assert rep.s.shape == (capacity, d) and rep.s.dtype == np.float32
    assert rep.s2.shape == (capacity, d) and rep.s2.dtype == np.float32
    assert rep.a.shape == (capacity,) and rep.a.dtype == np.int32
    assert rep.r.shape == (capacity,) and rep.r.dtype == np.float32
    assert rep.done.shape == (capacity,) and rep.done.dtype == np.float32
    assert 0 <= rep.ptr < capacity and 0 < rep.n <= capacity


@given(st.integers(min_value=1, max_value=12),      # batch size
       st.integers(min_value=2, max_value=9),       # n_actions
       st.floats(min_value=0.0, max_value=1.0),     # epsilon
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_select_batch_explore_respects_action_bounds(b, n_actions, eps, seed):
    dqn = DQN(n_features=5, n_actions=n_actions, hidden=8, seed=seed)
    dqn.eps = eps
    feats = np.random.default_rng(seed).random((b, 5)).astype(np.float32)
    acts = dqn.select_batch(feats)
    assert acts.shape == (b,)
    assert np.all((acts >= 0) & (acts < n_actions))


@given(st.integers(min_value=1, max_value=12),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_select_batch_greedy_when_no_exploration(b, seed):
    """eps=0: the explore mask is all-False, so every action is the argmax
    of that state's Q-row (one forward for the whole batch)."""
    dqn = DQN(n_features=5, n_actions=7, hidden=8, seed=seed)
    dqn.eps = 0.0
    feats = np.random.default_rng(seed).random((b, 5)).astype(np.float32)
    acts = dqn.select_batch(feats)
    assert np.array_equal(acts, np.argmax(dqn.q_values_batch(feats), axis=1))
