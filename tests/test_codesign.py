"""End-to-end HASCO co-design flow (tiny budgets) + paper baselines."""
import math

import pytest

from repro.core import workloads as W
from repro.core.codesign import (Constraints, codesign, library_schedule,
                                 separate_design, template_search)
from repro.core.hw_primitives import HWBuilder
from repro.core.intrinsics import GEMM
from repro.core.matching import match


@pytest.fixture(scope="module")
def report():
    wl = [W.conv2d(64, 32, 28, 28, name="c0"), W.gemm(256, 256, 128, name="g0")]
    return wl, codesign(wl, intrinsics=["GEMM"], n_trials=12, n_init=4,
                        seed=0, constraints=Constraints(power_w=1e4))


def test_codesign_produces_holistic_solution(report):
    wl, rep = report
    assert rep.solution is not None
    sol = rep.solution
    # one accelerator shared by the application, one schedule per workload
    assert set(sol.schedules) == {"c0", "g0"}
    assert sol.intrinsic == "GEMM"
    assert math.isfinite(sol.latency_s) and sol.power_w <= 1e4
    assert rep.partition_sizes[("c0", "GEMM")] > 0


def test_codesign_beats_separate_design(report):
    """Co-design must beat the decoupled flow with *untuned* software
    outright, and stay competitive (<=1.2x) with its software-tuned variant
    under this test's tiny 12-trial DSE budget (stochastic search)."""
    wl, rep = report
    base_hw = (HWBuilder("GEMM").reshapeArray([16, 16], depth=16)
               .addCache(256).partitionBanks(1).build())
    sep_untuned = separate_design(wl, base_hw, tuned_software=False, seed=0)
    sep_tuned = separate_design(wl, base_hw, tuned_software=True, seed=0)
    assert rep.solution.latency_s <= sep_untuned.latency_s
    assert rep.solution.latency_s <= 1.2 * sep_tuned.latency_s


def test_library_im2col_overhead_positive():
    conv = W.conv2d(64, 64, 28, 28)
    hw = (HWBuilder("GEMM").reshapeArray([16, 16], depth=16)
          .addCache(512).partitionBanks(2).build())
    _, lat, overhead = library_schedule(conv, hw)
    assert overhead > 0 and lat > overhead


def test_template_search_fixed_choice_and_order():
    wl = W.gemm(256, 256, 256)
    hw = (HWBuilder("GEMM").reshapeArray([16, 16], depth=16)
          .addCache(256).partitionBanks(2).build())
    choice = match(GEMM, wl)[0]
    s = template_search(wl, choice, hw, seed=0, budget=16)
    assert s.choice == choice
    assert s.order == tuple(wl.all_indices())  # template never reorders


def test_infeasible_constraints_yield_none():
    wl = [W.gemm(128, 128, 128, name="g")]
    rep = codesign(wl, intrinsics=["GEMM"], n_trials=4, n_init=2, seed=1,
                   constraints=Constraints(latency_s=1e-30))
    assert rep.solution is None
