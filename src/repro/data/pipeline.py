"""Deterministic synthetic LM data pipeline.

Design points that matter at scale (and are unit-tested here):
  * step-indexed determinism — batch(step) is a pure function of (seed, step),
    so restarts/elastic re-meshes resume bit-identically with no data state
    to checkpoint;
  * per-host sharding — each process materializes only its addressable slice
    (``jax.make_array_from_callback``), never the global batch;
  * background prefetch of the next batch while the step runs.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass
class SyntheticLM:
    """Zipf-ish synthetic token stream with next-token labels."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def _host_batch(self, step: int, lo: int, hi: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, lo]))
        n = hi - lo
        # zipf-like marginal over the vocabulary, cheap and deterministic
        u = rng.random((n, self.seq_len + 1))
        toks = np.minimum((self.vocab * u ** 2.2).astype(np.int32),
                          self.vocab - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batch(self, step: int) -> dict[str, np.ndarray]:
        return self._host_batch(step, 0, self.global_batch)

    def global_arrays(self, step: int, mesh,
                      batch_axes=("pod", "data")) -> dict[str, jax.Array]:
        """Distributed batch: every process fills only its slice."""
        axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        spec = P(axes, None)
        out = {}
        for name in ("tokens", "labels"):
            sharding = NamedSharding(mesh, spec)

            def cb(index, name=name):
                rows = index[0]
                lo = rows.start or 0
                hi = rows.stop if rows.stop is not None else self.global_batch
                return self._host_batch(step, lo, hi)[name]

            out[name] = jax.make_array_from_callback(
                (self.global_batch, self.seq_len), sharding, cb)
        return out


def make_global_batch(source: SyntheticLM, mesh, step: int):
    return source.global_arrays(step, mesh)


class Prefetcher:
    """One-deep background prefetch of batch(step+1)."""

    def __init__(self, fn, start_step: int = 0):
        self._fn = fn
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._next = start_step
        self._push()

    def _push(self):
        step = self._next
        self._next += 1
        t = threading.Thread(target=lambda: self._q.put((step, self._fn(step))),
                             daemon=True)
        t.start()

    def get(self):
        step, batch = self._q.get()
        self._push()
        return step, batch
