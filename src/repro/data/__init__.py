"""Data substrate: deterministic synthetic token pipeline, multi-host aware
sharded batching with background prefetch."""

from .pipeline import SyntheticLM, make_global_batch

__all__ = ["SyntheticLM", "make_global_batch"]
