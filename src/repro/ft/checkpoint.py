"""Checkpointing for restart + elastic re-meshing.

  * atomic: writes go to ``<dir>/tmp-<step>`` then os.rename to ``step-<n>``
    — a killed writer never corrupts the latest checkpoint;
  * mesh-agnostic: leaves are stored as host numpy (one .npy per leaf path),
    restore re-shards onto *whatever mesh the new job brings up* via
    NamedSharding — elastic scaling = checkpoint/restore across mesh shapes;
  * async: ``save(..., blocking=False)`` snapshots to host then writes in a
    background thread so the step loop keeps running;
  * retention: keeps the last ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

SEP = "__"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(_pretty(p) for p in path)
        out[key] = leaf
    return out


def _pretty(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        if blocking:
            self._write(step, host)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict[str, np.ndarray]) -> None:
        tmp = self.dir / f"tmp-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {}
        for key, arr in host.items():
            fname = f"{abs(hash(key)) % 10**12}_{len(manifest)}.npy"
            np.save(tmp / fname, arr)
            manifest[key] = {"file": fname, "shape": list(arr.shape),
                             "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "leaves": manifest}))
        final = self.dir / f"step-{step:012d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step-{s:012d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("-")[1]) for p in self.dir.glob("step-*"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, mesh=None, specs: Any = None) -> Any:
        """Restore into the structure of ``like``; if (mesh, specs) given,
        leaves are placed as NamedSharding arrays on the *current* mesh —
        this is the elastic-re-mesh path."""
        d = self.dir / f"step-{step:012d}"
        manifest = json.loads((d / "manifest.json").read_text())["leaves"]

        flat_like, tree = jax.tree_util.tree_flatten_with_path(like)
        flat_specs = (jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            if specs is not None else [None] * len(flat_like))
        out = []
        for (path, leaf), spec in zip(flat_like, flat_specs):
            key = SEP.join(_pretty(p) for p in path)
            arr = np.load(d / manifest[key]["file"])
            want = manifest[key]["dtype"]
            if str(arr.dtype) != want:  # bf16 etc. round-trip as void
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
            if mesh is not None and spec is not None:
                sharding = jax.sharding.NamedSharding(mesh, spec)
                arr = jax.device_put(arr, sharding)
            else:
                arr = jax.numpy.asarray(arr)
            out.append(arr)
        return jax.tree_util.tree_unflatten(tree, out)
