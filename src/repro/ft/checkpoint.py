"""Checkpointing for restart + elastic re-meshing (DESIGN.md §14).

  * atomic: array checkpoints go to ``<dir>/tmp-<step>`` then os.rename to
    ``step-<n>`` — a killed writer never corrupts the latest checkpoint;
    manifests and payloads go through ``core/artifacts.py``'s shared
    atomic writer (tmp file + rename, fault-injectable);
  * corrupt-safe: a corrupt or partial checkpoint is *skipped with a
    warning*, never fatal — ``restore``/``restore_payload`` fall back to
    the newest older checkpoint that loads cleanly, and a failed ``save``
    warns and keeps the previous checkpoint intact;
  * mesh-agnostic: leaves are stored as host numpy (one .npy per leaf path),
    restore re-shards onto *whatever mesh the new job brings up* via
    NamedSharding — elastic scaling = checkpoint/restore across mesh shapes;
  * async: ``save(..., blocking=False)`` snapshots to host then writes in a
    background thread so the step loop keeps running;
  * retention: keeps the last ``keep`` checkpoints;
  * payloads: ``save_payload``/``restore_payload`` checkpoint one pickled
    Python object per step (``state-<n>.pkl``) — the co-design driver's
    resume state (MOBO observations, DSE round state, EvalCache contents)
    rides this path.
"""
from __future__ import annotations

import os
import pickle
import shutil
import threading
import warnings
from pathlib import Path
from typing import Any

import jax
import numpy as np

SEP = "__"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(_pretty(p) for p in path)
        out[key] = leaf
    return out


def _pretty(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        if blocking:
            self._write(step, host)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict[str, np.ndarray]) -> None:
        from repro.core.artifacts import atomic_write_json

        tmp = self.dir / f"tmp-{step}"
        try:
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {}
            for key, arr in host.items():
                fname = f"{abs(hash(key)) % 10**12}_{len(manifest)}.npy"
                np.save(tmp / fname, arr)
                manifest[key] = {"file": fname, "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
            atomic_write_json(tmp / "manifest.json",
                              {"step": step, "leaves": manifest})
            final = self.dir / f"step-{step:012d}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
        except OSError as e:
            # a flaky disk must not take down the run: the previous
            # checkpoint is still intact (nothing was renamed over it)
            warnings.warn(f"checkpoint step {step} -> {self.dir}: write "
                          f"failed ({e}); keeping previous checkpoint",
                          stacklevel=2)
            shutil.rmtree(tmp, ignore_errors=True)
            return
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step-{s:012d}", ignore_errors=True)
        psteps = sorted(self.payload_steps())
        for s in psteps[: -self.keep]:
            (self.dir / f"state-{s:012d}.pkl").unlink(missing_ok=True)

    # -- payload checkpoints (one pickled object per step) ---------------------
    def save_payload(self, step: int, obj: Any) -> Path | None:
        """Atomically persist one pickled object as this step's payload
        checkpoint; warns and returns ``None`` (previous payloads intact)
        when the write fails."""
        from repro.core.artifacts import atomic_write_bytes

        path = self.dir / f"state-{step:012d}.pkl"
        try:
            atomic_write_bytes(path, pickle.dumps(obj))
        except (OSError, pickle.PicklingError) as e:
            warnings.warn(f"payload checkpoint step {step} -> {path}: write "
                          f"failed ({e}); keeping previous checkpoints",
                          stacklevel=2)
            return None
        self._gc()
        return path

    def payload_steps(self) -> list[int]:
        return sorted(int(p.stem.split("-")[1])
                      for p in self.dir.glob("state-*.pkl"))

    def restore_payload(self, step: int | None = None) -> Any | None:
        """Unpickle the payload at ``step`` (default: newest).  A corrupt,
        partial, or unreadable payload is skipped with a warning and the
        next older one is tried; ``None`` when nothing loads cleanly."""
        from repro.core.artifacts import read_bytes_safe

        steps = self.payload_steps()
        if step is not None:
            steps = [s for s in steps if s <= step]
        for s in reversed(steps):
            path = self.dir / f"state-{s:012d}.pkl"
            raw = read_bytes_safe(path, "payload checkpoint")
            if raw is None:
                continue
            try:
                return pickle.loads(raw)
            except Exception as e:  # corrupt pickle: skip, try older
                warnings.warn(f"payload checkpoint {path}: corrupt ({e}); "
                              f"skipping", stacklevel=2)
        return None

    # -- restore ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("-")[1])
                      for p in self.dir.glob("step-*"))

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, mesh=None, specs: Any = None) -> Any:
        """Restore into the structure of ``like``; if (mesh, specs) given,
        leaves are placed as NamedSharding arrays on the *current* mesh —
        this is the elastic-re-mesh path.

        A corrupt or partial checkpoint at ``step`` is skipped with a
        warning and the newest older step is tried; ``None`` when no
        checkpoint restores cleanly (callers start fresh)."""
        for s in reversed([x for x in self.all_steps() if x <= step]):
            try:
                return self._restore_step(s, like, mesh, specs)
            except Exception as e:   # missing leaves, torn npy, bad manifest
                warnings.warn(f"checkpoint step {s} in {self.dir}: corrupt "
                              f"or partial ({e}); skipping", stacklevel=2)
        return None

    def _restore_step(self, step: int, like: Any, mesh, specs: Any) -> Any:
        from repro.core.artifacts import read_json_object

        d = self.dir / f"step-{step:012d}"
        doc = read_json_object(d / "manifest.json", "checkpoint manifest")
        if not doc:
            raise ValueError("missing or corrupt manifest")
        manifest = doc["leaves"]

        flat_like, tree = jax.tree_util.tree_flatten_with_path(like)
        flat_specs = (jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            if specs is not None else [None] * len(flat_like))
        out = []
        for (path, leaf), spec in zip(flat_like, flat_specs):
            key = SEP.join(_pretty(p) for p in path)
            arr = np.load(d / manifest[key]["file"])
            want = manifest[key]["dtype"]
            if str(arr.dtype) != want:  # bf16 etc. round-trip as void
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
            if mesh is not None and spec is not None:
                sharding = jax.sharding.NamedSharding(mesh, spec)
                arr = jax.device_put(arr, sharding)
            else:
                arr = jax.numpy.asarray(arr)
            out.append(arr)
        return jax.tree_util.tree_unflatten(tree, out)
