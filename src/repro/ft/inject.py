"""Deterministic fault injection (DESIGN.md §14).

A seeded, process-global :class:`FaultPlan` drives failures through *named
injection sites* registered at the stack's real failure points — the page
allocator, the kernel measurement path, artifact I/O, and the paged serving
engine's prefill/decode ticks.  The design mirrors the ``obs`` singleton
(DESIGN.md §13): off by default, one module-level guarded global, and
allocation-free when disarmed — :func:`check` is a single global read plus
an ``is None`` test on the hot path.

Determinism contract: a site's failure schedule is a pure function of
``(plan seed, site name, per-site call index)``.  Each site owns an
independent RNG stream (seeded from the plan seed and a CRC of the site
name), advanced once per :func:`check` at that site, so adding calls at one
site never perturbs another site's schedule, and two runs with the same
plan + same call sequence inject byte-identical fault patterns — the
property the chaos conformance suite (``tests/test_chaos.py``) builds its
bit-exactness gates on.

Usage::

    from repro.ft import inject

    # at a failure point (library code):
    inject.check("page.alloc", MemoryError)     # no-op unless armed

    # in a chaos test / driver:
    inject.arm(seed=7, rates={"page.alloc": 0.2}, at={"serve.decode": [3]})
    try:
        ...                                      # run the system
    finally:
        inject.disarm()

Sites raise *realistic* exception types (``MemoryError`` for the allocator,
``OSError`` for artifact I/O) so the degradation paths exercised by
injection are exactly the ones real faults would take; sites with no
realistic type raise :class:`InjectedFault` so handlers can be precise.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro import obs

__all__ = ["FaultPlan", "InjectedFault", "arm", "disarm", "plan", "check",
           "fire"]


class InjectedFault(RuntimeError):
    """An injected failure with no more realistic exception type (e.g. a
    serving-tick fault).  Handlers that must distinguish injected faults
    from genuine bugs catch exactly this."""


class FaultPlan:
    """Seeded per-site failure schedules.

    ``rates`` maps site name -> per-call failure probability (drawn from
    the site's own RNG stream); ``at`` maps site name -> explicit 0-based
    call indices that must fail (exact, rate-independent).  Both may be
    given for the same site; a call fails if either schedules it.
    ``max_faults`` optionally caps the total injected faults, turning an
    aggressive rate into a transient burst.
    """

    def __init__(self, seed: int = 0, rates: dict[str, float] | None = None,
                 at: dict[str, object] | None = None,
                 max_faults: int | None = None):
        self.seed = int(seed)
        self.rates = {str(k): float(v) for k, v in (rates or {}).items()}
        for site, r in self.rates.items():
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"site {site!r}: rate {r} not in [0, 1]")
        self.at = {str(k): frozenset(int(i) for i in v)
                   for k, v in (at or {}).items()}
        self.max_faults = max_faults
        self.calls: dict[str, int] = {}     # site -> calls seen
        self.fired: dict[str, int] = {}     # site -> faults injected
        self._rngs: dict[str, np.random.Generator] = {}

    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    def _rng(self, site: str) -> np.random.Generator:
        rng = self._rngs.get(site)
        if rng is None:
            rng = np.random.default_rng(
                (self.seed, zlib.crc32(site.encode("utf-8"))))
            self._rngs[site] = rng
        return rng

    def fire(self, site: str) -> bool:
        """Advance ``site``'s schedule one call; True when this call must
        fail.  The rate stream is drawn on *every* call at a rated site so
        the schedule depends only on the call index, never on what other
        sites did in between."""
        n = self.calls.get(site, 0)
        self.calls[site] = n + 1
        hit = False
        rate = self.rates.get(site, 0.0)
        if rate > 0.0 and self._rng(site).random() < rate:
            hit = True
        if site in self.at and n in self.at[site]:
            hit = True
        if hit and self.max_faults is not None \
                and self.total_fired >= self.max_faults:
            hit = False
        if hit:
            self.fired[site] = self.fired.get(site, 0) + 1
            st = obs.state()
            if st is not None:
                st.metrics.counter("faults.injected").inc()
                st.tracer.instant("fault.inject",
                                  {"site": site, "call": n})
        return hit

    def summary(self) -> dict:
        return {"seed": self.seed, "calls": dict(self.calls),
                "fired": dict(self.fired)}


_PLAN: FaultPlan | None = None


def arm(plan: FaultPlan | None = None, **kwargs) -> FaultPlan:
    """Install a fault plan (replacing any previous one).  Either pass a
    prepared :class:`FaultPlan` or keyword arguments for its constructor."""
    global _PLAN
    if plan is not None and kwargs:
        raise ValueError("pass either a FaultPlan or constructor kwargs")
    _PLAN = plan if plan is not None else FaultPlan(**kwargs)
    return _PLAN


def disarm() -> None:
    """Back to no-fault mode (the default)."""
    global _PLAN
    _PLAN = None


def plan() -> FaultPlan | None:
    """The armed plan, or ``None`` — THE guard every site checks."""
    return _PLAN


def fire(site: str) -> bool:
    """True when the armed plan schedules a fault at this call of ``site``
    (and records it); always False when disarmed."""
    p = _PLAN
    if p is None:
        return False
    return p.fire(site)


def check(site: str, exc: type[BaseException] = InjectedFault) -> None:
    """Raise ``exc`` when the armed plan schedules a fault here; the
    disarmed fast path is one global read + ``is None``."""
    p = _PLAN
    if p is not None and p.fire(site):
        raise exc(f"injected fault at {site!r} "
                  f"(call {p.calls[site] - 1}, seed {p.seed})")
