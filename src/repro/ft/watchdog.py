"""Watchdogs: heartbeat (node failure / stragglers) and no-progress.

:class:`Watchdog` is the cluster heartbeat: on a real cluster each host
runs ``beat()`` per step; the (replicated) controller calls ``check()`` to
classify workers as healthy / straggler / dead and decides mitigation:

  * dead worker        -> restart from the latest checkpoint, possibly on a
                          smaller mesh (elastic: CheckpointManager reshards);
  * straggler          -> first re-dispatch its shard (backup-task policy);
                          repeated offenders are cordoned.

:class:`ProgressWatchdog` is the single-process complement (DESIGN.md §14):
a step-counted stall detector the serving engines feed a *progress
signature* every tick.  When the signature stops changing for
``stall_limit`` consecutive beats, the engine converts its would-be
infinite ``run()`` loop into a diagnosable fail-stop instead of a hang —
the chaos suite's "no schedule hangs" guarantee.

The control logic is deterministic and fully unit-tested; the container has
one host, so launch/train.py exercises it with simulated failures
(--inject-failure-at).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class ProgressWatchdog:
    """Fail-stop guard over a monotone progress signature.

    ``beat(signature)`` returns the number of consecutive beats the
    signature has been unchanged; :attr:`stalled` trips at
    ``stall_limit``.  The signature should capture *real* forward progress
    (tokens produced, requests reaching a terminal state) — deliberately
    NOT churn like preemption counts, which increment forever in exactly
    the livelocks this guard exists to catch (the PR-7 commit-pressure
    livelock spun on preempt/requeue with the whole pool free).
    """

    stall_limit: int = 256
    stalled_for: int = 0
    _last: object = None

    def beat(self, signature: object) -> int:
        if signature != self._last:
            self._last = signature
            self.stalled_for = 0
        else:
            self.stalled_for += 1
        return self.stalled_for

    @property
    def stalled(self) -> bool:
        return self.stalled_for >= self.stall_limit


@dataclass
class WorkerState:
    last_beat: float
    last_step: int
    slow_count: int = 0
    cordoned: bool = False


@dataclass
class Watchdog:
    n_workers: int
    dead_after_s: float = 60.0
    straggler_factor: float = 2.0   # slower than factor x median step time
    cordon_after: int = 3
    workers: dict[int, WorkerState] = field(default_factory=dict)
    step_times: list[float] = field(default_factory=list)

    def beat(self, worker: int, step: int, now: float | None = None,
             step_time_s: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        st = self.workers.setdefault(worker, WorkerState(now, step))
        st.last_beat, st.last_step = now, step
        if step_time_s is not None:
            self.step_times.append(step_time_s)
            med = self.median_step_time()
            if med != float("inf") and step_time_s > self.straggler_factor * med:
                st.slow_count += 1
                if st.slow_count >= self.cordon_after:
                    st.cordoned = True
            else:
                st.slow_count = 0

    def median_step_time(self) -> float:
        if not self.step_times:
            return float("inf")
        s = sorted(self.step_times[-256:])
        return s[len(s) // 2]

    def check(self, now: float | None = None) -> dict[str, list[int]]:
        now = time.monotonic() if now is None else now
        dead, stragglers, cordoned = [], [], []
        for w in range(self.n_workers):
            st = self.workers.get(w)
            if st is None or now - st.last_beat > self.dead_after_s:
                dead.append(w)
            elif st.cordoned:
                cordoned.append(w)
            elif st.slow_count > 0:
                stragglers.append(w)
        return {"dead": dead, "stragglers": stragglers, "cordoned": cordoned}

    def healthy_mesh_size(self, total: int, now: float | None = None) -> int:
        """Largest power-of-two worker count available after failures —
        the elastic-restart target size."""
        health = self.check(now=now)
        bad = set(health["dead"]) | set(health["cordoned"])
        avail = total - len([w for w in bad if w < total])
        size = 1
        while size * 2 <= avail:
            size *= 2
        return size
