"""Heartbeat watchdog: node-failure and straggler detection.

On a real cluster each host runs ``beat()`` per step; the (replicated)
controller calls ``check()`` to classify workers as healthy / straggler /
dead and decides mitigation:

  * dead worker        -> restart from the latest checkpoint, possibly on a
                          smaller mesh (elastic: CheckpointManager reshards);
  * straggler          -> first re-dispatch its shard (backup-task policy);
                          repeated offenders are cordoned.

The control logic is deterministic and fully unit-tested; the container has
one host, so launch/train.py exercises it with simulated failures
(--inject-failure-at).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class WorkerState:
    last_beat: float
    last_step: int
    slow_count: int = 0
    cordoned: bool = False


@dataclass
class Watchdog:
    n_workers: int
    dead_after_s: float = 60.0
    straggler_factor: float = 2.0   # slower than factor x median step time
    cordon_after: int = 3
    workers: dict[int, WorkerState] = field(default_factory=dict)
    step_times: list[float] = field(default_factory=list)

    def beat(self, worker: int, step: int, now: float | None = None,
             step_time_s: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        st = self.workers.setdefault(worker, WorkerState(now, step))
        st.last_beat, st.last_step = now, step
        if step_time_s is not None:
            self.step_times.append(step_time_s)
            med = self.median_step_time()
            if med != float("inf") and step_time_s > self.straggler_factor * med:
                st.slow_count += 1
                if st.slow_count >= self.cordon_after:
                    st.cordoned = True
            else:
                st.slow_count = 0

    def median_step_time(self) -> float:
        if not self.step_times:
            return float("inf")
        s = sorted(self.step_times[-256:])
        return s[len(s) // 2]

    def check(self, now: float | None = None) -> dict[str, list[int]]:
        now = time.monotonic() if now is None else now
        dead, stragglers, cordoned = [], [], []
        for w in range(self.n_workers):
            st = self.workers.get(w)
            if st is None or now - st.last_beat > self.dead_after_s:
                dead.append(w)
            elif st.cordoned:
                cordoned.append(w)
            elif st.slow_count > 0:
                stragglers.append(w)
        return {"dead": dead, "stragglers": stragglers, "cordoned": cordoned}

    def healthy_mesh_size(self, total: int, now: float | None = None) -> int:
        """Largest power-of-two worker count available after failures —
        the elastic-restart target size."""
        health = self.check(now=now)
        bad = set(health["dead"]) | set(health["cordoned"])
        avail = total - len([w for w in bad if w < total])
        size = 1
        while size * 2 <= avail:
            size *= 2
        return size
