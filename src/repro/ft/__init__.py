"""Fault tolerance (DESIGN.md §14): atomic/elastic checkpointing with
corrupt-safe restore, heartbeat + no-progress watchdogs, and the seeded
deterministic fault-injection harness the chaos suite drives."""

from . import inject
from .checkpoint import CheckpointManager
from .watchdog import ProgressWatchdog, Watchdog

__all__ = ["CheckpointManager", "ProgressWatchdog", "Watchdog", "inject"]
