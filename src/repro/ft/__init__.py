"""Fault tolerance: atomic/elastic checkpointing, heartbeat watchdog with
straggler detection, restartable training driver support."""

from .checkpoint import CheckpointManager
from .watchdog import Watchdog

__all__ = ["CheckpointManager", "Watchdog"]
