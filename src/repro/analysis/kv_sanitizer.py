"""Paged-KV aliasing sanitizer (DESIGN.md §16.5).

A checkable model of the invariants ``PagedServeEngine`` maintains between
its page table (``row_map``), per-slot page lists, and the
``PageAllocator`` free list (DESIGN.md §12):

  * page accounting closes: free-list ∪ slot-held = all pages, with no page
    simultaneously free and held, held by two slots, or held by nobody
    (leak);
  * no physical row is owned by two live slots, and every row a slot maps
    lies on a page that slot actually holds;
  * no negative-index wrap hazard: −1 is the only legal "unmapped" value
    (XLA's ``mode="drop"`` scatter drops indices ≥ size but *wraps*
    negatives — the PR 6 bug class), and every row below a live slot's
    write position is mapped;
  * write positions stay within [0, max_seq] (max_seq is the idle
    sentinel).

Three entry points share the rules: :func:`check_paged_state` validates one
snapshot of engine state, :func:`check_engine` adapts a live
``PagedServeEngine`` (the engine's ``sanitize=True`` debug mode calls it
once per tick and raises :class:`PagedStateError` on errors), and
:class:`TraceChecker` replays a recorded alloc/map/release/suspend/resume
trace op by op, reporting the first op that broke the pool.

Pure numpy — no jax, importable anywhere.
"""
from __future__ import annotations

import numpy as np

from .findings import Finding, errors, rule

R_NEG_ROW = rule(
    "kv/negative-row",
    "row_map entry below −1: a negative physical row index wraps under the "
    "scatter's mode='drop' and corrupts the tail of the pool")
R_ROW_RANGE = rule(
    "kv/row-out-of-range",
    "row_map entry addresses a physical row beyond the pool")
R_ROW_DOUBLE = rule(
    "kv/row-double-owned",
    "the same physical row is mapped by two live logical rows: decode "
    "writes of one request would clobber another's KV")
R_ROW_UNMAPPED = rule(
    "kv/row-unmapped-live",
    "a live slot has an unmapped (−1) row below its write position: "
    "attention would read garbage for that position")
R_ROW_FOREIGN = rule(
    "kv/row-not-owned",
    "a slot maps a row on a page it does not hold")
R_PAGE_DOUBLE = rule(
    "kv/page-double-owned",
    "the same physical page appears in two slots' page lists (or twice in "
    "one)")
R_PAGE_FREE_HELD = rule(
    "kv/page-free-and-held",
    "a page is simultaneously on the allocator free list and held by a "
    "slot")
R_PAGE_LEAK = rule(
    "kv/page-leak",
    "a page is neither free nor held by any slot: the pool has leaked "
    "capacity (free ∪ mapped ≠ all pages)")
R_PAGE_FOREIGN = rule(
    "kv/page-foreign",
    "a slot holds a page the allocator does not consider allocated")
R_POS_RANGE = rule(
    "kv/pos-out-of-range",
    "slot write position outside [0, max_seq] (max_seq = idle sentinel)")


class PagedStateError(RuntimeError):
    """Raised by the engine's debug sanitizer; carries the findings."""

    def __init__(self, findings: list[Finding]):
        self.findings = findings
        lines = "\n  ".join(str(f) for f in findings)
        super().__init__(f"paged KV state is corrupt "
                         f"({len(findings)} finding(s)):\n  {lines}")


def check_paged_state(row_map, pos, pages, *, n_pages: int, page_size: int,
                      free_pages, held_pages, max_seq: int | None = None,
                      site: str = "paged") -> list[Finding]:
    """Validate one snapshot of paged-engine state.

    ``row_map`` is the (slots, max_seq) page table (−1 = unmapped), ``pos``
    the per-slot write positions, ``pages`` the per-slot page lists;
    ``free_pages``/``held_pages`` are the allocator's view of the pool.
    """
    rm = np.asarray(row_map)
    pos = np.asarray(pos)
    slots, width = rm.shape
    max_seq = width if max_seq is None else max_seq
    pool_rows = n_pages * page_size
    free = set(int(p) for p in free_pages)
    held = set(int(p) for p in held_pages)
    out: list[Finding] = []

    # -- page accounting -----------------------------------------------------
    owner: dict[int, int] = {}
    for s in range(slots):
        for p in pages[s]:
            p = int(p)
            if p in owner:
                out.append(Finding("error", R_PAGE_DOUBLE, f"{site}/page{p}",
                                   f"page {p} held by slot {owner[p]} and "
                                   f"slot {s}"))
            else:
                owner[p] = s
            if p in free:
                out.append(Finding("error", R_PAGE_FREE_HELD,
                                   f"{site}/page{p}",
                                   f"page {p} held by slot {s} but on the "
                                   f"free list"))
            if p not in held:
                out.append(Finding("error", R_PAGE_FOREIGN, f"{site}/page{p}",
                                   f"slot {s} holds page {p} the allocator "
                                   f"does not track as allocated"))
    for p in range(n_pages):
        if p not in free and p not in owner:
            out.append(Finding("error", R_PAGE_LEAK, f"{site}/page{p}",
                               f"page {p} is neither free nor held by any "
                               f"slot"))

    # -- row_map -------------------------------------------------------------
    row_owner: dict[int, tuple[int, int]] = {}
    for s in range(slots):
        p = int(pos[s])
        if p < 0 or p > max_seq:
            out.append(Finding("error", R_POS_RANGE, f"{site}/slot{s}",
                               f"pos={p} outside [0, {max_seq}]"))
            p = min(max(p, 0), max_seq)
        live = p < max_seq
        for i in range(width):
            r = int(rm[s, i])
            if r == -1:
                if live and i < p:
                    out.append(Finding(
                        "error", R_ROW_UNMAPPED, f"{site}/slot{s}/row{i}",
                        f"row {i} unmapped below write position {p}"))
                continue
            if r < -1:
                out.append(Finding(
                    "error", R_NEG_ROW, f"{site}/slot{s}/row{i}",
                    f"physical row {r} < −1 wraps under mode='drop'"))
                continue
            if r >= pool_rows:
                out.append(Finding(
                    "error", R_ROW_RANGE, f"{site}/slot{s}/row{i}",
                    f"physical row {r} >= pool of {pool_rows} rows"))
                continue
            if r in row_owner:
                os_, oi = row_owner[r]
                out.append(Finding(
                    "error", R_ROW_DOUBLE, f"{site}/slot{s}/row{i}",
                    f"physical row {r} also mapped by slot {os_} row {oi}"))
            else:
                row_owner[r] = (s, i)
            if owner.get(r // page_size) != s:
                out.append(Finding(
                    "error", R_ROW_FOREIGN, f"{site}/slot{s}/row{i}",
                    f"physical row {r} lies on page {r // page_size}, "
                    f"which slot {s} does not hold"))
    return out


def check_engine(engine, *, site: str = "engine") -> list[Finding]:
    """Snapshot-check a live ``PagedServeEngine`` (duck-typed: row_map,
    pos, _pages, alloc, max_seq)."""
    alloc = engine.alloc
    return check_paged_state(
        engine.row_map, engine.pos, engine._pages,
        n_pages=alloc.n_pages, page_size=alloc.page_size,
        free_pages=alloc.free_pages, held_pages=alloc._held,
        max_seq=engine.max_seq, site=site)


def assert_engine(engine, *, site: str = "engine") -> None:
    """Raise :class:`PagedStateError` if the engine's paged state has any
    error-severity finding (the per-tick debug assertion)."""
    bad = errors(check_engine(engine, site=site))
    if bad:
        raise PagedStateError(bad)


class TraceChecker:
    """Standalone trace checker: replay page-pool operations against a
    model of the invariants and report the first op that breaks them.

    Ops (dicts, ``op`` key dispatches):

      {"op": "alloc",   "slot": s, "pages": [..]}   pages granted to a slot
      {"op": "map",     "slot": s, "rows": n}       map the slot's first n
                                                    logical rows page-major
      {"op": "release", "slot": s}                  free the slot's pages
      {"op": "suspend", "slot": s}                  swap out: pages freed,
                                                    rows parked off-pool
      {"op": "resume",  "slot": s, "pages": [..]}   swap in on fresh pages

    :meth:`check_trace` returns the findings (each tagged with the op
    index); a clean trace returns [].
    """

    def __init__(self, n_pages: int, page_size: int, slots: int,
                 max_seq: int):
        self.n_pages, self.page_size = n_pages, page_size
        self.slots, self.max_seq = slots, max_seq
        self._free = set(range(n_pages))
        self._pages: list[list[int]] = [[] for _ in range(slots)]
        self.row_map = np.full((slots, max_seq), -1, np.int32)
        self.pos = np.full(slots, max_seq, np.int64)

    def _held(self) -> set[int]:
        return {p for ps in self._pages for p in ps}

    def _snapshot(self, site: str) -> list[Finding]:
        return check_paged_state(
            self.row_map, self.pos, self._pages,
            n_pages=self.n_pages, page_size=self.page_size,
            free_pages=self._free, held_pages=self._held(),
            max_seq=self.max_seq, site=site)

    def apply(self, op: dict, site: str = "trace") -> list[Finding]:
        """Apply one op, then re-check the whole state."""
        kind = op["op"]
        s = int(op.get("slot", 0))
        if kind in ("alloc", "resume"):
            pages = [int(p) for p in op["pages"]]
            self._free.difference_update(pages)
            self._pages[s].extend(pages)
            if kind == "resume":
                self._map(s, int(op.get("rows", self._capacity(s))))
        elif kind == "map":
            self._map(s, int(op["rows"]))
        elif kind in ("release", "suspend"):
            self._free.update(self._pages[s])
            self._pages[s] = []
            self.row_map[s, :] = -1
            self.pos[s] = self.max_seq
        else:
            raise ValueError(f"unknown trace op {kind!r}")
        return self._snapshot(site)

    def _capacity(self, s: int) -> int:
        return min(len(self._pages[s]) * self.page_size, self.max_seq)

    def _map(self, s: int, rows: int) -> None:
        rows = min(rows, self._capacity(s))
        ps = self.page_size
        flat = [p * ps + i for p in self._pages[s] for i in range(ps)]
        self.row_map[s, :rows] = np.asarray(flat[:rows], np.int32)
        self.pos[s] = rows

    def check_trace(self, ops: list[dict]) -> list[Finding]:
        out: list[Finding] = []
        for i, op in enumerate(ops):
            out.extend(self.apply(op, site=f"trace[{i}]:{op['op']}"))
            if errors(out):
                break   # state is corrupt; later findings would be noise
        return out
