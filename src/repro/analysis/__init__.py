"""Static legality, sharding, and hot-path analysis (DESIGN.md §16).

Four analyzers share one typed :class:`~repro.analysis.findings.Finding`
schema and a common rule catalog:

  legality       static (HWConfig, Schedule, TensorizeChoice) verifier
                 mirroring the cost model's feasibility rules — the tuner's
                 pre-lowering gate (``error_type="Illegal"``)
  jaxpr_audit    trace the jitted serve/train hot paths and flag host
                 callbacks, closure-captured state, recompile hazards, and
                 missed donations
  sharding_lint  validate each family's PartitionSpec trees against real
                 (eval_shape) shapes and a target mesh
  kv_sanitizer   checkable model of the paged-KV page-table/allocator
                 invariants; per-tick engine assertion + trace replay

``python -m repro.analysis`` lints the shipped configs/meshes plus the
golden codesign schedule and exits non-zero on error-severity findings
(the CI ``analysis-lint`` gate).  Submodules import lazily where they need
jax; ``findings``/``legality``/``kv_sanitizer`` stay import-light so the
tuner measurement path can use them unconditionally.
"""
from . import findings
from .findings import (RULES, SEVERITIES, Finding, errors, max_severity,
                       rule, summarize, to_json, warnings)

__all__ = [
    "findings", "RULES", "SEVERITIES", "Finding", "errors", "max_severity",
    "rule", "summarize", "to_json", "warnings",
]
