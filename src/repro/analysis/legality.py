"""Static legality verifier for (HWConfig, Schedule, TensorizeChoice)
triples (DESIGN.md §16.2).

Re-checks, *without* evaluating or lowering anything, every constraint the
runtime pipeline enforces dynamically:

  * ``cost_model._evaluate_reference`` — intrinsic agreement and the
    scratchpad working-set bound.  The formulas here are mirrored line for
    line (tile clamp, block padding, per-tensor footprints, the
    double-buffer factor, the local-accumulator carve-out), so an
    error-severity ``legality/*`` finding implies the cost model returns
    ILLEGAL for the same triple and vice versa — the zero-false-positive
    contract ``tests/test_analysis_legality.py`` asserts on random
    populations.
  * ``hw_space.HWSpace.legal`` — the hardware point itself must live inside
    the legal design space (minimal intrinsic tile fits VMEM, the PE-local
    accumulator does not eat the scratchpad).
  * ``matching`` rule ②'' and the accumulation flag — a choice whose
    index map sends an intrinsic-reduced index to a compute-free index has
    summed away data the workload still needs; a mis-set accumulation flag
    silently drops partial sums.
  * ``tuner.measure`` — the padded block-volume cap a lowering would trip
    (reported as a warning: the measurement layer owns that failure mode
    and its ValueError capture is load-bearing for the tuning DB).

Pure ``core``-level module: no jax, importable from the tuner's measurement
hot path at zero cost.
"""
from __future__ import annotations

from repro.core.hw_primitives import HWConfig
from repro.core.hw_space import AXES, PARALLELISM_AXES, HWSpace
from repro.core.intrinsics import ALL_INTRINSICS, BINDINGS
from repro.core.sw_primitives import Schedule
from repro.core.tst import TensorExpr

from .findings import Finding, errors, rule

DTYPE_BYTES = 2   # bf16 operands   (cost_model.DTYPE_BYTES)
ACC_BYTES = 4     # f32 accumulator (cost_model.ACC_BYTES)

R_INTRINSIC_MISMATCH = rule(
    "legality/intrinsic-mismatch",
    "schedule's tensorize choice targets a different intrinsic than the "
    "hardware point implements")
R_UNKNOWN_INTRINSIC = rule(
    "legality/unknown-intrinsic",
    "hardware intrinsic has no binding/TST (not one of DOT/GEMV/GEMM/CONV2D)")
R_WORKLOAD_MISMATCH = rule(
    "legality/choice-workload-mismatch",
    "tensorize choice was matched against a different workload")
R_UNKNOWN_LOOP = rule(
    "legality/unknown-loop",
    "index map references a loop the workload does not have")
R_UNBOUND_INDEX = rule(
    "legality/unbound-intrinsic-index",
    "index map references an intrinsic index the binding does not size")
R_REDUCTION_UNSOUND = rule(
    "legality/reduction-unsound",
    "intrinsic-reduced index mapped to a compute-free index (matching ②''): "
    "the intrinsic sums away data the workload still needs")
R_ACCUM_FLAG = rule(
    "legality/accumulation-flag",
    "choice.accumulation disagrees with the matching rules: partial sums "
    "would be dropped (or spuriously accumulated) at runtime")
R_VMEM_OVERFLOW = rule(
    "legality/vmem-overflow",
    "per-call working set (double-buffered operand tiles + accumulator "
    "spill) exceeds the configured VMEM budget")
R_MIN_TILE = rule(
    "legality/min-tile-overflow",
    "hardware point is outside the legal design space: one minimal "
    "intrinsic tile cannot fit its own VMEM (hw_space.legal)")
R_LOCAL_ACCUM = rule(
    "legality/local-accum-oversized",
    "hardware point is outside the legal design space: the PE-local "
    "accumulator claims more than a quarter of VMEM (hw_space.legal)")
R_TILE_CLAMPED = rule(
    "legality/tile-clamped",
    "schedule tile is non-positive or exceeds the loop extent; the "
    "evaluator clamps it, so the stated tile is not what runs")
R_TILE_MISALIGNED = rule(
    "legality/tile-misaligned",
    "interface tile is not a multiple of the intrinsic block: the padded "
    "call wastes the stated fraction of its compute")
R_TILE_UNMAPPED = rule(
    "legality/tile-unmapped-loop",
    "schedule carries a split factor for a loop the tensorize choice does "
    "not map (ignored by the interface, but it still inflates the padded "
    "block volume a lowering would allocate)")
R_KNOB_RANGE = rule(
    "legality/knob-out-of-range",
    "hardware knob value is not an ordinal of the design-space axis "
    "(hw_space.AXES): no DSE flow can have produced this point")
R_KNOB_POW2 = rule(
    "legality/knob-not-pow2",
    "PE-array knob is not a power of two: MXU block mapping pads it")
R_BLOCK_VOLUME = rule(
    "legality/block-volume",
    "padded tile volume exceeds the measurement layer's max_block_elems "
    "cap: lowering this candidate would be refused")

_POW2_KNOBS = ("pe_rows", "pe_cols", "pe_depth")


def _is_pow2(v: int) -> bool:
    return v > 0 and (v & (v - 1)) == 0


def verify_hw(hw: HWConfig, *, site: str | None = None) -> list[Finding]:
    """Design-space legality of a hardware point alone."""
    site = site or f"hw[{hw.intrinsic}]"
    out: list[Finding] = []
    if hw.intrinsic not in BINDINGS:
        out.append(Finding("error", R_UNKNOWN_INTRINSIC, site,
                           f"intrinsic {hw.intrinsic!r} has no binding"))
        return out
    for name, values in AXES.items():
        v = getattr(hw, name)
        if v not in values:
            out.append(Finding("warning", R_KNOB_RANGE, site,
                               f"{name}={v} is not an ordinal of "
                               f"hw_space.AXES[{name!r}]"))
    if hw.tp not in PARALLELISM_AXES["tp"]:
        out.append(Finding("warning", R_KNOB_RANGE, site,
                           f"tp={hw.tp} is not an ordinal of "
                           f"PARALLELISM_AXES['tp']"))
    for name in _POW2_KNOBS:
        v = getattr(hw, name)
        if not _is_pow2(v):
            out.append(Finding("warning", R_KNOB_POW2, site,
                               f"{name}={v} is not a power of two"))
    # hw_space.HWSpace.legal, split into its two constituent rules
    space = HWSpace(hw.intrinsic)
    if hw.local_accum_kib * 1024 > hw.vmem_bytes // 4:
        out.append(Finding("error", R_LOCAL_ACCUM, site,
                           f"local_accum {hw.local_accum_kib}KiB > "
                           f"vmem/4 ({hw.vmem_bytes // 4}B)"))
    elif not space.legal(hw):
        out.append(Finding("error", R_MIN_TILE, site,
                           f"one minimal {hw.intrinsic} tile (double-"
                           f"buffered) exceeds vmem {hw.vmem_bytes}B"))
    return out


def _expected_accumulation(choice, workload: TensorExpr) -> bool:
    """Mirror of matching._emit's accumulation rule."""
    intr = ALL_INTRINSICS[choice.intrinsic_name]
    sigma = dict(choice.index_map)
    software = [i for i in workload.all_indices() if i not in sigma.values()]
    return any(i in workload.reduced for i in software) or any(
        ci in workload.reduced and qi not in intr.reduced
        for qi, ci in sigma.items())


def verify_candidate(workload: TensorExpr, schedule: Schedule, hw: HWConfig,
                     *, max_block_elems: int | None = 1 << 24,
                     site: str | None = None) -> list[Finding]:
    """Full static legality of one (workload, schedule, hw) triple.

    Error-severity findings are exactly the candidates the dynamic pipeline
    would reject (cost model ILLEGAL, design-space-illegal hardware, or a
    semantically broken choice); :func:`is_legal` folds them to a bool.
    """
    choice = schedule.choice
    site = site or (f"{workload.name}|{hw.intrinsic}|{schedule.describe()}")
    out: list[Finding] = list(verify_hw(hw, site=site))
    if any(f.rule == R_UNKNOWN_INTRINSIC for f in out):
        return out

    if choice.workload_name != workload.name:
        out.append(Finding("error", R_WORKLOAD_MISMATCH, site,
                           f"choice was matched against "
                           f"{choice.workload_name!r}, verifying against "
                           f"{workload.name!r}"))
        return out
    if choice.intrinsic_name != hw.intrinsic:
        # cost_model._evaluate_reference returns ILLEGAL outright here
        out.append(Finding("error", R_INTRINSIC_MISMATCH, site,
                           f"choice targets {choice.intrinsic_name}, "
                           f"hw implements {hw.intrinsic}"))
        return out

    ext = workload.extents
    block = hw.intrinsic_dims()
    mapped = dict(choice.index_map)
    bad_map = False
    for q, c in mapped.items():
        if c not in ext:
            out.append(Finding("error", R_UNKNOWN_LOOP, site,
                               f"index map sends {q!r} to unknown loop "
                               f"{c!r}"))
            bad_map = True
        if q not in block:
            out.append(Finding("error", R_UNBOUND_INDEX, site,
                               f"intrinsic index {q!r} is not sized by the "
                               f"{hw.intrinsic} binding"))
            bad_map = True
    if bad_map:
        return out

    # -- matching soundness (②'' + the accumulation flag) --------------------
    intr = ALL_INTRINSICS[choice.intrinsic_name]
    for q, c in mapped.items():
        if q in intr.reduced and c not in workload.reduced:
            out.append(Finding("error", R_REDUCTION_UNSOUND, site,
                               f"intrinsic-reduced {q!r} maps to compute-"
                               f"free {c!r} (matching ②'')"))
    want_accum = _expected_accumulation(choice, workload)
    if choice.accumulation != want_accum:
        out.append(Finding("error", R_ACCUM_FLAG, site,
                           f"accumulation={choice.accumulation} but the "
                           f"matching rules require {want_accum}"))

    # -- tiles: clamp, block padding, stray splits ---------------------------
    tiles = schedule.tile_map
    ptile: dict[str, int] = {}
    for q, c in mapped.items():
        raw = tiles.get(c, ext[c])
        t = max(1, min(raw, ext[c]))
        if raw != t:
            out.append(Finding("warning", R_TILE_CLAMPED, site,
                               f"tile {c}={raw} clamped to {t} "
                               f"(extent {ext[c]})"))
        b = max(1, block[q])
        pt = -(-t // b) * b
        ptile[c] = pt
        if pt != t:
            out.append(Finding(
                "warning", R_TILE_MISALIGNED, site,
                f"tile {c}={t} pads to {pt} (block {q}={b}): "
                f"{100.0 * (1.0 - t / pt):.0f}% of each call is padding"))
    for loop in tiles:
        if loop not in mapped.values():
            out.append(Finding("warning", R_TILE_UNMAPPED, site,
                               f"split factor for unmapped loop {loop!r} "
                               f"is ignored by the interface"))

    # -- scratchpad working set (cost_model._evaluate_reference, verbatim) ---
    foot_total = 0
    for _, dims in workload.tensors().items():
        sz = 1
        for dim in dims:
            contrib = sum(ptile.get(i, 1) for i in dim) - (len(dim) - 1)
            sz *= max(1, contrib)
        foot_total += sz * DTYPE_BYTES
    out_foot = 1
    for i in workload.out_indices:
        out_foot *= ptile.get(i, 1)
    out_bytes = out_foot * ACC_BYTES
    buffered = 2 if hw.banks >= 2 else 1
    local = hw.local_accum_kib * 1024
    out_in_vmem = out_bytes if out_bytes > local else 0
    working = foot_total * buffered + out_in_vmem
    if working > hw.vmem_bytes:
        out.append(Finding("error", R_VMEM_OVERFLOW, site,
                           f"working set {working}B > vmem "
                           f"{hw.vmem_bytes}B"))

    # -- measurement block-volume cap (tuner.measure.padded_tiles/lower) -----
    if max_block_elems is not None:
        vol = 1
        for loop in workload.all_indices():
            if loop in ptile:
                vol *= ptile[loop]
            else:
                vol *= max(1, min(tiles.get(loop, ext[loop]), ext[loop]))
        if vol > max_block_elems:
            out.append(Finding("warning", R_BLOCK_VOLUME, site,
                               f"padded tile volume {vol} exceeds "
                               f"max_block_elems={max_block_elems}"))
    return out


def is_legal(workload: TensorExpr, schedule: Schedule, hw: HWConfig) -> bool:
    """True iff :func:`verify_candidate` raises no error-severity finding."""
    return not errors(verify_candidate(workload, schedule, hw))
