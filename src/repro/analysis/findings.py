"""Typed findings for the static-analysis subsystem (DESIGN.md §16).

Every analyzer in ``repro.analysis`` reports through one record type: a
:class:`Finding` names the *rule* that fired (a stable ``family/slug`` id
from the :data:`RULES` catalog), the *site* it fired at (a human-readable
path: a candidate describe string, a spec-tree leaf, a row_map cell), a
``severity``, and free-form ``detail``.  Analyzers never raise on the code
under analysis — they return findings; only callers decide whether errors
are fatal (the CLI exits nonzero, the paged engine's debug sanitizer
raises, the tuner skips the candidate).

Severity contract:

  * ``error``   — the artifact is statically wrong: it would fail, corrupt
    state, or silently produce garbage at runtime.  Error rules must hold
    the zero-false-positive bar on everything the repo ships.
  * ``warning`` — legal but suspicious: padding waste, replication of a
    large tensor, a missed donation.  Reported, never gating.
  * ``info``    — context the analyzer wants on the record (skipped checks,
    missing introspection support).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass

SEVERITIES = ("info", "warning", "error")
_RANK = {s: i for i, s in enumerate(SEVERITIES)}

#: Rule catalog: rule id -> one-line description.  Analyzers register their
#: rules at import time via :func:`rule`; the catalog is what DESIGN.md §16
#: documents and what ``python -m repro.analysis --rules`` prints.
RULES: dict[str, str] = {}


def rule(rule_id: str, description: str) -> str:
    """Register a rule id in the catalog (idempotent) and return it."""
    if "/" not in rule_id:
        raise ValueError(f"rule id {rule_id!r} must be 'family/slug'")
    RULES[rule_id] = description
    return rule_id


@dataclass(frozen=True)
class Finding:
    """One analyzer observation: (severity, rule, site, detail)."""

    severity: str
    rule: str
    site: str
    detail: str

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")

    def to_dict(self) -> dict:
        return asdict(self)

    def __str__(self) -> str:
        return f"[{self.severity}] {self.rule} @ {self.site}: {self.detail}"


def errors(findings: list[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity == "error"]


def warnings(findings: list[Finding]) -> list[Finding]:
    return [f for f in findings if f.severity == "warning"]


def max_severity(findings: list[Finding]) -> str | None:
    """Highest severity present, or None for a clean report."""
    if not findings:
        return None
    return max(findings, key=lambda f: _RANK[f.severity]).severity


def to_json(findings: list[Finding]) -> list[dict]:
    return [f.to_dict() for f in findings]


def summarize(findings: list[Finding]) -> dict[str, int]:
    out = {s: 0 for s in SEVERITIES}
    for f in findings:
        out[f.severity] += 1
    return out
