"""Jaxpr hot-path auditor (DESIGN.md §16.3).

Traces a jitted serve/train step and statically inspects the resulting
jaxpr for the failure modes that cost real serving throughput without ever
raising an exception:

  * host syncs / device-to-host transfers inside the program — callback
    primitives (``jax.debug.print``, ``pure_callback``, ``io_callback``)
    block the dispatch pipeline every tick;
  * python-scalar / host-state captures — a python scalar closed over by a
    step function is baked into the jaxpr at trace time, so engine state
    that should flow as an argument either goes stale (cached jit) or
    forces a retrace per tick (fresh wrapper).  Statically these fold into
    literals indistinguishable from code constants, so the robust detector
    is differential: trace the program at two consecutive engine states
    (same shapes/dtypes, different values) and diff the canonicalized
    jaxprs — any difference proves the program depends on host state the
    arguments do not carry;
  * silent recompiles across ticks — drive the *actual jitted callable*
    with two same-shaped tick inputs and assert its compilation-cache size
    stops growing after the first call;
  * weak-typed inputs (python scalars passed as traced args: their dtype
    rides python promotion and splits the jit cache) and missed donations
    (an output aval that matches a large non-donated input aval means two
    live copies of a buffer the program could have reused in place).

``audit_hot_paths`` bundles the shipped serve decode / chunked-prefill /
slot-write / train-step programs for one model config — the program set
``tests/test_analysis_audit.py`` pins clean and the CLI's ``--audit``
re-checks.
"""
from __future__ import annotations

import numpy as np

from .findings import Finding, rule

R_HOST_CALLBACK = rule(
    "jaxpr/host-callback",
    "callback primitive inside a jitted hot path: every invocation "
    "synchronizes with the host")
R_STATE_TRACE = rule(
    "jaxpr/state-dependent-trace",
    "program traced at two same-shaped engine states produced different "
    "jaxprs: host state (e.g. a python scalar) is captured by closure "
    "instead of flowing as an argument — stale under a cached jit, a "
    "retrace per tick under a fresh one")
R_RECOMPILE = rule(
    "jaxpr/recompile",
    "jit compilation cache grew on a same-shaped tick: the program "
    "silently recompiles across ticks")
R_WEAK_ARG = rule(
    "jaxpr/weak-type-arg",
    "weak-typed scalar argument: the traced dtype rides python promotion "
    "and value-class changes split the jit cache")
R_SCALAR_CONST = rule(
    "jaxpr/scalar-capture",
    "weak-typed scalar constant captured from the enclosing scope")
R_BIG_CONST = rule(
    "jaxpr/large-const-capture",
    "large array captured by closure: baked into every compiled "
    "executable instead of passed as an argument")
R_MISSED_DONATION = rule(
    "jaxpr/missed-donation",
    "an output buffer matches a large non-donated input (shape+dtype): "
    "the program holds two live copies where donation would reuse one")
R_NO_INTROSPECTION = rule(
    "jaxpr/no-cache-introspection",
    "the jit callable exposes no _cache_size; recompile check skipped")

#: Primitives that synchronize with the host when hit inside a program.
CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call",
})

_BIG_CONST_BYTES = 1 << 20      # 1 MiB: above this, closure capture is
_DONATION_BYTES = 1 << 16       # worth flagging; below, it's a lookup table


def _iter_eqns(jaxpr):
    """Yield every eqn in a jaxpr, recursing into sub-jaxpr params
    (pjit/scan/while/cond bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                yield from _iter_eqns(sub)
            elif hasattr(v, "eqns"):
                yield from _iter_eqns(v)
            elif isinstance(v, (list, tuple)):
                for x in v:
                    sub = getattr(x, "jaxpr", None)
                    if sub is not None and hasattr(sub, "eqns"):
                        yield from _iter_eqns(sub)
                    elif hasattr(x, "eqns"):
                        yield from _iter_eqns(x)


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _leaves(args):
    import jax
    return jax.tree_util.tree_leaves(args)


def audit_program(fn, *example_args, donate_argnums: tuple[int, ...] = (),
                  site: str = "program") -> list[Finding]:
    """Trace ``fn`` on example inputs and statically audit the jaxpr."""
    import jax

    closed = jax.make_jaxpr(fn)(*example_args)
    out: list[Finding] = []

    donated_flat: list[bool] = []
    for eqn in _iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        if name in CALLBACK_PRIMS:
            cb = eqn.params.get("callback", "")
            out.append(Finding("error", R_HOST_CALLBACK, site,
                               f"primitive {name!r} ({cb}) synchronizes "
                               f"with the host every invocation"))
        if name == "pjit" and "donated_invars" in eqn.params \
                and not donated_flat:
            donated_flat = list(eqn.params["donated_invars"])

    for var, const in zip(closed.jaxpr.constvars, closed.consts):
        aval = var.aval
        nb = _aval_bytes(aval)
        if getattr(aval, "weak_type", False) and aval.ndim == 0:
            out.append(Finding("error", R_SCALAR_CONST, site,
                               f"weak {aval.dtype} scalar captured by "
                               f"closure (value {const!r})"))
        elif nb > _BIG_CONST_BYTES:
            out.append(Finding("warning", R_BIG_CONST, site,
                               f"{aval.dtype}{list(aval.shape)} constant "
                               f"({nb} bytes) captured by closure"))

    in_avals = list(closed.in_avals)
    for i, aval in enumerate(in_avals):
        if getattr(aval, "weak_type", False):
            out.append(Finding("warning", R_WEAK_ARG, site,
                               f"arg {i} is weak-typed {aval.dtype}: pass "
                               f"a committed array/np scalar instead"))

    # -- missed donation: output avals that match big non-donated inputs -----
    if not donated_flat:
        flat_args = _leaves(example_args)
        donated_leaves: set[int] = set()
        pos = 0
        for i, a in enumerate(example_args):
            n = len(_leaves(a))
            if i in donate_argnums:
                donated_leaves.update(range(pos, pos + n))
            pos += n
        donated_flat = [j in donated_leaves for j in range(len(flat_args))]
    avail: dict[tuple, int] = {}
    for j, aval in enumerate(in_avals):
        if j < len(donated_flat) and donated_flat[j]:
            continue
        nb = _aval_bytes(aval)
        if nb >= _DONATION_BYTES:
            key = (tuple(aval.shape), str(aval.dtype))
            avail[key] = avail.get(key, 0) + 1
    missed = missed_bytes = 0
    for aval in closed.out_avals:
        key = (tuple(aval.shape), str(aval.dtype))
        if avail.get(key, 0) > 0:
            avail[key] -= 1
            missed += 1
            missed_bytes += _aval_bytes(aval)
    if missed:
        out.append(Finding("warning", R_MISSED_DONATION, site,
                           f"{missed} output buffer(s) ({missed_bytes} "
                           f"bytes) match non-donated inputs; donating "
                           f"would reuse them in place"))
    return out


def _canon_jaxpr(fn, args) -> str:
    import jax
    return str(jax.make_jaxpr(fn)(*args))


def audit_retrace(fn, args_a, args_b, site: str = "program") -> list[Finding]:
    """Differential capture check: trace ``fn`` at two consecutive engine
    states (same tree/shapes/dtypes, different values).  Identical jaxprs
    prove every tick-varying value flows through the arguments."""
    ja = _canon_jaxpr(fn, args_a)
    jb = _canon_jaxpr(fn, args_b)
    if ja == jb:
        return []
    delta = next((f"line {i}: {la!r} != {lb!r}" for i, (la, lb) in
                  enumerate(zip(ja.splitlines(), jb.splitlines()))
                  if la != lb), "program lengths differ")
    return [Finding("error", R_STATE_TRACE, site,
                    f"jaxpr differs across ticks ({delta})")]


def audit_jit_cache(jitted, ticks, site: str = "program") -> list[Finding]:
    """Dynamic recompile check: invoke the jitted callable on each tick's
    args (same shapes/dtypes throughout) and assert the compilation cache
    stops growing after the first call."""
    import jax

    if not hasattr(jitted, "_cache_size"):
        return [Finding("info", R_NO_INTROSPECTION, site,
                        "callable has no _cache_size()")]
    sizes = []
    for args in ticks:
        jax.block_until_ready(jitted(*args))
        sizes.append(jitted._cache_size())
    grew = [i for i in range(1, len(sizes)) if sizes[i] > sizes[i - 1]]
    if grew:
        return [Finding("error", R_RECOMPILE, site,
                        f"cache sizes {sizes} across same-shaped ticks: "
                        f"recompiled on tick(s) {grew}")]
    return []


# ---------------------------------------------------------------------------
# Shipped hot paths: the program set the repo serves/trains with
# ---------------------------------------------------------------------------


def audit_hot_paths(cfg, *, slots: int = 2, max_seq: int = 16,
                    page_size: int = 4, prompt_len: int = 4,
                    batch: int = 2) -> list[Finding]:
    """Audit the shipped serve decode / prefill / slot-write and train-step
    programs for ``cfg`` (use a reduced config: tracing is cheap but real).
    Encoder-only families audit the train step only.
    """
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import make_train_step
    from repro.models import family_module
    from repro.optim import AdamW

    out: list[Finding] = []
    mod = family_module(cfg)
    key = jax.random.PRNGKey(0)
    params = mod.init(cfg, key, 1)

    # -- train step (jitted exactly as launch/train.py does) -----------------
    opt = AdamW()
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, tp=1), donate_argnums=(0, 1))
    seq = 8
    mdt = jnp.dtype(cfg.dtype)
    lbl = jnp.zeros((batch, seq), jnp.int32)
    if cfg.embed_inputs:          # hubert: precomputed frame embeddings
        b = {"frames": jnp.zeros((batch, seq, cfg.d_model), mdt),
             "labels": lbl}
    elif cfg.vis_tokens:          # internvl2: patch-embedding prefix
        b = {"tokens": jnp.zeros((batch, seq), jnp.int32),
             "patches": jnp.zeros((batch, cfg.vis_tokens, cfg.d_model), mdt),
             "labels": lbl}
    else:
        b = {"tokens": jnp.zeros((batch, seq), jnp.int32), "labels": lbl}
    out += audit_program(step, params, opt_state, b,
                         donate_argnums=(0, 1),
                         site=f"{cfg.name}/train_step")
    if cfg.embed_inputs:
        return out

    # -- serving programs (the lru-cached builders the engines share) --------
    from repro.launch.serve import _jitted_steps, _paged_jitted_steps

    decode, prefill, write_slot = _jitted_steps(cfg, 1, "xla", max_seq)
    cache = mod.init_cache(cfg, slots, max_seq, 1)
    toks = np.zeros((slots, 1), np.int32)

    def dense_tick(t):
        return (params, cache, jnp.asarray(toks + t),
                jnp.asarray(np.full(slots, 1 + t), jnp.int32))

    out += audit_program(decode, *dense_tick(0),
                         site=f"{cfg.name}/serve_decode")
    out += audit_retrace(decode, dense_tick(0), dense_tick(1),
                         site=f"{cfg.name}/serve_decode")
    out += audit_jit_cache(decode, [dense_tick(0), dense_tick(1),
                                    dense_tick(2)],
                           site=f"{cfg.name}/serve_decode")

    ptoks = jnp.zeros((1, prompt_len), jnp.int32)
    out += audit_program(prefill, params, ptoks,
                         site=f"{cfg.name}/serve_prefill")
    out += audit_retrace(prefill, (params, ptoks), (params, ptoks + 1),
                         site=f"{cfg.name}/serve_prefill")

    _, pslot = jax.eval_shape(prefill, params, ptoks)
    slot_cache = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), pslot)
    out += audit_program(write_slot, cache, slot_cache, jnp.int32(0),
                         site=f"{cfg.name}/serve_write_slot")

    # -- paged decode (page-table KV) ----------------------------------------
    pdecode, _, _ = _paged_jitted_steps(cfg, 1, "xla")
    n_pages = -(-max_seq // page_size) * slots
    pcache = mod.init_paged_cache(cfg, slots, n_pages * page_size,
                                  max_seq, 1)
    row_map = np.full((slots, max_seq), -1, np.int32)
    row_map[:, :page_size] = np.arange(
        slots * page_size, dtype=np.int32).reshape(slots, page_size)

    def paged_tick(t):
        return (params, pcache, jnp.asarray(toks + t),
                jnp.asarray(np.full(slots, 1 + t), jnp.int32),
                jnp.asarray(row_map))

    out += audit_program(pdecode, *paged_tick(0),
                         site=f"{cfg.name}/paged_decode")
    out += audit_retrace(pdecode, paged_tick(0), paged_tick(1),
                         site=f"{cfg.name}/paged_decode")
    out += audit_jit_cache(pdecode, [paged_tick(0), paged_tick(1),
                                     paged_tick(2)],
                           site=f"{cfg.name}/paged_decode")
    return out
