"""Command-line entry for the static analysis suite (DESIGN.md §16.6).

Lints every shipped model config's sharding specs against the production
meshes, statically verifies the golden codesign schedule against its
committed hardware config, and (optionally) audits the jitted serve/train
hot paths.  Exits non-zero iff any error-severity finding survives — the
CI ``analysis-lint`` gate.

  # the CI invocation: all configs x {no mesh, data=2 model=4} + golden
  PYTHONPATH=src python -m repro.analysis --json artifacts/analysis_findings.json

  # one config on one mesh, plus a jaxpr audit of its hot paths
  PYTHONPATH=src python -m repro.analysis --arch qwen3-8b \
      --mesh data=2,model=4 --audit qwen3-8b
"""
from __future__ import annotations

import argparse
import json
import re
from pathlib import Path

from .findings import RULES, Finding, errors, summarize, to_json

GOLDEN_DEFAULT = Path(__file__).resolve().parents[3] \
    / "tests" / "golden" / "codesign_table1_gemm.json"

_DESCRIBE_RE = re.compile(
    r"\[(?P<intr>\w+)\] tiles\((?P<tiles>[^)]*)\) "
    r"order\((?P<order>[^)]*)\) fuse=(?P<fuse>\d+)")


def parse_mesh(spec: str) -> dict[str, int] | None:
    """'none' -> None; 'data=2,model=4' -> {'data': 2, 'model': 4}."""
    if spec.lower() in ("none", "nomesh", "1"):
        return None
    mesh: dict[str, int] = {}
    for part in spec.split(","):
        name, _, size = part.partition("=")
        try:
            mesh[name.strip()] = int(size)
        except ValueError:
            raise SystemExit(f"bad --mesh spec {spec!r} "
                             f"(want e.g. data=2,model=4 or none)")
    return mesh


def parse_schedule(described: str, workload):
    """Reconstruct a Schedule from its ``describe()`` string by re-running
    tensorize matching and picking the choice whose mapped loop set equals
    the tile keys (first match — the SoftwareSpace enumeration order)."""
    from repro.core.intrinsics import intrinsic
    from repro.core.matching import match
    from repro.core.sw_primitives import Schedule

    m = _DESCRIBE_RE.match(described.strip())
    if m is None:
        raise ValueError(f"unparseable schedule {described!r}")
    tiles = tuple((k.strip(), int(v)) for k, v in
                  (kv.split("=") for kv in m["tiles"].split(",") if kv))
    order = tuple(x.strip() for x in m["order"].split(">") if x.strip())
    keys = {k for k, _ in tiles}
    for choice in match(intrinsic(m["intr"]), workload):
        if set(choice.mapped_compute_indices) == keys:
            return Schedule(choice, tiles, order, int(m["fuse"]))
    raise ValueError(f"no tensorize choice of {m['intr']} on "
                     f"{workload.name} maps loops {sorted(keys)}")


def golden_findings(path: Path) -> list[Finding]:
    """Statically verify the golden codesign solution: the committed
    hardware config and every per-workload schedule must be legal."""
    from repro.core import workloads as W
    from repro.core.hw_primitives import HWConfig

    from .legality import verify_candidate, verify_hw

    snap = json.loads(path.read_text())
    enc = snap["hw"]
    hw = HWConfig(intrinsic=enc[0], pe_rows=enc[1], pe_cols=enc[2],
                  pe_depth=enc[3], vmem_kib=enc[4], banks=enc[5],
                  local_accum_kib=enc[6], burst_bytes=enc[7],
                  dataflow=enc[8], tp=enc[9])
    out = verify_hw(hw, site=f"golden/{path.name}/hw")
    by_name = {w.name: w for w in W.table1_gemm()}
    for name, entry in snap["workloads"].items():
        site = f"golden/{path.name}/{name}"
        wl = by_name.get(name)
        if wl is None:
            out.append(Finding("error", "legality/choice-workload-mismatch",
                               site, f"golden names unknown workload "
                               f"{name!r}"))
            continue
        sched = parse_schedule(entry["schedule"], wl)
        out.extend(verify_candidate(wl, sched, hw, site=site))
    return out


def _fmt(got: list[Finding]) -> str:
    if not got:
        return "clean"
    s = summarize(got)
    return ", ".join(f"{n} {k}" for k, n in s.items() if n)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static legality / sharding / hot-path lint "
                    "(exit 1 on error-severity findings)")
    ap.add_argument("--arch", action="append", default=[],
                    help="model config to lint (repeatable; default: all)")
    ap.add_argument("--mesh", action="append", default=[],
                    help="mesh as axis=size pairs or 'none' (repeatable; "
                         "default: none + data=2,model=4)")
    ap.add_argument("--golden", type=Path, default=GOLDEN_DEFAULT,
                    help="golden codesign snapshot to verify statically")
    ap.add_argument("--no-golden", action="store_true",
                    help="skip the golden-schedule legality check")
    ap.add_argument("--audit", action="append", default=[],
                    help="also jaxpr-audit this arch's serve/train hot "
                         "paths at reduced scale (repeatable; compiles)")
    ap.add_argument("--json", type=Path,
                    default=Path("artifacts/analysis_findings.json"),
                    help="write the findings JSON artifact here")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.rules:
        # importing the analyzers registers their rules
        from . import jaxpr_audit, kv_sanitizer, legality, sharding_lint  # noqa: F401
        for rid in sorted(RULES):
            print(f"{rid}: {RULES[rid]}")
        return 0

    from repro.configs import ARCH_IDS, get_config

    from .sharding_lint import lint_config

    arches = args.arch or list(ARCH_IDS)
    meshes = [parse_mesh(s) for s in args.mesh] \
        or [None, {"data": 2, "model": 4}]

    findings: list[Finding] = []
    for arch in arches:
        cfg = get_config(arch)
        for mesh in meshes:
            tag = "no-mesh" if mesh is None else \
                "x".join(f"{k}={v}" for k, v in mesh.items())
            got = lint_config(cfg, mesh)
            findings.extend(got)
            print(f"sharding {arch} [{tag}]: {_fmt(got)}")

    if not args.no_golden and args.golden.exists():
        got = golden_findings(args.golden)
        findings.extend(got)
        print(f"golden {args.golden.name}: {_fmt(got)}")

    if args.audit:
        from repro.models import reduced

        from .jaxpr_audit import audit_hot_paths
        for arch in args.audit:
            got = audit_hot_paths(reduced(get_config(arch)))
            findings.extend(got)
            print(f"audit {arch}: {_fmt(got)}")

    bad = errors(findings)
    for f in findings:
        print(f"  {f}")
    if args.json is not None:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(
            {"summary": summarize(findings), "errors": len(bad),
             "findings": to_json(findings)}, indent=2) + "\n")
        print(f"findings -> {args.json}")
    print(f"{len(findings)} finding(s), {len(bad)} error(s)")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
