"""Sharding lint (DESIGN.md §16.4).

Validates each model family's declared PartitionSpec trees —
``specs()`` / ``cache_specs()`` / ``paged_cache_specs()`` — against the
*real* array shapes the family initializers produce (via ``jax.eval_shape``,
so a 67B config lints in milliseconds without allocating a byte) and
against a target mesh described as a plain ``{axis: size}`` dict (no
devices needed):

  * every axis named by a spec must be a known logical axis
    ('pod' | 'data' | 'model');
  * specs must structurally match the init tree and never exceed a leaf's
    rank or name the same mesh axis twice;
  * every dim sharded over mesh axes must be divisible by their product at
    the tensor-parallel padding the mesh implies (``tp = mesh['model']``);
  * large parameter leaves whose spec prunes to fully-replicated on a
    multi-device mesh are flagged (the silent memory cliff);
  * pooled paged-KV leaves must keep the physical-row axis replicated (the
    host-side page table addresses rows on every shard) and must not carry
    batch axes at all — pool rows are shared across slots, so
    batch-sharding them is meaningless.

``lint_config`` is the per-(config, mesh) entry the CLI and CI gate loop
over; a clean shipped config returns no error-severity findings.
"""
from __future__ import annotations

import functools
import math

from .findings import Finding, rule

R_UNKNOWN_AXIS = rule(
    "sharding/unknown-axis",
    "spec names a mesh axis outside the logical axis set (pod/data/model): "
    "it will never match any production mesh and silently replicates")
R_RANK = rule(
    "sharding/rank-mismatch",
    "spec has more entries than the leaf has dims")
R_TREE = rule(
    "sharding/tree-mismatch",
    "spec tree structure differs from the init tree it must annotate")
R_DUP_AXIS = rule(
    "sharding/duplicate-axis",
    "the same mesh axis appears twice in one spec")
R_INDIVISIBLE = rule(
    "sharding/indivisible-dim",
    "a sharded dim is not divisible by the product of its mesh axis sizes")
R_REPLICATED = rule(
    "sharding/fully-replicated",
    "a large parameter leaf prunes to fully-replicated on this mesh: every "
    "device holds a whole copy")
R_POOL_ROWS = rule(
    "sharding/pool-rows-sharded",
    "paged-KV pool physical-row axis is sharded: the page table must "
    "address every row on every shard")
R_POOL_BATCH = rule(
    "sharding/pool-batch-axis",
    "paged-KV pool leaf sharded over a batch axis: pool rows are shared "
    "across slots, batch-sharding them is meaningless")

KNOWN_AXES = ("pod", "data", "model")

#: A replicated param leaf bigger than this on a >1-device mesh is flagged.
_REPLICATE_WARN_BYTES = 8 << 20


def _entry_axes(entry) -> tuple[str, ...]:
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def _flatten_specs(tree):
    import jax
    from jax.sharding import PartitionSpec as P
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, P))


def lint_tree(specs_tree, shape_tree, mesh_axes: dict[str, int] | None, *,
              site: str, warn_replicated: bool = False,
              pool_axes=None) -> list[Finding]:
    """Check one spec tree against the matching tree of array shapes.

    ``mesh_axes`` is ``{axis_name: size}`` (None = linting off-mesh: only
    structural and axis-name rules apply).  ``pool_axes`` is the family's
    ``paged_slot_axes`` tree; leaves marked ``"pool"`` get the pooled-KV
    rules.
    """
    import jax

    mesh = mesh_axes or {}
    spec_leaves, spec_def = _flatten_specs(specs_tree)
    shape_leaves, shape_def = jax.tree_util.tree_flatten_with_path(shape_tree)
    out: list[Finding] = []
    if len(spec_leaves) != len(shape_leaves) or \
            [p for p, _ in spec_leaves] != [p for p, _ in shape_leaves]:
        out.append(Finding(
            "error", R_TREE, site,
            f"spec tree ({len(spec_leaves)} leaves) does not match the init "
            f"tree ({len(shape_leaves)} leaves)"))
        return out
    pool_flags = [None] * len(spec_leaves)
    if pool_axes is not None:
        pl, _ = jax.tree_util.tree_flatten(pool_axes)
        if len(pl) == len(spec_leaves):
            pool_flags = pl

    for (path, spec), (_, leaf), marker in zip(spec_leaves, shape_leaves,
                                               pool_flags):
        where = site + jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        entries = tuple(spec)
        if len(entries) > len(shape):
            out.append(Finding("error", R_RANK, where,
                               f"spec {spec} has {len(entries)} entries for "
                               f"a rank-{len(shape)} leaf {list(shape)}"))
            continue
        seen: set[str] = set()
        bad = False
        for d, entry in enumerate(entries):
            for a in _entry_axes(entry):
                if a not in KNOWN_AXES:
                    out.append(Finding(
                        "error", R_UNKNOWN_AXIS, where,
                        f"dim {d} names unknown axis {a!r} (known: "
                        f"{'/'.join(KNOWN_AXES)})"))
                    bad = True
                elif a in seen:
                    out.append(Finding("error", R_DUP_AXIS, where,
                                       f"axis {a!r} appears twice in {spec}"))
                    bad = True
                seen.add(a)
        if bad:
            continue
        sharded = False
        for d, entry in enumerate(entries):
            div = math.prod(mesh.get(a, 1) for a in _entry_axes(entry))
            if div > 1:
                sharded = True
                if shape[d] % div:
                    out.append(Finding(
                        "error", R_INDIVISIBLE, where,
                        f"dim {d} of size {shape[d]} not divisible by "
                        f"{div} (axes {_entry_axes(entry)} on mesh "
                        f"{mesh})"))
        nbytes = math.prod(shape) * leaf.dtype.itemsize
        if warn_replicated and not sharded and mesh and \
                max(mesh.values()) > 1 and nbytes >= _REPLICATE_WARN_BYTES:
            out.append(Finding(
                "warning", R_REPLICATED, where,
                f"{nbytes >> 20} MiB leaf replicated on every device of "
                f"mesh {mesh}"))
        if marker == "pool":
            if len(entries) > 1 and entries[1] is not None:
                out.append(Finding(
                    "error", R_POOL_ROWS, where,
                    f"physical-row axis (dim 1) sharded as "
                    f"{entries[1]!r} in {spec}"))
            batch = [a for e in entries for a in _entry_axes(e)
                     if a in ("pod", "data")]
            if batch:
                out.append(Finding(
                    "error", R_POOL_BATCH, where,
                    f"pool leaf carries batch axis(es) {batch} in {spec}"))
    return out


def lint_config(cfg, mesh_axes: dict[str, int] | None = None, *,
                slots: int = 4, max_seq: int = 64) -> list[Finding]:
    """Lint one model config's param/cache/paged-cache specs against a mesh
    (``{axis: size}``; None = single device).  Shapes come from
    ``jax.eval_shape`` over the real initializers at the mesh's TP degree,
    so padding/divisibility is checked exactly as serving would see it.
    """
    import jax

    from repro.models import family_module

    mod = family_module(cfg)
    tp = (mesh_axes or {}).get("model", 1)
    key = jax.random.PRNGKey(0)
    out: list[Finding] = []

    params = jax.eval_shape(functools.partial(mod.init, cfg, tp=tp), key)
    out += lint_tree(mod.specs(cfg), params, mesh_axes,
                     site=f"{cfg.name}/params", warn_replicated=True)

    if cfg.embed_inputs:     # encoder-only: no serving caches to lint
        return out

    cache = jax.eval_shape(
        functools.partial(mod.init_cache, cfg, slots, max_seq, tp))
    out += lint_tree(mod.cache_specs(cfg), cache, mesh_axes,
                     site=f"{cfg.name}/cache")

    rows = slots * max_seq
    paged = jax.eval_shape(
        functools.partial(mod.init_paged_cache, cfg, slots, rows, max_seq,
                          tp))
    out += lint_tree(mod.paged_cache_specs(cfg), paged, mesh_axes,
                     site=f"{cfg.name}/paged_cache",
                     pool_axes=mod.paged_slot_axes(cfg))
    return out
