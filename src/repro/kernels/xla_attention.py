"""Memory-efficient attention in pure XLA — the 'xla' implementation used by
the dry-run/roofline and by training on this container.

Forward: lax.scan over KV chunks with online softmax (O(S·chunk) memory).
Backward: custom VJP with flash-style recomputation — only (q, k, v, out,
lse) are saved; per-chunk probabilities are rebuilt in the backward scan.
Without this, scan's reverse-mode saves the f32 accumulator per chunk and a
67B-scale train step wants ~46 GB/device of temp (dry-run probe evidence).

Semantics match the Pallas flash kernel: GQA, causal, logit softcap, sliding
window, cache-aligned positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _positions(sq: int, skv: int):
    qpos = (jnp.arange(sq) + (skv - sq))[:, None]    # cache-aligned
    return qpos


def _mask_for(kpos, qpos, skv, causal, window):
    mask = (kpos < skv) & jnp.ones_like(qpos, bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    return mask


def _chunked(k, v, chunk):
    b, skv, hkv, d = k.shape
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    return kc, vc, n_chunks


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def chunked_attention(q, k, v, causal: bool = True, softcap: float = 0.0,
                      window: int = 0, scale: float | None = None,
                      chunk: int = 256):
    out, _ = _fwd(q, k, v, causal, softcap, window, scale, chunk)
    return out


def attention(q, k, v, *, causal: bool = True, softcap: float = 0.0,
              window: int = 0, scale: float | None = None,
              chunk: int = 256):
    """Keyword-friendly wrapper around the custom-VJP primitive."""
    return chunked_attention(q, k, v, causal, softcap, window, scale, chunk)


def _fwd(q, k, v, causal, softcap, window, scale, chunk):
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    sc = float(scale if scale is not None else d ** -0.5)
    chunk = min(chunk, skv)
    kc, vc, n_chunks = _chunked(k, v, chunk)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d) * sc
    qpos = _positions(sq, skv)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, c0 = xs
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb.astype(jnp.float32))
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = c0 + jnp.arange(chunk)[None, :]
        mask = _mask_for(kpos, qpos, skv, causal, window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(s > 0.5 * NEG_INF, jnp.exp(s - m_new[..., None]), 0.0)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    acc0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    starts = jnp.arange(n_chunks) * chunk
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, starts))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).reshape(b, sq, h, d).astype(q.dtype)
    lse = m + jnp.log(l_safe)                         # (b, sq, hkv, g)
    return out, lse


def _fwd_vjp(q, k, v, causal, softcap, window, scale, chunk):
    out, lse = _fwd(q, k, v, causal, softcap, window, scale, chunk)
    return out, (q, k, v, out, lse)


def _bwd_vjp(causal, softcap, window, scale, chunk, res, do):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    sc = float(scale if scale is not None else d ** -0.5)
    chunk = min(chunk, skv)
    kc, vc, n_chunks = _chunked(k, v, chunk)
    pad = n_chunks * chunk - skv

    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    dof = do.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    of = out.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    delta = jnp.sum(dof * of, axis=-1)                # (b, sq, hkv, g)
    qpos = _positions(sq, skv)

    def body(dq, xs):
        kb, vb, c0 = xs
        kf = kb.astype(jnp.float32)
        s_raw = jnp.einsum("bqhgd,bkhd->bqhgk", qf * sc, kf)
        if softcap > 0:
            t = jnp.tanh(s_raw / softcap)
            s = softcap * t
        else:
            s = s_raw
        kpos = c0 + jnp.arange(chunk)[None, :]
        mask = _mask_for(kpos, qpos, skv, causal, window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.where(s > 0.5 * NEG_INF,
                      jnp.exp(s - lse[..., None]), 0.0)
        dv = jnp.einsum("bqhgk,bqhgd->bkhd", p, dof)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dof, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if softcap > 0:
            ds = ds * (1.0 - t * t)
        dq = dq + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kf) * sc
        dk = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qf) * sc
        return dq, (dk, dv)

    dq0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    starts = jnp.arange(n_chunks) * chunk
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kc, vc, starts))
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, n_chunks * chunk, hkv, d)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, n_chunks * chunk, hkv, d)
    if pad:
        dk, dv = dk[:, :skv], dv[:, :skv]
    return (dq.reshape(b, sq, h, d).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


chunked_attention.defvjp(_fwd_vjp, _bwd_vjp)
