"""Blocked GEMM Pallas kernel — the GEMM hardware intrinsic (paper §II-B).

The block shape (bm, bn, bk) *is* the co-designed accelerator parameter set:
``pe_rows × pe_cols`` maps to (bm, bn) and ``pe_depth`` to bk (DESIGN.md §2).
Grid = (M/bm, N/bn, K/bk) with the contraction innermost ("arbitrary") so the
f32 VMEM accumulator is revisited; (bm, bk)/(bk, bn) tiles are the scratchpad
residents that HASCO's VMEM-legality constraint sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def gemm(a: jax.Array, b: jax.Array, *, bm: int = 256, bn: int = 256,
         bk: int = 512, interpret: bool = False) -> jax.Array:
    """C = A @ B with f32 accumulation.  A: (M, K), B: (K, N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    # zero-pad to block multiples: zeros are exact for the accumulation
    mp, np_, kp = (pl.cdiv(m, bm) * bm, pl.cdiv(n, bn) * bn,
                   pl.cdiv(k, bk) * bk)
    a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
