"""Blocked GEMV Pallas kernel — the GEMV hardware intrinsic.

y = A @ x.  The vector is broadcast as a (1, bk) block; rows stream in
(bm, bk) tiles (pe_rows × pe_depth in HASCO terms).  Accumulation in a
(bm, 1)-shaped f32 VMEM scratch — GEMV on the MXU is rank-deficient, which is
exactly why the paper's Fig. 7 shows dedicated intrinsics winning; the cost
model carries the same penalty.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _gemv_kernel(a_ref, x_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)          # (bm, bk)
    x = x_ref[...].astype(jnp.float32)          # (1, bk)
    acc_ref[...] += jnp.sum(a * x, axis=1, keepdims=True)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def gemv(a: jax.Array, x: jax.Array, *, bm: int = 512, bk: int = 512,
         interpret: bool = False) -> jax.Array:
    """y[m] = sum_k A[m,k] x[k].  Returns shape (M,)."""
    m, k = a.shape
    assert x.shape == (k,)
    bm, bk = min(bm, m), min(bk, k)
    mp, kp = pl.cdiv(m, bm) * bm, pl.cdiv(k, bk) * bk
    a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    x = jnp.pad(x, (0, kp - k))
    grid = (mp // bm, kp // bk)
    out = pl.pallas_call(
        functools.partial(_gemv_kernel, n_k=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((1, bk), lambda i, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, kk: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 1), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, 1), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, x[None, :])
    return out[:m, 0]
