"""Chunked RWKV-6 / Mamba-2 recurrences in pure XLA — the 'xla'
implementations used by the dry-run/roofline and CPU training.

Same chunked math as the Pallas kernels (rwkv6.py / mamba2.py docstrings),
vectorized over (batch, heads) with lax.scan over chunks.  The sequential
ref.py oracles would make reverse-mode save one carried state per *token*
(51 GB/device for rwkv6-3b train_4k); chunking bounds the saved carries to
one state per chunk.  All exponentials are non-positive — stable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6(r, k, v, w, u, state=None, *, chunk: int = 32):
    """Chunked WKV6.  r/k/w: (B,T,H,Dk); v: (B,T,H,Dv); u: (H,Dk);
    w = log-decay <= 0.  Returns (out (B,T,H,Dv), final state (B,H,Dk,Dv))."""
    b, t, h, dk = k.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    n = t // chunk
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    def split(x):
        return jnp.moveaxis(
            x.reshape(b, n, chunk, h, x.shape[-1]), 1, 0)   # (n,b,chunk,h,d)

    rc, kc, vc, wc = split(r.astype(jnp.float32)), split(k.astype(jnp.float32)), \
        split(v.astype(jnp.float32)), split(w.astype(jnp.float32))
    uf = u.astype(jnp.float32)

    ti = jnp.arange(chunk)[:, None]
    si = jnp.arange(chunk)[None, :]
    strict = (si < ti)[None, :, :, None, None]              # (1,L,L,1,1)

    def body(s, xs):
        rb, kb, vb, wb = xs                                 # (b,L,h,d*)
        lw = jnp.cumsum(wb, axis=1)                         # inclusive
        aq = lw - wb                                        # exclusive
        o = jnp.einsum("blhk,bhkv->blhv", rb * jnp.exp(aq), s)
        expo = aq[:, :, None] - lw[:, None, :]              # (b,L,L,h,dk)
        pair = jnp.where(strict, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        scores = jnp.einsum("btshk,bthk,bshk->bths",
                            pair, rb, kb)
        o = o + jnp.einsum("bths,bshv->bthv", scores, vb)
        o = o + jnp.einsum("blhk,hk,blhk->blh", rb, uf, kb)[..., None] * vb
        lw_last = lw[:, -1:]
        kd = kb * jnp.exp(lw_last - lw)
        s = jnp.exp(lw_last[:, 0])[..., None] * s + \
            jnp.einsum("blhk,blhv->bhkv", kd, vb)
        return s, o

    final, outs = jax.lax.scan(body, state, (rc, kc, vc, wc))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, t, h, dv)
    return out.astype(v.dtype), final


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba2(x, a, b, c, state=None, *, chunk: int = 128):
    """Chunked SSD.  x: (B,T,H,P); a: (B,T,H) log-decay <= 0; b/c: (B,T,H,N).
    Returns (y (B,T,H,P), final state (B,H,N,P))."""
    bs, t, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    if state is None:
        state = jnp.zeros((bs, h, n, p), jnp.float32)

    def split(z):
        return jnp.moveaxis(
            z.reshape(bs, nc, chunk, h, z.shape[-1]), 1, 0)

    xc = split(x.astype(jnp.float32))
    bc = split(b.astype(jnp.float32))
    cc = split(c.astype(jnp.float32))
    ac = jnp.moveaxis(a.astype(jnp.float32).reshape(bs, nc, chunk, h), 1, 0)

    ti = jnp.arange(chunk)[:, None]
    si = jnp.arange(chunk)[None, :]
    incl = (si <= ti)[None, :, :, None]                     # (1,L,L,1)

    def body(s, xs):
        xb, ab, bb, cb = xs
        la = jnp.cumsum(ab, axis=1)                         # (b,L,h)
        y = jnp.einsum("blhn,bhnp->blhp", cb * jnp.exp(la)[..., None], s)
        decay = jnp.where(
            incl, jnp.exp(jnp.minimum(la[:, :, None] - la[:, None, :], 0.0)),
            0.0)                                            # (b,t,s,h)
        gram = jnp.einsum("bthn,bshn->btsh", cb, bb) * decay
        y = y + jnp.einsum("btsh,bshp->bthp", gram, xb)
        la_last = la[:, -1:]
        bd = bb * jnp.exp(la_last - la)[..., None]
        s = jnp.exp(la_last[:, 0])[..., None, None] * s + \
            jnp.einsum("blhn,blhp->bhnp", bd, xb)
        return s, y

    final, ys = jax.lax.scan(body, state, (xc, ac, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bs, t, h, p)
    return y.astype(x.dtype), final
