"""Version compatibility shims for Pallas TPU APIs.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` around
0.5; the kernels import the name from here so they run on both sides of the
rename without touching jax module state.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
