"""Direct 2-D convolution Pallas kernel — the CONV2D hardware intrinsic.

C[k,x,y] = sum_{c,r,s} A[c,x+r,y+s] * W[k,c,r,s]   ('valid').

TPU adaptation of the paper's dedicated conv accelerator: the input tile is
scratchpad(VMEM)-resident with its halo, filters stream per-k block, and the
R×S taps unroll into MXU matmuls of (C, X·Y) slices — a direct conv, *not*
im2col (the paper's Fig. 11 shows why materialized im2col loses).  Workloads
bigger than VMEM are decomposed by the software layer (the tensorize
interface) into sub-workloads that fit — exactly the paper's HW/SW split.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _conv_kernel(a_ref, w_ref, o_ref, acc_ref, *, xdim: int, ydim: int,
                 taps: tuple[tuple[int, int], ...]):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    for r, s in taps:
        a_slice = a_ref[:, r:r + xdim, s:s + ydim]          # (C, X, Y)
        a_mat = a_slice.reshape(a_slice.shape[0], xdim * ydim)
        w_mat = w_ref[:, :, r, s]                           # (bk, C)
        acc_ref[...] += jnp.dot(w_mat, a_mat,
                                preferred_element_type=jnp.float32)
    o_ref[...] = acc_ref[...].reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def conv2d(a: jax.Array, w: jax.Array, *, bk: int = 128,
           interpret: bool = False) -> jax.Array:
    """a: (C, H, W);  w: (K, C, R, S);  returns (K, H-R+1, W-S+1)."""
    c, h, wd = a.shape
    k, c2, r, s = w.shape
    assert c == c2
    x, y = h - r + 1, wd - s + 1
    bk = min(bk, k)
    grid = (pl.cdiv(k, bk),)
    taps = tuple((i, j) for i in range(r) for j in range(s))
    return pl.pallas_call(
        functools.partial(_conv_kernel, xdim=x, ydim=y, taps=taps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, h, wd), lambda kk: (0, 0, 0)),
            pl.BlockSpec((bk, c, r, s), lambda kk: (kk, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bk, x, y), lambda kk: (kk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, x, y), a.dtype),
        scratch_shapes=[pltpu.VMEM((bk, x * y), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(a, w)
