"""Dot-product Pallas kernel — the DOT hardware intrinsic.

The most general (and least data-reusing) intrinsic of the paper's four:
streams both operands once, accumulates a scalar.  bk is ``pe_depth``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _dot_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.sum(a * b).reshape(1, 1)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def dot(a: jax.Array, b: jax.Array, *, bk: int = 2048,
        interpret: bool = False) -> jax.Array:
    """sum(a * b) over 1-D operands; returns shape (1, 1) f32."""
    (k,) = a.shape
    assert b.shape == (k,)
    bk = min(bk, k)
    kp = pl.cdiv(k, bk) * bk
    a = jnp.pad(a, (0, kp - k))
    b = jnp.pad(b, (0, kp - k))
    grid = (kp // bk,)
    return pl.pallas_call(
        functools.partial(_dot_kernel, n_k=grid[0]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda kk: (0, kk)),
            pl.BlockSpec((1, bk), lambda kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda kk: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(a[None, :], b[None, :])
