"""Jit'd dispatch wrappers for all kernels.

Every op takes ``implementation``: 'pallas' (the TPU kernel; on this CPU
container only via interpret=True), 'interpret' (Pallas interpreter —
correctness path used by tests), or 'xla' (pure-jnp reference semantics,
used by the dry-run so cost_analysis sees XLA-native HLO).

Block shapes left unspecified are resolved through a three-level fallback
(DESIGN.md §8.4): the measured tuning database (``tuner/db.py``) for this
exact (op, shape, dtype, backend); then app-level defaults installed by
:func:`configure` at launch startup (serve/train); then the safe built-in
constants.  Explicit keyword arguments always win — tests and benchmarks
that pin block shapes are unaffected.
"""
from __future__ import annotations

import os
import warnings

import jax
import jax.numpy as jnp

from . import conv2d as _conv2d
from . import dotprod as _dotprod
from . import flash_attention as _flash
from . import gemm as _gemm
from . import gemv as _gemv
from . import mamba2 as _mamba2
from . import ref
from . import rwkv6 as _rwkv6

IMPLEMENTATIONS = ("pallas", "interpret", "xla")

# safe built-in block shapes — the last-resort tier of resolve_blocks
DEFAULT_BLOCKS: dict[str, dict[str, int]] = {
    "gemm": {"bm": 256, "bn": 256, "bk": 512},
    "gemv": {"bm": 512, "bk": 512},
    "dot": {"bk": 2048},
    "conv2d": {"bk": 128},
}

# app-level defaults installed by configure(); shape-exact DB hits override
_APP_BLOCKS: dict[str, dict[str, int]] = {}
# lazy tuning-db handle: (path, mtime) -> TuningDB, reloaded when the
# artifact changes on disk (tuning runs merge-save into it)
_DB_STATE: dict = {"path": None, "mtime": None, "db": None}


def _mode(implementation: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    if implementation == "pallas":
        return True, False
    if implementation == "interpret":
        return True, True
    if implementation == "xla":
        return False, False
    raise ValueError(f"implementation must be one of {IMPLEMENTATIONS}")


def set_tuning_db(path) -> None:
    """Point the dispatch layer at a tuning database artifact."""
    _DB_STATE.update(path=path, mtime=None, db=None)


def _tuning_db():
    """The current TuningDB, reloaded on mtime change; never raises."""
    from repro.tuner.db import DEFAULT_DB_PATH, TuningDB

    path = _DB_STATE["path"] or DEFAULT_DB_PATH
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return None
    if _DB_STATE["db"] is None or _DB_STATE["mtime"] != mtime \
            or _DB_STATE["path"] != path:
        try:
            _DB_STATE.update(path=path, mtime=mtime, db=TuningDB.load(path))
        except Exception as e:   # a broken artifact must not break dispatch
            warnings.warn(f"tuning db {path}: {e}; using defaults")
            _DB_STATE.update(path=path, mtime=mtime, db=None)
    return _DB_STATE["db"]


def _db_best(op: str, shape, dtype, implementation: str) -> dict[str, int]:
    """Shape-exact tuned blocks from the DB (this backend, else the CPU
    container's 'interpret' measurements), filtered to the op's known block
    names so a malformed artifact can only narrow, never break, dispatch."""
    db = _tuning_db()
    if db is None:
        return {}
    dt = str(jnp.dtype(dtype))
    rec = (db.best_config(op, shape, dt, implementation)
           or db.best_config(op, shape, dt, "interpret")) or {}
    return {k: v for k, v in rec.items() if k in DEFAULT_BLOCKS[op]}


def resolve_blocks(op: str, shape, dtype, implementation: str,
                   **explicit) -> dict[str, int]:
    """Block shapes for one kernel call: built-in defaults, overridden by
    app-level tuned defaults, overridden by a shape-exact tuning-db record,
    overridden by explicit (non-None) caller arguments."""
    out = dict(DEFAULT_BLOCKS[op])
    out.update(_APP_BLOCKS.get(op, {}))
    if any(v is None for v in explicit.values()):
        out.update(_db_best(op, shape, dtype, implementation))
    out.update({k: v for k, v in explicit.items() if v is not None})
    return out


def configure(app: str = "default", db_path=None,
              solutions_path=None) -> dict[str, dict[str, int]]:
    """Install app-level tuned block shapes as process-wide dispatch
    defaults (called by launch/serve.py and launch/train.py at startup).

    Sources, in priority order: the tuning database's ``apps`` section (the
    accelerator the measured co-design committed for ``app``), then the
    solution registry (``core/solution.py``).  Returns what was installed
    ({} when nothing is tuned — dispatch stays on safe defaults).
    """
    from repro.core.solution import mxu_legal

    if db_path is not None:
        set_tuning_db(db_path)
    hw_dict = None
    db = _tuning_db()
    if db is not None:
        entry = db.apps.get(app)
        # apps entries are absorbed unvalidated: a malformed one must not
        # take down a launch — fall through to the registry instead
        if isinstance(entry, dict) and isinstance(entry.get("hw"), dict):
            hw_dict = entry["hw"]
    if hw_dict is None:
        try:
            from repro.core.solution import load_hw

            hw = (load_hw(app, solutions_path) if solutions_path is not None
                  else load_hw(app))
            if hw is not None:
                hw_dict = {"pe_rows": hw.pe_rows, "pe_cols": hw.pe_cols,
                           "pe_depth": hw.pe_depth}
        except Exception as e:
            warnings.warn(f"solution registry unavailable ({e}); "
                          f"dispatch stays on defaults")
    if hw_dict is None:
        return {}

    def dim(knob: str, default: int) -> int:
        v = hw_dict.get(knob, default)
        return int(v) if isinstance(v, (int, float)) else default

    installed = {
        "gemm": {"bm": mxu_legal(dim("pe_rows", 256), 8),
                 "bn": mxu_legal(dim("pe_cols", 256), 128),
                 "bk": mxu_legal(dim("pe_depth", 512), 128)},
        "gemv": {"bm": mxu_legal(dim("pe_rows", 512), 8),
                 "bk": mxu_legal(dim("pe_depth", 512), 128)},
        "dot": {"bk": mxu_legal(dim("pe_depth", 2048), 128)},
        "conv2d": {"bk": mxu_legal(dim("pe_cols", 128), 8)},
    }
    _APP_BLOCKS.update(installed)
    return installed


def reset_dispatch() -> None:
    """Forget configure()/set_tuning_db state (tests)."""
    _APP_BLOCKS.clear()
    _DB_STATE.update(path=None, mtime=None, db=None)


def matmul(a, b, *, bm: int | None = None, bn: int | None = None,
           bk: int | None = None, implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        return ref.gemm_ref(a, b)
    blk = resolve_blocks("gemm", (a.shape[0], b.shape[1], a.shape[1]),
                         a.dtype, implementation, bm=bm, bn=bn, bk=bk)
    return _gemm.gemm(a, b, interpret=interp, **blk)


def matvec(a, x, *, bm: int | None = None, bk: int | None = None,
           implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        return ref.gemv_ref(a, x)
    blk = resolve_blocks("gemv", a.shape, a.dtype, implementation,
                         bm=bm, bk=bk)
    return _gemv.gemv(a, x, interpret=interp, **blk)


def dot(a, b, *, bk: int | None = None, implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        return ref.dot_ref(a, b)
    blk = resolve_blocks("dot", a.shape, a.dtype, implementation, bk=bk)
    return _dotprod.dot(a, b, interpret=interp, **blk)


def conv2d(a, w, *, bk: int | None = None, implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        return ref.conv2d_ref(a, w)
    c, h, wd = a.shape
    k, _, r, s = w.shape
    blk = resolve_blocks("conv2d", (k, c, h - r + 1, wd - s + 1, r, s),
                         a.dtype, implementation, bk=bk)
    return _conv2d.conv2d(a, w, interpret=interp, **blk)


def attention(q, k, v, *, causal: bool = True, softcap: float = 0.0,
              window: int = 0, scale: float | None = None,
              bq: int = 128, bkv: int = 128, implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        # chunked online-softmax with flash-style custom VJP:
        # O(S·chunk) memory forward AND backward, same semantics/FLOPs
        from . import xla_attention
        return xla_attention.attention(
            q, k, v, causal=causal, softcap=softcap, window=window,
            scale=scale)
    return _flash.flash_attention(q, k, v, causal=causal, softcap=softcap,
                                  window=window, scale=scale, bq=bq,
                                  bkv=bkv, interpret=interp)


def rwkv6(r, k, v, w, u, state=None, *, chunk: int = 16,
          implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        from . import xla_linear
        return xla_linear.rwkv6(r, k, v, w, u, state)
    return _rwkv6.rwkv6(r, k, v, w, u, state, chunk=chunk, interpret=interp)


def mamba2(x, a, b, c, state=None, *, chunk: int = 64,
           implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        from . import xla_linear
        return xla_linear.mamba2(x, a, b, c, state)
    return _mamba2.mamba2(x, a, b, c, state, chunk=chunk, interpret=interp)


def tuned_matmul(a, b, app: str = "default", implementation: str = "xla"):
    """GEMM with HASCO-tuned block shapes — the paper's technique as a
    first-class framework feature.  Shape-exact tuning-db records win;
    otherwise the app's co-designed accelerator from the solution registry
    sizes the blocks; otherwise the safe defaults."""
    from repro.core.solution import kernel_blocks

    shape = (a.shape[0], b.shape[1], a.shape[1])
    bm, bn, bk = kernel_blocks(app)
    blk = {"bm": bm, "bn": bn, "bk": bk}
    blk.update(_db_best("gemm", shape, a.dtype, implementation))
    return matmul(a, b, implementation=implementation, **blk)
