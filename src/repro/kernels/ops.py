"""Jit'd dispatch wrappers for all kernels.

Every op takes ``implementation``: 'pallas' (the TPU kernel; on this CPU
container only via interpret=True), 'interpret' (Pallas interpreter —
correctness path used by tests), or 'xla' (pure-jnp reference semantics,
used by the dry-run so cost_analysis sees XLA-native HLO).  Block shapes
default to the HASCO-tuned values from the solution registry when available.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import conv2d as _conv2d
from . import dotprod as _dotprod
from . import flash_attention as _flash
from . import gemm as _gemm
from . import gemv as _gemv
from . import mamba2 as _mamba2
from . import ref
from . import rwkv6 as _rwkv6

IMPLEMENTATIONS = ("pallas", "interpret", "xla")


def _mode(implementation: str) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    if implementation == "pallas":
        return True, False
    if implementation == "interpret":
        return True, True
    if implementation == "xla":
        return False, False
    raise ValueError(f"implementation must be one of {IMPLEMENTATIONS}")


def matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 512,
           implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        return ref.gemm_ref(a, b)
    return _gemm.gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=interp)


def matvec(a, x, *, bm: int = 512, bk: int = 512,
           implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        return ref.gemv_ref(a, x)
    return _gemv.gemv(a, x, bm=bm, bk=bk, interpret=interp)


def dot(a, b, *, bk: int = 2048, implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        return ref.dot_ref(a, b)
    return _dotprod.dot(a, b, bk=bk, interpret=interp)


def conv2d(a, w, *, bk: int = 128, implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        return ref.conv2d_ref(a, w)
    return _conv2d.conv2d(a, w, bk=bk, interpret=interp)


def attention(q, k, v, *, causal: bool = True, softcap: float = 0.0,
              window: int = 0, scale: float | None = None,
              bq: int = 128, bkv: int = 128, implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        # chunked online-softmax with flash-style custom VJP:
        # O(S·chunk) memory forward AND backward, same semantics/FLOPs
        from . import xla_attention
        return xla_attention.attention(
            q, k, v, causal=causal, softcap=softcap, window=window,
            scale=scale)
    return _flash.flash_attention(q, k, v, causal=causal, softcap=softcap,
                                  window=window, scale=scale, bq=bq,
                                  bkv=bkv, interpret=interp)


def rwkv6(r, k, v, w, u, state=None, *, chunk: int = 16,
          implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        from . import xla_linear
        return xla_linear.rwkv6(r, k, v, w, u, state)
    return _rwkv6.rwkv6(r, k, v, w, u, state, chunk=chunk, interpret=interp)


def mamba2(x, a, b, c, state=None, *, chunk: int = 64,
           implementation: str = "xla"):
    use_pallas, interp = _mode(implementation)
    if not use_pallas:
        from . import xla_linear
        return xla_linear.mamba2(x, a, b, c, state)
    return _mamba2.mamba2(x, a, b, c, state, chunk=chunk, interpret=interp)


def tuned_matmul(a, b, app: str = "default", implementation: str = "xla"):
    """GEMM with HASCO-tuned block shapes from the solution registry —
    the paper's technique as a first-class framework feature."""
    from repro.core.solution import kernel_blocks

    bm, bn, bk = kernel_blocks(app)
    return matmul(a, b, bm=bm, bn=bn, bk=bk, implementation=implementation)
