"""Pallas TPU kernels for the performance-critical compute layers, each with
a pure-jnp oracle in ``ref.py`` and jit'd dispatch in ``ops.py``.

  gemm / gemv / dotprod / conv2d — the paper's four hardware intrinsics
  flash_attention               — fused attention (softcap, local window, GQA)
  rwkv6                         — chunked linear-attention WKV (Finch)
  mamba2                        — chunked SSD scan
"""

from . import ops, ref
from .conv2d import conv2d
from .dotprod import dot
from .flash_attention import flash_attention
from .gemm import gemm
from .gemv import gemv
from .mamba2 import mamba2
from .rwkv6 import rwkv6

__all__ = ["conv2d", "dot", "flash_attention", "gemm", "gemv", "mamba2",
           "ops", "ref", "rwkv6"]
