"""Chunked Mamba-2 SSD Pallas kernel.

Scalar-per-head decay makes the chunked form pure MXU work (unlike RWKV-6's
per-channel decay): the (L, L) intra-chunk decay mask multiplies a C·Bᵀ
Gram matrix.  All exponents are non-positive — numerically stable.

Per chunk (la = inclusive cumsum of log-decay a):
  y_t    = (c_t e^{la_t})·h0 + Σ_{s≤t} e^{la_t−la_s} (c_t·b_s) x_s
  h_new  = e^{la_L} h0 + Σ_s e^{la_L−la_s} b_s x_sᵀ
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _mamba2_kernel(x_ref, a_ref, b_ref, c_ref, h0_ref,
                   y_ref, hT_ref, state_ref, *, chunk: int, n_t: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)               # (L, P)
    a = a_ref[0].astype(jnp.float32)               # (1, L) log-decay <= 0
    b = b_ref[0].astype(jnp.float32)               # (L, N)
    c = c_ref[0].astype(jnp.float32)               # (L, N)

    la = jnp.cumsum(a[0])                          # (L,), inclusive
    h0 = state_ref[...]                            # (N, P)

    # inter-chunk
    y = jnp.dot(c * jnp.exp(la)[:, None], h0,
                preferred_element_type=jnp.float32)

    # intra-chunk (inclusive diagonal: y_t uses h_t after its own update)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(si <= ti,
                      jnp.exp(jnp.minimum(la[:, None] - la[None, :], 0.0)),
                      0.0)
    gram = jnp.dot(c, b.T, preferred_element_type=jnp.float32) * decay
    y += jnp.dot(gram, x, preferred_element_type=jnp.float32)

    # state update
    bd = b * jnp.exp(la[-1] - la)[:, None]
    state_ref[...] = jnp.exp(la[-1]) * h0 + jnp.dot(
        bd.T, x, preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(t == n_t - 1)
    def _flush():
        hT_ref[0] = state_ref[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
           state: jax.Array | None = None, *, chunk: int = 64,
           interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, H, P); a: (B, T, H) log-decay; b/c: (B, T, H, N);
    state: (B, H, N, P) or None.  Returns (y (B,T,H,P), final state)."""
    bs, t, h, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0, f"T={t} must be a multiple of chunk={chunk}"
    if state is None:
        state = jnp.zeros((bs, h, n, p), jnp.float32)

    def flat(z):
        return jnp.moveaxis(z, 2, 1).reshape(bs * h, t, z.shape[-1])

    xf, bf, cf = flat(x), flat(b), flat(c)
    af = jnp.moveaxis(a, 2, 1).reshape(bs * h, 1, t)
    h0 = state.reshape(bs * h, n, p)

    n_t = t // chunk
    grid = (bs * h, n_t)
    y, hT = pl.pallas_call(
        functools.partial(_mamba2_kernel, chunk=chunk, n_t=n_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bh, tt: (bh, 0, tt)),
            pl.BlockSpec((1, chunk, n), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, n, p), lambda bh, tt: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, n, p), lambda bh, tt: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bs * h, t, p), x.dtype),
            jax.ShapeDtypeStruct((bs * h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xf, af, bf, cf, h0)

    out = jnp.moveaxis(y.reshape(bs, h, t, p), 1, 2)
    return out, hT.reshape(bs, h, n, p)
