"""Chunked RWKV-6 (Finch) WKV Pallas kernel.

TPU adaptation (DESIGN.md §Arch-applicability): the data-dependent per-channel
decay recurrence is *not* a fixed-shape intrinsic — HASCO's matcher cannot
tensorize it directly.  We therefore chunk the sequence: within-chunk terms
become dense (MXU-friendly) contractions and the recurrence survives only at
chunk granularity, carried in a VMEM-resident f32 state.  All exponentials
are differences of log-decay cumsums with non-positive exponents → stable.

Per chunk of length L (lw = inclusive cumsum of log-decay, aq = exclusive):
  o_t     = Σ_d r_td e^{aq_td} S0[d]  +  Σ_{s<t} Σ_d r_td k_sd e^{aq_td−lw_sd} v_s
            + (Σ_d r_td u_d k_td) v_t
  S_new[d] = e^{lw_Ld} S0[d] + Σ_s k_sd e^{lw_Ld−lw_sd} v_s
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                  o_ref, sT_ref, state_ref, *, chunk: int, n_t: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)               # (L, Dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)               # (L, Dv)
    w = w_ref[0].astype(jnp.float32)               # (L, Dk) log-decay <= 0
    u = u_ref[0].astype(jnp.float32)               # (1, Dk)

    lw = jnp.cumsum(w, axis=0)                     # inclusive
    aq = lw - w                                    # exclusive
    s0 = state_ref[...]                            # (Dk, Dv)

    # inter-chunk: query against the carried state
    o = jnp.dot(r * jnp.exp(aq), s0, preferred_element_type=jnp.float32)

    # intra-chunk: pairwise decay tensor, strictly-lower-triangular
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = (si < ti)[..., None]                  # (L, L, 1)
    expo = aq[:, None, :] - lw[None, :, :]         # (L, L, Dk), <= 0 where s<t
    pair = jnp.where(strict, jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
    scores = jnp.sum(pair * r[:, None, :] * k[None, :, :], axis=-1)
    o += jnp.dot(scores, v, preferred_element_type=jnp.float32)

    # current-token bonus (diag(u))
    o += jnp.sum(r * u * k, axis=-1, keepdims=True) * v

    # state update
    lw_L = lw[-1:, :]                              # (1, Dk)
    kd = k * jnp.exp(lw_L - lw)                    # <= k, stable
    state_ref[...] = jnp.exp(lw_L.T) * s0 + jnp.dot(
        kd.T, v, preferred_element_type=jnp.float32)

    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(t == n_t - 1)
    def _flush():
        sT_ref[0] = state_ref[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
          u: jax.Array, state: jax.Array | None = None, *,
          chunk: int = 16, interpret: bool = False
          ) -> tuple[jax.Array, jax.Array]:
    """r/k/w: (B, T, H, Dk); v: (B, T, H, Dv); u: (H, Dk);
    state: (B, H, Dk, Dv) or None.  Returns (out (B,T,H,Dv), final state)."""
    b, t, h, dk = k.shape
    dv = v.shape[-1]
    assert t % chunk == 0, f"T={t} must be a multiple of chunk={chunk}"
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(b * h, t, x.shape[-1])

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(w)
    uf = jnp.broadcast_to(u[None], (b, h, dk)).reshape(b * h, 1, dk)
    s0 = state.reshape(b * h, dk, dv)

    n_t = t // chunk
    grid = (b * h, n_t)
    o, sT = pl.pallas_call(
        functools.partial(_rwkv6_kernel, chunk=chunk, n_t=n_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, chunk, dv), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, 1, dk), lambda bh, tt: (bh, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda bh, tt: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda bh, tt: (bh, tt, 0)),
            pl.BlockSpec((1, dk, dv), lambda bh, tt: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, t, dv), v.dtype),
            jax.ShapeDtypeStruct((b * h, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, wf, uf, s0)

    out = jnp.moveaxis(o.reshape(b, h, t, dv), 1, 2)
    return out, sT.reshape(b, h, dk, dv)
