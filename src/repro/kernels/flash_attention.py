"""Flash attention (forward) Pallas kernel with GQA, causal masking,
gemma2 logit soft-capping and sliding-window (local) attention.

Online-softmax over kv blocks (the innermost, "arbitrary" grid dim); per
q-block scratch holds the running max/denominator and the f32 accumulator —
the canonical VMEM-resident working set.  The (bq, bkv) block shape is the
HASCO-tunable "PE array" of the attention intrinsic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, softcap: float, window: int,
                  bq: int, bkv: int, n_kv: int, q_offset: int, kv_len: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0].astype(jnp.float32)                 # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    iq = pl.program_id(1)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
        + q_offset
    kpos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < kv_len                             # padded keys never attend
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "softcap", "window", "scale", "bq", "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, softcap: float = 0.0,
                    window: int = 0, scale: float | None = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D);  k, v: (B, Skv, Hkv, D);  GQA via H % Hkv == 0.

    Sequence lengths are padded to the block sizes internally; the causal
    offset aligns the last query with the last key (decode convention).
    """
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    scale = float(scale if scale is not None else d ** -0.5)

    bq = min(bq, max(8, sq))
    bkv = min(bkv, skv)
    sq_p = pl.cdiv(sq, bq) * bq
    skv_p = pl.cdiv(skv, bkv) * bkv
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    # padded keys must never win the max: push them outside the causal mask
    q_offset = skv - sq

    qf = jnp.moveaxis(qp, 2, 1).reshape(b * h, sq_p, d)
    kf = jnp.moveaxis(kp, 2, 1).reshape(b * hkv, skv_p, d)
    vf = jnp.moveaxis(vp, 2, 1).reshape(b * hkv, skv_p, d)

    n_kv = skv_p // bkv
    grid = (b * h, sq_p // bq, n_kv)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, softcap=float(softcap),
        window=int(window), bq=bq, bkv=bkv, n_kv=n_kv, q_offset=q_offset,
        kv_len=skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bkv, d),
                         lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((1, bkv, d),
                         lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(b, h, sq_p, d)[:, :, :sq]
    return jnp.moveaxis(out, 1, 2)
