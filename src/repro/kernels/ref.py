"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantic ground truth: simple, obviously-correct, unfused
implementations that the kernel tests sweep shapes/dtypes against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C[m,n] = sum_k A[m,k] B[k,n], f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(a.dtype)


def gemv_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """y[m] = sum_k A[m,k] x[k]."""
    return (a.astype(jnp.float32) @ x.astype(jnp.float32)).astype(a.dtype)


def dot_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Scalar dot product, f32 accumulation, returned as shape (1, 1)."""
    return jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32)
                   ).reshape(1, 1)


def conv2d_ref(a: jax.Array, w: jax.Array) -> jax.Array:
    """C[k,x,y] = sum_{c,r,s} A[c,x+r,y+s] W[k,c,r,s] ('valid' conv,
    the paper's CONV2D intrinsic semantics)."""
    a4 = a[None].astype(jnp.float32)              # (1, C, H, W)
    w4 = w.astype(jnp.float32)                    # (K, C, R, S)
    out = jax.lax.conv_general_dilated(
        a4, w4, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return out[0].astype(a.dtype)                 # (K, X, Y)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, softcap: float = 0.0,
                  window: int = 0, scale: float | None = None) -> jax.Array:
    """Multi-head attention oracle.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) with H % Hkv == 0 (GQA).
    ``softcap``: gemma2 logit soft-capping  cap*tanh(logits/cap).
    ``window``: >0 = local (sliding-window) attention of that width.
    """
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)   # align cache offsets
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, state: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 (Finch) WKV oracle — strict sequential recurrence.

    r/k: (B, T, H, Dk); v: (B, T, H, Dv); w: (B, T, H, Dk) per-channel
    data-dependent log-decay (w <= 0, decay = exp(w)); u: (H, Dk) bonus.
    state: (B, H, Dk, Dv).  Returns (out (B,T,H,Dv), final state).

      o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T) ;  S_t = diag(e^{w_t}) S_{t-1} + k_t v_t^T
    """
    b, t, h, dk = k.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp          # (B,H,Dk),(B,H,Dk),(B,H,Dv),(B,H,Dk)
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,Dk,Dv)
        ot = jnp.einsum("bhk,bhkv->bhv", rt, s + uf[None, :, :, None] * kv)
        s = jnp.exp(wt)[..., None] * s + kv
        return s, ot

    ins = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    final, outs = jax.lax.scan(step, state, ins)
    return jnp.moveaxis(outs, 0, 1).astype(v.dtype), final


def mamba2_ref(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
               state: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """Mamba-2 SSD oracle — sequential recurrence.

    x: (B, T, H, P) head inputs; a: (B, T, H) per-head log-decay (<= 0);
    b/c: (B, T, H, N) input/output projections (N = ssm state size).
    state: (B, H, N, P).  Returns (y (B,T,H,P), final state).

      h_t = e^{a_t} h_{t-1} + b_t x_t^T ;  y_t = c_t^T h_t
    """
    bs, t, h, p = x.shape
    n = b.shape[-1]
    if state is None:
        state = jnp.zeros((bs, h, n, p), jnp.float32)
    xf, bf, cf = (z.astype(jnp.float32) for z in (x, b, c))
    af = a.astype(jnp.float32)

    def step(s, inp):
        xt, at, bt, ct = inp
        s = jnp.exp(at)[..., None, None] * s \
            + bt[..., :, None] * xt[..., None, :]
        yt = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, yt

    ins = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0),
           jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    final, ys = jax.lax.scan(step, state, ins)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
