"""Candidate-value heuristic (paper §VI-B, Fig. 5(d)).

The paper values a candidate ``p`` with latency ``l_p`` against the best
latency in history ``l*`` as ``exp(-(l* - l_p)/l*)``.  Taken literally that
rewards *worse* candidates (l_p > l* ⇒ value > 1); we use the evidently
intended sign, ``exp(-(l_p - l*)/l*)``, so the best candidate scores 1.0 and
worse candidates decay — the FlexTensor [85] convention the paper cites.
This deviation is recorded in EXPERIMENTS.md §Fidelity.
"""
from __future__ import annotations

import math


def candidate_value(latency: float, best_latency: float) -> float:
    if not math.isfinite(latency):
        return 0.0
    if best_latency <= 0:
        return 0.0
    return math.exp(-(latency - best_latency) / best_latency)


def top_k(pool: list, latencies: list[float], k: int) -> list[int]:
    """Indices of the (up to) k most valuable *feasible* candidates.

    Infeasible candidates (non-finite latency: illegal tiling, resource
    overflow) are filtered out entirely rather than padding the tail — a
    refine budget spent revising a known-illegal schedule is a wasted
    evaluation — so fewer than ``k`` indices come back when feasible
    candidates are scarce.  Callers must size downstream work by
    ``len(result)``, not ``k``.
    """
    best = min((l for l in latencies if math.isfinite(l)), default=math.inf)
    feasible = [i for i in range(len(pool)) if math.isfinite(latencies[i])]
    feasible.sort(key=lambda i: -candidate_value(latencies[i], best))
    return feasible[:k]
