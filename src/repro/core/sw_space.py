"""Software design space per (workload × accelerator) (paper §VI-A/B).

The space is the set of legal Schedules: a tensorize choice from the
partition space, power-of-two interface tiles per mapped loop, an outer loop
order, and a fuse factor.  The space exposes the *revision choices* (moves)
the Q-learning agent selects among, and a fixed-size feature embedding of a
schedule for the DQN.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .cost_model import EvalCache, evaluate, evaluate_batch, evaluate_batch_reports
from .hw_primitives import HWConfig
from .matching import TensorizeChoice
from .sw_primitives import Schedule
from .tst import TensorExpr

MAX_LOOPS = 8          # feature/action slots (>= loops of any Table-I workload)


@dataclass(frozen=True)
class Move:
    kind: str            # 'grow' | 'shrink' | 'sink' | 'swap_outer' | 'switch'
    slot: int = -1

    def describe(self) -> str:
        return f"{self.kind}({self.slot})" if self.slot >= 0 else self.kind


def _pow2_down(x: int) -> int:
    return 1 << max(0, int(math.floor(math.log2(max(1, x)))))


class SoftwareSpace:
    """Legal schedules for one workload on one accelerator instance."""

    def __init__(self, workload: TensorExpr, choices: list[TensorizeChoice],
                 hw: HWConfig, target: str = "spatial",
                 cache: EvalCache | None = None):
        if not choices:
            raise ValueError(f"no tensorize choices for {workload.name}")
        self.workload = workload
        self.choices = [c for c in choices if c.intrinsic_name == hw.intrinsic]
        if not self.choices:
            raise ValueError(
                f"no {hw.intrinsic} choices for {workload.name}")
        self.hw = hw
        self.target = target
        self.cache = cache
        self.loops = list(workload.all_indices())

        # the action table (paper: "change the combination of the primitive
        # sequence or change one primitive factor")
        self.moves: list[Move] = []
        for s in range(MAX_LOOPS):
            self.moves.append(Move("grow", s))
            self.moves.append(Move("shrink", s))
        for s in range(MAX_LOOPS):
            self.moves.append(Move("sink", s))     # move loop s innermost
        self.moves.append(Move("swap_outer"))
        self.moves.append(Move("switch"))          # next tensorize choice

    # -- construction -----------------------------------------------------------
    def random_schedule(self, rng: np.random.Generator) -> Schedule:
        choice = self.choices[int(rng.integers(len(self.choices)))]
        ext = self.workload.extents
        tiles = []
        for c in choice.mapped_compute_indices:
            hi = _pow2_down(ext[c])
            t = 1 << int(rng.integers(0, int(math.log2(hi)) + 1))
            tiles.append((c, min(t, ext[c])))
        order = list(self.loops)
        rng.shuffle(order)
        fuse = int(rng.integers(0, 3))
        return Schedule(choice, tuple(sorted(tiles)), tuple(order), fuse)

    def default_schedule(self) -> Schedule:
        """A library-style untuned mapping: intrinsic-sized tiles, source
        loop order (the paper's 'directly calling the intrinsic')."""
        choice = self.choices[0]
        block = self.hw.intrinsic_dims()
        tiles = tuple(sorted(
            (c, min(self.workload.extents[c], max(1, block[q])))
            for q, c in choice.index_map))
        return Schedule(choice, tiles, tuple(self.loops), 0)

    # -- evaluation ---------------------------------------------------------------
    def latency(self, s: Schedule) -> float:
        return evaluate(self.workload, s, self.hw, self.target,
                        cache=self.cache).latency_s

    def report(self, s: Schedule):
        return evaluate(self.workload, s, self.hw, self.target,
                        cache=self.cache)

    def latency_batch(self, schedules: list[Schedule]) -> np.ndarray:
        """Latencies of a whole candidate population in one vectorized pass
        (the DSE hot path — DESIGN.md §4.3)."""
        return evaluate_batch(self.workload, self.hw, schedules, self.target,
                              cache=self.cache)[:, 0]

    def report_batch(self, schedules: list[Schedule]):
        return evaluate_batch_reports(self.workload, self.hw, schedules,
                                      self.target, cache=self.cache)

    # -- moves ---------------------------------------------------------------------
    def apply(self, s: Schedule, move: Move,
              rng: np.random.Generator | None = None) -> Schedule:
        ext = self.workload.extents
        tiles = list(s.tiles)
        if move.kind in ("grow", "shrink"):
            if move.slot >= len(tiles):
                return s
            loop, t = tiles[move.slot]
            t = min(ext[loop], t * 2) if move.kind == "grow" else max(1, t // 2)
            return s.with_tile(loop, t)
        if move.kind == "sink":
            if move.slot >= len(s.order):
                return s
            order = list(s.order)
            order.append(order.pop(move.slot))
            return s.with_order(tuple(order))
        if move.kind == "swap_outer":
            if len(s.order) < 2:
                return s
            order = list(s.order)
            order[0], order[1] = order[1], order[0]
            return s.with_order(tuple(order))
        if move.kind == "switch":
            k = self.choices.index(s.choice) if s.choice in self.choices else 0
            nxt = self.choices[(k + 1) % len(self.choices)]
            tiles_map = s.tile_map
            new_tiles = tuple(sorted(
                (c, min(ext[c], tiles_map.get(c, ext[c])))
                for c in nxt.mapped_compute_indices))
            return Schedule(nxt, new_tiles, s.order, s.fuse_outer)
        raise ValueError(move.kind)

    # -- features for the DQN ---------------------------------------------------------
    @property
    def n_features(self) -> int:
        return MAX_LOOPS * 3 + 4

    def features(self, s: Schedule, rep=None) -> np.ndarray:
        """Fixed-size DQN embedding of one schedule.  ``rep`` may supply the
        schedule's CostReport (e.g. from a batched pass) so no extra
        cost-model evaluation is needed."""
        ext = self.workload.extents
        f = np.zeros(self.n_features, dtype=np.float32)
        tile_map = s.tile_map
        for k, loop in enumerate(self.loops[:MAX_LOOPS]):
            f[k] = math.log2(max(1, tile_map.get(loop, 0) or 1)) / 16.0
            f[MAX_LOOPS + k] = (s.order.index(loop) / max(1, len(s.order) - 1)
                                if loop in s.order else 0.0)
            f[2 * MAX_LOOPS + k] = math.log2(ext[loop]) / 16.0
        if rep is None:
            rep = self.report(s)
        f[3 * MAX_LOOPS + 0] = min(1.0, rep.vmem_bytes / self.hw.vmem_bytes) \
            if rep.vmem_bytes else 0.0
        f[3 * MAX_LOOPS + 1] = rep.utilization if rep.legal else 0.0
        f[3 * MAX_LOOPS + 2] = self.choices.index(s.choice) / max(
            1, len(self.choices) - 1) if s.choice in self.choices else 0.0
        f[3 * MAX_LOOPS + 3] = 1.0 if rep.legal else 0.0
        return f

    def features_batch(self, schedules: list[Schedule],
                       reports=None) -> np.ndarray:
        """Feature rows for a whole frontier, (n, n_features): the report-
        derived entries come from ONE batched cost-model pass (or from
        ``reports`` when the caller already has them), not n scalar
        evaluations."""
        if not schedules:
            return np.zeros((0, self.n_features), dtype=np.float32)
        if reports is None:
            reports = self.report_batch(schedules)
        return np.stack([self.features(s, rep)
                         for s, rep in zip(schedules, reports)])
