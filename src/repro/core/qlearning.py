"""DQN revision policy for software DSE (paper §VI-B, Fig. 5(e)).

"We use the DQN algorithm to train a 4-layer fully-connected neural network,
which predicts Q-values.  The DQN is reused for all design points in a
software space."  Implemented in pure JAX: a 4-layer MLP, a numpy replay
buffer, epsilon-greedy action selection, TD(0) targets with a slow target
network, Adam updates — all jitted and CPU-friendly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _init_mlp(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (a, b)) * jnp.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros(b)})
    return params


def _forward(params, x):
    h = x
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    last = params[-1]
    return h @ last["w"] + last["b"]


@partial(jax.jit, static_argnames=())
def _td_loss(params, target_params, s, a, r, s2, done, gamma):
    q = _forward(params, s)
    q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    q_next = jnp.max(_forward(target_params, s2), axis=1)
    target = r + gamma * q_next * (1.0 - done)
    return jnp.mean((q_sa - jax.lax.stop_gradient(target)) ** 2)


@jax.jit
def _adam_step(params, grads, m, v, t, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    def upd(p, g, m_, v_):
        m2 = b1 * m_ + (1 - b1) * g
        v2 = b2 * v_ + (1 - b2) * g * g
        mh = m2 / (1 - b1 ** t)
        vh = v2 / (1 - b2 ** t)
        return p - lr * mh / (jnp.sqrt(vh) + eps), m2, v2

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return new_p, new_m, new_v


_grad_loss = jax.jit(jax.grad(_td_loss))


@dataclass
class Replay:
    capacity: int
    s: np.ndarray = None
    a: np.ndarray = None
    r: np.ndarray = None
    s2: np.ndarray = None
    done: np.ndarray = None
    n: int = 0
    ptr: int = 0

    def add(self, s, a, r, s2, done):
        if self.s is None:
            d = len(s)
            self.s = np.zeros((self.capacity, d), np.float32)
            self.s2 = np.zeros((self.capacity, d), np.float32)
            self.a = np.zeros(self.capacity, np.int32)
            self.r = np.zeros(self.capacity, np.float32)
            self.done = np.zeros(self.capacity, np.float32)
        i = self.ptr
        self.s[i], self.a[i], self.r[i], self.s2[i], self.done[i] = \
            s, a, r, s2, float(done)
        self.ptr = (i + 1) % self.capacity
        self.n = min(self.n + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.n, size=batch)
        return (self.s[idx], self.a[idx], self.r[idx], self.s2[idx],
                self.done[idx])


class DQN:
    """4-layer MLP Q-network with target network and replay."""

    def __init__(self, n_features: int, n_actions: int, hidden: int = 64,
                 gamma: float = 0.9, seed: int = 0, buffer: int = 4096):
        key = jax.random.PRNGKey(seed)
        sizes = (n_features, hidden, hidden, hidden, n_actions)
        self.params = _init_mlp(key, sizes)
        self.target_params = jax.tree_util.tree_map(lambda x: x, self.params)
        self.m = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        self.v = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        self.t = 0
        self.gamma = gamma
        self.n_actions = n_actions
        self.replay = Replay(buffer)
        self.rng = np.random.default_rng(seed)
        self.eps = 1.0
        self.eps_min = 0.05
        self.eps_decay = 0.97

    def q_values(self, feat: np.ndarray) -> np.ndarray:
        return np.asarray(_forward(self.params, jnp.asarray(feat[None, :])))[0]

    def q_values_batch(self, feats: np.ndarray) -> np.ndarray:
        """Q-values for a whole state batch, one network forward: (B, A)."""
        return np.asarray(_forward(self.params, jnp.asarray(feats)))

    def select(self, feat: np.ndarray) -> int:
        """Epsilon-greedy revision choice (the paper applies the highest-Q
        revision to the candidate)."""
        if self.rng.random() < self.eps:
            return int(self.rng.integers(self.n_actions))
        return int(np.argmax(self.q_values(feat)))

    def select_batch(self, feats: np.ndarray) -> np.ndarray:
        """Epsilon-greedy actions for the entire candidate frontier in one
        call: a single forward pass scores every state, then per-state
        exploration noise is applied (int array of shape (B,))."""
        feats = np.asarray(feats, np.float32)
        greedy = np.argmax(self.q_values_batch(feats), axis=1)
        explore = self.rng.random(len(feats)) < self.eps
        random_a = self.rng.integers(self.n_actions, size=len(feats))
        return np.where(explore, random_a, greedy).astype(int)

    def record(self, s, a, r, s2, done=False):
        self.replay.add(np.asarray(s, np.float32), a, r,
                        np.asarray(s2, np.float32), done)

    def train_step(self, batch: int = 32):
        if self.replay.n < batch:
            return None
        s, a, r, s2, done = self.replay.sample(self.rng, batch)
        self.t += 1
        grads = _grad_loss(self.params, self.target_params,
                           jnp.asarray(s), jnp.asarray(a), jnp.asarray(r),
                           jnp.asarray(s2), jnp.asarray(done),
                           self.gamma)
        self.params, self.m, self.v = _adam_step(
            self.params, grads, self.m, self.v, float(self.t))
        if self.t % 25 == 0:
            self.target_params = jax.tree_util.tree_map(
                lambda x: x, self.params)
        self.eps = max(self.eps_min, self.eps * self.eps_decay)
        return float(_td_loss(self.params, self.target_params,
                              jnp.asarray(s), jnp.asarray(a), jnp.asarray(r),
                              jnp.asarray(s2), jnp.asarray(done), self.gamma))
