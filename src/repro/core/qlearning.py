"""DQN revision policy for software DSE (paper §VI-B, Fig. 5(e)).

"We use the DQN algorithm to train a 4-layer fully-connected neural network,
which predicts Q-values.  The DQN is reused for all design points in a
software space."  Implemented in pure JAX: a 4-layer MLP, a numpy replay
buffer, epsilon-greedy action selection, TD(0) targets with a slow target
network, Adam updates — all jitted and CPU-friendly.

Two drivers share the same math (DESIGN.md §10):

  * :class:`DQN`     — one agent, one software space.  Used by the scalar
    ``engine="reference"`` DSE path.
  * :class:`DQNBank` — N independent agents advanced in lock-step by the
    batched DSE engine: parameters are stacked along a leading search axis,
    action selection is one vmapped forward over every search's frontier,
    and a round's N×k (record, train) transitions run as a single jitted
    ``lax.scan`` vmapped across searches.  Each agent replicates the exact
    update cadence and RNG stream of a standalone :class:`DQN`, which is
    what makes batched-vs-reference parity bit-exact.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _init_mlp(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (a, b)) * jnp.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros(b)})
    return params


def _forward(params, x):
    h = x
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    last = params[-1]
    return h @ last["w"] + last["b"]


@partial(jax.jit, static_argnames=())
def _td_loss(params, target_params, s, a, r, s2, done, gamma):
    q = _forward(params, s)
    q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    q_next = jnp.max(_forward(target_params, s2), axis=1)
    target = r + gamma * q_next * (1.0 - done)
    return jnp.mean((q_sa - jax.lax.stop_gradient(target)) ** 2)


def _lift(tree):
    """Add a leading singleton search axis: one DQN as a 1-slot bank."""
    return jax.tree_util.tree_map(lambda x: x[None], tree)


def _drop(tree):
    return jax.tree_util.tree_map(lambda x: x[0], tree)


@dataclass
class Replay:
    capacity: int
    s: np.ndarray = None
    a: np.ndarray = None
    r: np.ndarray = None
    s2: np.ndarray = None
    done: np.ndarray = None
    n: int = 0
    ptr: int = 0

    def add(self, s, a, r, s2, done):
        if self.s is None:
            d = len(s)
            self.s = np.zeros((self.capacity, d), np.float32)
            self.s2 = np.zeros((self.capacity, d), np.float32)
            self.a = np.zeros(self.capacity, np.int32)
            self.r = np.zeros(self.capacity, np.float32)
            self.done = np.zeros(self.capacity, np.float32)
        i = self.ptr
        self.s[i], self.a[i], self.r[i], self.s2[i], self.done[i] = \
            s, a, r, s2, float(done)
        self.ptr = (i + 1) % self.capacity
        self.n = min(self.n + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.n, size=batch)
        return (self.s[idx], self.a[idx], self.r[idx], self.s2[idx],
                self.done[idx])


class DQN:
    """4-layer MLP Q-network with target network and replay."""

    def __init__(self, n_features: int, n_actions: int, hidden: int = 64,
                 gamma: float = 0.9, seed: int = 0, buffer: int = 4096):
        key = jax.random.PRNGKey(seed)
        sizes = (n_features, hidden, hidden, hidden, n_actions)
        # 1-slot instance of the bank's stacked init: same compiled program
        # as DQNBank => bit-identical weights between the two drivers
        self.params = _drop(_bank_init(key[None], sizes))
        self.target_params = jax.tree_util.tree_map(lambda x: x, self.params)
        self.m = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        self.v = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        self.t = 0
        self.gamma = gamma
        self.n_actions = n_actions
        self.replay = Replay(buffer)
        self.rng = np.random.default_rng(seed)
        self.eps = 1.0
        self.eps_min = 0.05
        self.eps_decay = 0.97

    def q_values(self, feat: np.ndarray) -> np.ndarray:
        return np.asarray(_forward(self.params, jnp.asarray(feat[None, :])))[0]

    def q_values_batch(self, feats: np.ndarray) -> np.ndarray:
        """Q-values for a whole state batch, one network forward: (B, A).
        Runs the same compiled program as ``DQNBank`` (as a 1-slot bank) so
        both engines see bit-identical Q-values."""
        return np.asarray(_dqn_forward(self.params,
                                       jnp.asarray(feats, jnp.float32)))

    def select(self, feat: np.ndarray) -> int:
        """Epsilon-greedy revision choice (the paper applies the highest-Q
        revision to the candidate)."""
        if self.rng.random() < self.eps:
            return int(self.rng.integers(self.n_actions))
        return int(np.argmax(self.q_values(feat)))

    def select_batch(self, feats: np.ndarray) -> np.ndarray:
        """Epsilon-greedy actions for the entire candidate frontier in one
        call: a single forward pass scores every state, then per-state
        exploration noise is applied (int array of shape (B,))."""
        feats = np.asarray(feats, np.float32)
        greedy = np.argmax(self.q_values_batch(feats), axis=1)
        explore = self.rng.random(len(feats)) < self.eps
        random_a = self.rng.integers(self.n_actions, size=len(feats))
        return np.where(explore, random_a, greedy).astype(int)

    def record(self, s, a, r, s2, done=False):
        self.replay.add(np.asarray(s, np.float32), a, r,
                        np.asarray(s2, np.float32), done)

    def train_step(self, batch: int = 32):
        """One TD(0) update; returns the minibatch loss (pre-update, straight
        from the same ``value_and_grad`` pass as the gradients — no extra
        network forward just to report a scalar).  Dispatches ONE jitted
        call: the same N=1, m=1 instance of the program ``DQNBank`` runs
        per round, so reference and lock-step weight trajectories are
        bit-identical."""
        if self.replay.n < batch:
            return None
        s, a, r, s2, done = self.replay.sample(self.rng, batch)
        (self.params, self.target_params, self.m, self.v), loss = \
            _dqn_train_step(self.params, self.target_params, self.m, self.v,
                            np.int32(self.t), jnp.asarray(s), jnp.asarray(a),
                            jnp.asarray(r), jnp.asarray(s2),
                            jnp.asarray(done), self.gamma)
        self.t += 1
        self.eps = max(self.eps_min, self.eps * self.eps_decay)
        return float(loss)


# ---------------------------------------------------------------------------
# DQNBank: N per-search agents advanced in lock-step (DESIGN.md §10)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("sizes",))
def _bank_init(keys, sizes):
    """Stacked per-search MLP init: one compiled call, same per-key values as
    N standalone ``_init_mlp`` calls."""
    return jax.vmap(lambda k: _init_mlp(k, sizes))(keys)


_bank_forward = jax.jit(jax.vmap(_forward))


def _bank_step(gamma, carry, inp):
    """One train step of one agent — the body of the per-round scan, and
    (at N=1, m=1) the whole of ``DQN.train_step``: TD(0) loss + grads from
    one ``value_and_grad`` pass over the (pre-gathered) minibatch, Adam,
    slow target sync every 25 updates.  ``do_train`` masks the whole update
    (padding of ragged rounds).  Reference and batched engines share THIS
    compiled program, which is what makes their weight trajectories — not
    just their decisions — bit-identical."""
    params, target, m, v, t = carry
    bs, ba, br, bs2, bd, do_train = inp

    loss, grads = jax.value_and_grad(_td_loss)(params, target, bs, ba, br,
                                               bs2, bd, gamma)
    t2 = t + do_train.astype(jnp.int32)
    tf = t2.astype(jnp.float32)
    lr, b1, b2, eps = 1e-3, 0.9, 0.999, 1e-8

    def upd(p, g, m_, v_):
        m2 = b1 * m_ + (1 - b1) * g
        v2 = b2 * v_ + (1 - b2) * g * g
        mh = m2 / (1 - b1 ** tf)
        vh = v2 / (1 - b2 ** tf)
        return p - lr * mh / (jnp.sqrt(vh) + eps), m2, v2

    flat_p, tree = jax.tree_util.tree_flatten(params)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in
           zip(flat_p, jax.tree_util.tree_leaves(grads),
               jax.tree_util.tree_leaves(m), jax.tree_util.tree_leaves(v))]
    pick = lambda new, old: jnp.where(do_train, new, old)
    params = jax.tree_util.tree_unflatten(
        tree, [pick(o[0], p) for o, p in zip(out, flat_p)])
    m = jax.tree_util.tree_unflatten(
        tree, [pick(o[1], x) for o, x in
               zip(out, jax.tree_util.tree_leaves(m))])
    v = jax.tree_util.tree_unflatten(
        tree, [pick(o[2], x) for o, x in
               zip(out, jax.tree_util.tree_leaves(v))])
    sync = do_train & (t2 % 25 == 0)
    target = jax.tree_util.tree_map(
        lambda tp, p: jnp.where(sync, p, tp), target, params)
    return (params, target, m, v, t2), loss


@jax.jit
def _bank_train_steps(params, target, m, v, t, S, A, R, S2, D, DT, gamma):
    """A whole round's training work in one dispatch: scan over each agent's
    (up to) m sequential train steps, vmapped across the N agents.  Returns
    the updated agent state and the per-step losses (N, m)."""

    def per_search(params, target, m, v, t, S, A, R, S2, D, DT):
        return jax.lax.scan(partial(_bank_step, gamma),
                            (params, target, m, v, t),
                            (S, A, R, S2, D, DT))

    return jax.vmap(per_search)(params, target, m, v, t, S, A, R, S2, D, DT)


@jax.jit
def _dqn_forward(params, x):
    """Single-DQN forward as a 1-slot bank (lift/drop fuse away under jit)."""
    return _bank_forward(_lift(params), x[None])[0]


@jax.jit
def _dqn_train_step(params, target, m, v, t, s, a, r, s2, d, gamma):
    """Single-DQN train step: the N=1, m=1 instance of the bank scan, with
    the lift/drop reshapes inside the compiled program."""
    (p, tp, m2, v2, t2), loss = _bank_train_steps(
        _lift(params), _lift(target), _lift(m), _lift(v),
        jnp.reshape(t, (1,)), s[None, None], a[None, None], r[None, None],
        s2[None, None], d[None, None], jnp.ones((1, 1), bool), gamma)
    return (_drop(p), _drop(tp), _drop(m2), _drop(v2)), loss[0, 0]


class DQNBank:
    """N independent per-search DQNs advanced in lock-step.

    Each slot replicates a standalone ``DQN(n_features, n_actions, seed=s)``
    bit-for-bit: same init key, same numpy action/sample RNG stream, same
    epsilon schedule, same Adam/target cadence.  What changes is the
    execution shape — parameters are stacked along a leading search axis so
    one vmapped forward scores every search's frontier (:meth:`select_round`)
    and one jitted vmapped scan applies every search's round of replay
    inserts + train steps (:meth:`train_round`).
    """

    def __init__(self, n_features: int, n_actions: int, seeds: list[int],
                 hidden: int = 64, gamma: float = 0.9, buffer: int = 4096,
                 batch: int = 32):
        sizes = (n_features, hidden, hidden, hidden, n_actions)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
        self.params = _bank_init(keys, sizes)
        self.target_params = jax.tree_util.tree_map(lambda x: x, self.params)
        self.m = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        self.v = jax.tree_util.tree_map(jnp.zeros_like, self.params)
        N = len(seeds)
        self.n_searches = N
        self.n_actions = n_actions
        self.gamma = gamma
        self.batch = batch
        self.t = jnp.zeros(N, jnp.int32)
        self.replays = [Replay(buffer) for _ in seeds]
        self.rngs = [np.random.default_rng(s) for s in seeds]
        self.eps = np.full(N, 1.0)
        self.eps_min = 0.05
        self.eps_decay = 0.97

    def q_values_round(self, feats: np.ndarray) -> np.ndarray:
        """Q-values for every search's frontier, one vmapped forward:
        feats (N, k, F) -> (N, k, A)."""
        return np.asarray(_bank_forward(self.params,
                                        jnp.asarray(feats, jnp.float32)))

    def select_round(self, feats: np.ndarray,
                     counts: list[int] | None = None) -> np.ndarray:
        """Epsilon-greedy actions for all N frontiers in one network pass;
        per-search exploration noise drawn from that search's own RNG in the
        same order a standalone ``DQN.select_batch`` would (int (N, k)).

        ``counts`` marks how many leading rows of each search's frontier are
        real (ragged feasible-only frontiers arrive zero-padded to k): only
        those consume RNG draws — a search whose reference twin would have
        called ``select_batch`` on m states must advance its stream by
        exactly m — and the padded tail comes back zeroed."""
        q = self.q_values_round(np.asarray(feats, np.float32))
        greedy = np.argmax(q, axis=2)
        N, k = greedy.shape
        acts = np.zeros((N, k), dtype=int)
        for s in range(N):
            m = k if counts is None else counts[s]
            if not m:
                continue
            explore = self.rngs[s].random(m) < self.eps[s]
            random_a = self.rngs[s].integers(self.n_actions, size=m)
            acts[s, :m] = np.where(explore, random_a, greedy[s, :m])
        return acts

    def train_round(self, s: np.ndarray, a: np.ndarray, r: np.ndarray,
                    s2: np.ndarray, done: np.ndarray | None = None,
                    counts: list[int] | None = None) -> None:
        """Record + learn a whole round of transitions: (N, k, F) states,
        (N, k) actions/rewards.  Replay inserts and minibatch draws run
        host-side per search (identical ``Replay`` semantics and RNG stream
        to the reference per-transition loop); every search's sequential
        train steps then run as ONE jitted vmapped scan.  Rounds where no
        replay is warm enough dispatch nothing at all.  ``counts`` bounds
        how many leading transitions per search are real (ragged
        feasible-only frontiers zero-pad to k); only those are recorded."""
        N, k = a.shape
        if done is None:
            done = np.zeros((N, k), np.float32)
        batches: list[list[tuple]] = [[] for _ in range(N)]
        for si in range(N):
            rep, rng = self.replays[si], self.rngs[si]
            for j in range(k if counts is None else counts[si]):
                rep.add(np.asarray(s[si, j], np.float32), a[si, j], r[si, j],
                        np.asarray(s2[si, j], np.float32), done[si, j])
                if rep.n >= self.batch:
                    batches[si].append(rep.sample(rng, self.batch))
                    self.eps[si] = max(self.eps_min,
                                       self.eps[si] * self.eps_decay)
        if all(len(b) == 0 for b in batches):
            return
        # pad the step axis to k (the per-round maximum) so one scan shape
        # serves the warm-up round and steady state alike — one compile per
        # engine configuration instead of one per replay fill level
        m_steps = k
        F = s.shape[-1]
        pad = (np.zeros((self.batch, F), np.float32),
               np.zeros(self.batch, np.int32),
               np.zeros(self.batch, np.float32),
               np.zeros((self.batch, F), np.float32),
               np.zeros(self.batch, np.float32))
        stacked = [np.stack([
            np.stack([bl[step][part] if step < len(bl) else pad[part]
                      for step in range(m_steps)])
            for bl in batches]) for part in range(5)]
        dt = np.array([[step < len(bl) for step in range(m_steps)]
                       for bl in batches])
        (self.params, self.target_params, self.m, self.v,
         self.t), _ = _bank_train_steps(
            self.params, self.target_params, self.m, self.v, self.t,
            *(jnp.asarray(x) for x in stacked), jnp.asarray(dt), self.gamma)
