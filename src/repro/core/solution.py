"""Solution registry: persisted co-design outputs consumed by the framework.

The HASCO flow produces (accelerator config, per-workload schedules); the
training/serving framework consumes the accelerator config as the *tuned
Pallas kernel configuration* (block shapes, pipeline depth) — this is how the
paper's technique becomes a first-class feature of the framework
(DESIGN.md §2: the co-designed "hardware" is the kernel resource envelope).
"""
from __future__ import annotations

import json
from dataclasses import asdict
from pathlib import Path

from .codesign import Solution
from .hw_primitives import HWConfig

DEFAULT_PATH = Path("artifacts/solutions.json")


def save(app: str, sol: Solution, path: Path | str = DEFAULT_PATH) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    data = json.loads(path.read_text()) if path.exists() else {}
    data[app] = {
        "hw": asdict(sol.hw),
        "intrinsic": sol.intrinsic,
        "latency_s": sol.latency_s,
        "power_w": sol.power_w,
        "area_um2": sol.area_um2,
        "schedules": {
            w: {"tiles": list(map(list, s.tiles)), "order": list(s.order),
                "fuse_outer": s.fuse_outer,
                "index_map": list(map(list, s.choice.index_map))}
            for w, s in sol.schedules.items()},
    }
    path.write_text(json.dumps(data, indent=2, sort_keys=True))


def load_hw(app: str, path: Path | str = DEFAULT_PATH) -> HWConfig | None:
    path = Path(path)
    if not path.exists():
        return None
    data = json.loads(path.read_text())
    if app not in data:
        return None
    return HWConfig(**data[app]["hw"])


def kernel_blocks(app: str, path: Path | str = DEFAULT_PATH,
                  default: tuple[int, int, int] = (256, 256, 512)
                  ) -> tuple[int, int, int]:
    """Tuned (bm, bn, bk) Pallas block shape for the app's GEMM kernel,
    clamped to MXU-legal multiples."""
    hw = load_hw(app, path)
    if hw is None:
        return default

    def legal(x: int, lane: int) -> int:
        return max(lane, (x // lane) * lane)

    return (legal(hw.pe_rows, 8), legal(hw.pe_cols, 128),
            legal(hw.pe_depth, 128))
