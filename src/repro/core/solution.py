"""Solution registry: persisted co-design outputs consumed by the framework.

The HASCO flow produces (accelerator config, per-workload schedules); the
training/serving framework consumes the accelerator config as the *tuned
Pallas kernel configuration* (block shapes, pipeline depth) — this is how the
paper's technique becomes a first-class feature of the framework
(DESIGN.md §2: the co-designed "hardware" is the kernel resource envelope).

This per-app registry is subsumed by the measured tuning database
(``repro.tuner.db``): the DB stores shape-exact measured kernel records plus
an ``apps`` section equivalent to this file's schema, and the dispatch layer
(``kernels/ops.py``) consults the DB first.  The registry remains the
lightweight analytical-only artifact and shares the same robustness
contract: corrupt or missing files load as empty with a warning (a bad
artifact must never take down a launch), and saves are atomic
(tmp file + rename) and merge-on-save.
"""
from __future__ import annotations

import warnings
from dataclasses import asdict
from pathlib import Path

from .artifacts import atomic_write_json, read_json_object
from .codesign import Solution
from .hw_primitives import HWConfig

DEFAULT_PATH = Path("artifacts/solutions.json")


def _read_registry(path: Path) -> dict:
    """Missing/corrupt registries are empty, never fatal."""
    return read_json_object(path, "solution registry")


def save(app: str, sol: Solution, path: Path | str = DEFAULT_PATH) -> None:
    """Merge ``sol`` into the registry under ``app``, atomically.

    Existing apps are preserved (merge-on-save); the write goes through a
    temp file + rename so readers never observe a torn artifact.
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    data = _read_registry(path)
    data[app] = {
        "hw": asdict(sol.hw),
        "intrinsic": sol.intrinsic,
        "latency_s": sol.latency_s,
        "power_w": sol.power_w,
        "area_um2": sol.area_um2,
        "schedules": {
            w: {"tiles": list(map(list, s.tiles)), "order": list(s.order),
                "fuse_outer": s.fuse_outer,
                "index_map": list(map(list, s.choice.index_map))}
            for w, s in sol.schedules.items()},
    }
    atomic_write_json(path, data)


def load_hw(app: str, path: Path | str = DEFAULT_PATH) -> HWConfig | None:
    """The app's co-designed accelerator, or None (missing app, missing
    file, corrupt file, malformed entry — all non-fatal)."""
    data = _read_registry(Path(path))
    entry = data.get(app)
    if not isinstance(entry, dict) or "hw" not in entry:
        return None
    try:
        return HWConfig(**entry["hw"])
    except (TypeError, ValueError) as e:
        warnings.warn(f"solution registry {path}: malformed hw entry for "
                      f"{app!r} ({e})", stacklevel=2)
        return None


def mxu_legal(x: int, lane: int) -> int:
    """Clamp a block dim down to an MXU-legal multiple of ``lane`` (floor,
    never below one lane) — the one place this rule lives."""
    return max(lane, (int(x) // lane) * lane)


def kernel_blocks(app: str, path: Path | str = DEFAULT_PATH,
                  default: tuple[int, int, int] = (256, 256, 512)
                  ) -> tuple[int, int, int]:
    """Tuned (bm, bn, bk) Pallas block shape for the app's GEMM kernel,
    clamped to MXU-legal multiples."""
    hw = load_hw(app, path)
    if hw is None:
        return default
    return (mxu_legal(hw.pe_rows, 8), mxu_legal(hw.pe_cols, 128),
            mxu_legal(hw.pe_depth, 128))
