"""The hardware design space (paper §V-A) and its encoding for DSE.

Each knob is an ordinal axis; a design point encodes to a normalized vector in
[0,1]^d for the GP surrogate and to an index tuple for NSGA-II crossover /
mutation.  Legality prunes points whose minimal working set cannot fit the
declared VMEM budget (the paper's scratchpad constraint).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .hw_primitives import DATAFLOWS, HWConfig

# ordinal axes of the space (TPU-aligned values; DESIGN.md §2)
AXES: dict[str, tuple] = {
    "pe_rows": (8, 16, 32, 64, 128, 256, 512),
    "pe_cols": (8, 16, 32, 64, 128, 256, 512),
    "pe_depth": (8, 16, 32, 64, 128, 256, 512, 1024, 2048),
    "vmem_kib": (128, 256, 512, 1024, 2048, 4096, 8192, 12288, 16384),
    "banks": (1, 2, 3, 4),
    "local_accum_kib": (0, 64, 256, 1024),
    "burst_bytes": (256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536),
    "dataflow": DATAFLOWS,
}
_AXIS_NAMES = tuple(AXES)

#: Opt-in tensor-parallelism axis: pass ``codesign(...,
#: space_axes=PARALLELISM_AXES)`` to let MOBO explore (chip config × TP
#: degree) jointly — the cost model charges the per-call all-reduce over
#: ``Target.link_gbps`` and scales area/static power by the chip count.
#: Kept out of the default AXES so seeded single-chip searches (and their
#: goldens) are untouched.
PARALLELISM_AXES: dict[str, tuple] = {"tp": (1, 2, 4, 8)}


@dataclass
class HWSpace:
    """Legal hardware design space for one intrinsic."""

    intrinsic: str = "GEMM"
    axes: dict[str, tuple] = field(default_factory=lambda: dict(AXES))

    def __post_init__(self) -> None:
        self.intrinsic = self.intrinsic.upper()
        self._names = tuple(self.axes)
        self._sizes = tuple(len(self.axes[n]) for n in self._names)

    # -- size / enumeration ---------------------------------------------------
    @property
    def size(self) -> int:
        n = 1
        for s in self._sizes:
            n *= s
        return n

    def config(self, idx: tuple[int, ...]) -> HWConfig:
        kw = {n: self.axes[n][i] for n, i in zip(self._names, idx)}
        return HWConfig(intrinsic=self.intrinsic, **kw)

    def index_of(self, hw: HWConfig) -> tuple[int, ...]:
        return tuple(self.axes[n].index(getattr(hw, n)) for n in self._names)

    def legal(self, hw: HWConfig) -> bool:
        """Minimal working set (one intrinsic tile per operand, double
        buffered per bank policy) must fit the scratchpad."""
        dt = 2  # bf16
        if hw.intrinsic == "GEMM":
            tile = (hw.pe_rows * hw.pe_depth + hw.pe_depth * hw.pe_cols
                    + hw.pe_rows * hw.pe_cols * 2)  # f32 accumulator
        elif hw.intrinsic == "GEMV":
            tile = hw.pe_rows * hw.pe_depth + hw.pe_depth + hw.pe_rows * 2
        elif hw.intrinsic == "DOT":
            tile = 2 * hw.pe_depth + 2
        else:  # CONV2D: 3x3 window halo on an rows x depth input tile
            tile = (hw.pe_depth * (hw.pe_rows + 2) * 3
                    + hw.pe_cols * hw.pe_depth * 9
                    + hw.pe_rows * hw.pe_cols * 2)
        need = tile * dt * max(1, min(hw.banks, 2))
        if need > hw.vmem_bytes:
            return False
        if hw.local_accum_kib * 1024 > hw.vmem_bytes // 4:
            return False
        return True

    # -- sampling & encoding ---------------------------------------------------
    def sample(self, rng: np.random.Generator, n: int,
               exclude: set[tuple] | None = None) -> list[HWConfig]:
        exclude = exclude or set()
        out: list[HWConfig] = []
        seen: set[tuple] = set()
        attempts = 0
        while len(out) < n and attempts < 200 * n:
            attempts += 1
            idx = tuple(int(rng.integers(s)) for s in self._sizes)
            if idx in seen:
                continue
            seen.add(idx)
            hw = self.config(idx)
            if hw.encode() in exclude or not self.legal(hw):
                continue
            out.append(hw)
        return out

    def encode01(self, hw: HWConfig) -> np.ndarray:
        """Normalized [0,1]^d vector for the GP (ordinal axes scaled)."""
        idx = self.index_of(hw)
        return np.array([i / max(1, s - 1) for i, s in zip(idx, self._sizes)],
                        dtype=float)

    def mutate(self, hw: HWConfig, rng: np.random.Generator,
               p: float = 0.25) -> HWConfig:
        idx = list(self.index_of(hw))
        for k, s in enumerate(self._sizes):
            if rng.random() < p:
                step = int(rng.integers(1, 3)) * (1 if rng.random() < 0.5 else -1)
                idx[k] = int(np.clip(idx[k] + step, 0, s - 1))
        cand = self.config(tuple(idx))
        return cand if self.legal(cand) else hw

    def crossover(self, a: HWConfig, b: HWConfig,
                  rng: np.random.Generator) -> HWConfig:
        ia, ib = self.index_of(a), self.index_of(b)
        idx = tuple(ia[k] if rng.random() < 0.5 else ib[k]
                    for k in range(len(ia)))
        cand = self.config(idx)
        return cand if self.legal(cand) else a
