"""Multi-objective Bayesian optimization (paper §V-B, Algorithm 1).

GP surrogate per objective (log-space), hypervolume-based probability of
improvement acquisition [Auger et al.]: the acquisition of a candidate is the
Monte-Carlo probability that its posterior draw enlarges the current
dominated hypervolume, tie-broken by the expected enlargement.

Acquisition runs on the vectorized Pareto engine (DESIGN.md §9): the current
front's :class:`~repro.core.pareto.BoxDecomposition` is built once per trial
and both the candidate prefilter and the MC draws are scored through one
``hvi`` pass each.  ``q > 1`` turns each trial into a q-batch suggestion —
greedy sequential hypervolume improvement with in-loop fantasy-front
augmentation — so a whole population per trial flows through
``batch_objectives`` (and, in the co-design flow, through the shared
``EvalCache``).  In the co-design flow, ``batch_objectives`` is
``hw_objectives_batch``: the trial's q × len(workloads) inner software
searches resolve in ONE lock-step batched-DSE engine pass (DESIGN.md
§10).  ``acquisition="reference"`` keeps the pre-engine per-candidate
scoring loops for parity benchmarks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro import obs

from .hw_primitives import HWConfig
from .hw_space import HWSpace
from .pareto import (IncrementalHV, _reference_hypervolume, default_reference,
                     pareto_mask)
from .surrogate import fit_gps

Objectives = Callable[[HWConfig], tuple[float, ...]]
# batched form: a population of configs -> (n, n_obj) array in one call
BatchObjectives = Callable[[Sequence[HWConfig]], np.ndarray]


def as_batch(objectives: Objectives,
             batch_objectives: BatchObjectives | None) -> BatchObjectives:
    """Promote a scalar objectives callable to the batched protocol (the
    explorers only speak batch; scalar callers pay a per-config loop)."""
    if batch_objectives is not None:
        return batch_objectives
    return lambda configs: np.array([objectives(c) for c in configs],
                                    dtype=float)


@dataclass
class DSEResult:
    configs: list[HWConfig]
    ys: np.ndarray                       # (n, n_obj), minimized
    hv_history: list[float]              # hypervolume after each trial
    evaluations: int
    ref: np.ndarray

    @property
    def pareto_configs(self) -> list[HWConfig]:
        mask = pareto_mask(self.ys)
        return [c for c, m in zip(self.configs, mask) if m]

    @property
    def pareto_ys(self) -> np.ndarray:
        return self.ys[pareto_mask(self.ys)]

    def best_under(self, constraints: dict[int, float]) -> tuple[HWConfig, np.ndarray] | None:
        """Lowest-latency (objective 0) point satisfying y[i] <= bound."""
        ok = np.ones(len(self.ys), dtype=bool)
        for i, bound in constraints.items():
            ok &= self.ys[:, i] <= bound
        if not ok.any():
            return None
        idx = int(np.argmin(np.where(ok, self.ys[:, 0], np.inf)))
        return self.configs[idx], self.ys[idx]


def _finite_rows(ys: np.ndarray) -> np.ndarray:
    return np.all(np.isfinite(ys), axis=1)


def _log_rows(ys: np.ndarray) -> np.ndarray:
    return np.log10(np.maximum(ys, 1e-30))


def shared_reference(results: list[DSEResult], margin: float = 1.3) -> np.ndarray:
    """A common reference point over several DSE runs so their hypervolume
    histories are comparable (paper Fig. 10 plots all methods on one axis)."""
    rows = []
    for r in results:
        m = _finite_rows(r.ys)
        if m.any():
            rows.append(_log_rows(r.ys[m]))
    if not rows:
        # every objective of every run came back infeasible: all hypervolume
        # curves are identically zero, so any finite reference works
        d = results[0].ys.shape[1] if results else 1
        return np.ones(d)
    return default_reference(np.vstack(rows), margin=margin)


def rescore_hv_history(result: DSEResult, ref: np.ndarray) -> list[float]:
    """Recompute a run's hypervolume-vs-trial curve under a shared ref.

    Maintains an incremental front: each trial folds one point into an
    :class:`IncrementalHV` instead of recomputing the full prefix
    hypervolume from scratch (O(n) decomposition queries vs O(n^2) sweeps).
    """
    tracker = IncrementalHV(ref)
    out = []
    for y in result.ys:
        if np.all(np.isfinite(y)):
            tracker.add(_log_rows(y))
        out.append(tracker.hv)
    return out


def _acquire_reference(space: HWSpace, gps, cands: list[HWConfig],
                       Ylog: np.ndarray, ref: np.ndarray,
                       rng: np.random.Generator, n_draws: int,
                       n_candidates: int) -> HWConfig:
    """The pre-engine acquisition: per-candidate hypervolume recomputation in
    Python loops.  Kept as the parity/wall-clock baseline for
    ``benchmarks/bench_acquisition.py``; not a production path."""
    hv = _reference_hypervolume
    hv_now = hv(Ylog, ref)
    Xc = np.stack([space.encode01(c) for c in cands])
    # stage 1: rank by HVI of the posterior mean (cheap prefilter)
    means = np.stack([g.predict(Xc)[0] for g in gps], axis=-1)
    mean_hvi = np.array([
        hv(np.vstack([Ylog, m]), ref) - hv_now
        if np.all(m < ref) else 0.0 for m in means])
    top = np.argsort(-mean_hvi)[: max(8, n_candidates // 8)]
    # stage 2: MC hypervolume-PoI on the shortlist
    draws = np.stack([g.sample(Xc[top], n_draws, rng) for g in gps],
                     axis=-1)                # (draws, top, n_obj)
    prob = np.zeros(len(top))
    gain = np.zeros(len(top))
    for d in range(n_draws):
        for c in range(len(top)):
            y_new = draws[d, c]
            if np.any(y_new >= ref):
                continue
            hv_new = hv(np.vstack([Ylog, y_new]), ref)
            if hv_new > hv_now + 1e-12:
                prob[c] += 1.0
                gain[c] += hv_new - hv_now
    prob /= n_draws
    gain /= n_draws
    score = gain + 1e-3 * prob * (abs(hv_now) + 1e-9)
    return cands[int(top[int(np.argmax(score))])]


def _acquire(space: HWSpace, gps, cands: list[HWConfig],
             tracker: IncrementalHV, rng: np.random.Generator, n_draws: int,
             n_candidates: int, q: int) -> list[HWConfig]:
    """Vectorized q-batch acquisition.

    One box decomposition of the current front scores the 256-candidate
    posterior-mean prefilter in a single ``hvi`` pass; the shortlist's
    ``n_draws × |shortlist|`` posterior draws are scored in one more.  For
    ``q > 1``, picks are greedy-sequential joint-draw HVI: every MC draw
    keeps its own fantasy front, augmented after each pick with *that
    draw's* sample of the pick, so the batch hedges across posterior
    scenarios instead of piling onto the region one optimistic mean
    dominates.  With ``q=1`` the single pick scores against the shared
    decomposition — identical to the classic loop.
    """
    hv_now = tracker.hv
    Xc = np.stack([space.encode01(c) for c in cands])
    means = np.stack([g.predict(Xc)[0] for g in gps], axis=-1)
    mean_hvi = tracker.decomposition.hvi(means)
    top = np.argsort(-mean_hvi)[: max(8, n_candidates // 8)]
    draws = np.stack([g.sample(Xc[top], n_draws, rng) for g in gps],
                     axis=-1)                # (draws, top, n_obj)
    picked: list[int] = []
    fantasies: list[IncrementalHV] | None = None
    q_eff = min(q, len(top))            # a thin candidate pool caps the batch
    for _ in range(q_eff):
        if fantasies is None:                # first pick: shared front
            hvi = tracker.decomposition.hvi(
                draws.reshape(-1, draws.shape[-1])).reshape(n_draws, len(top))
        else:
            hvi = np.stack([f.decomposition.hvi(draws[d])
                            for d, f in enumerate(fantasies)])
        improving = hvi > 1e-12
        gain = np.where(improving, hvi, 0.0).mean(axis=0)
        prob = improving.mean(axis=0)
        score = gain + 1e-3 * prob * (abs(hv_now) + 1e-9)
        score[picked] = -np.inf
        j = int(np.argmax(score))
        picked.append(j)
        if len(picked) < q_eff:
            if fantasies is None:
                fantasies = [tracker.copy() for _ in range(n_draws)]
            for d, f in enumerate(fantasies):
                f.add(draws[d, j])
    return [cands[int(top[j])] for j in picked]


def mobo(space: HWSpace, objectives: Objectives, *, n_init: int = 5,
         n_trials: int = 20, seed: int = 0, n_candidates: int = 256,
         n_draws: int = 24, ref: np.ndarray | None = None,
         batch_objectives: BatchObjectives | None = None, q: int = 1,
         acquisition: str = "vectorized") -> DSEResult:
    """Algorithm 1.  ``objectives`` returns minimized metrics, e.g.
    (latency_s, power_w, area_um2).  ``batch_objectives``, when given, scores
    whole populations per call (the initial design, and each trial's picks)
    through the batched cost-model path.

    ``q`` is the suggestion batch size: each acquisition round proposes ``q``
    distinct configs (greedy sequential HVI) and evaluates them with one
    batched objectives call.  ``q=1`` reproduces the classic single-pick
    loop.  ``acquisition`` selects the engine: ``"vectorized"`` (default) or
    ``"reference"`` (pre-engine scalar loops; q must be 1).
    """
    if acquisition not in ("vectorized", "reference"):
        raise ValueError(f"unknown acquisition engine: {acquisition!r}")
    q = max(1, int(q))
    if acquisition == "reference" and q != 1:
        raise ValueError("reference acquisition only supports q=1")
    rng = np.random.default_rng(seed)
    fbatch = as_batch(objectives, batch_objectives)

    configs: list[HWConfig] = space.sample(rng, n_init)
    with obs.span("mobo.init_design"):
        ys = np.asarray(fbatch(configs), dtype=float)
    tried = {c.encode() for c in configs}

    fin = _finite_rows(ys)
    if ref is None:
        base = ys[fin] if fin.any() else np.ones((1, ys.shape[1]))
        ref = default_reference(_log_rows(base), margin=1.3)

    tracker = IncrementalHV(ref)
    for y in ys:
        if np.all(np.isfinite(y)):
            tracker.add(_log_rows(y))
    hv_history = [0.0] * (len(configs) - 1) + [tracker.hv]

    st = obs.state()
    while len(configs) < n_trials:
        with obs.span("mobo.trial"):
            fin = _finite_rows(ys)
            if fin.sum() >= 2:
                # impute illegal/failed points at a log-space penalty above
                # the observed worst so the surrogate learns to avoid them
                # (dropping them wastes the paper's scarce trials on
                # infeasible regions)
                X = np.stack([space.encode01(c) for c in configs])
                Ylog = _log_rows(ys)
                worst = np.nanmax(np.where(np.isfinite(Ylog), Ylog, np.nan),
                                  axis=0)
                Y = np.where(np.isfinite(Ylog), Ylog, worst + 1.0)
                with obs.span("mobo.fit_gps"):
                    # one shared kernel sweep for all objectives
                    gps = fit_gps(X, Y)
            else:
                gps = None

            cands = space.sample(rng, n_candidates, exclude=tried)
            if not cands:
                break
            q_now = min(q, n_trials - len(configs))
            with obs.span("mobo.acquire"):
                if gps is None:
                    picks = cands[:q_now]
                elif acquisition == "reference":
                    picks = [_acquire_reference(space, gps, cands,
                                                _log_rows(ys[fin]), ref, rng,
                                                n_draws, n_candidates)]
                else:
                    picks = _acquire(space, gps, cands, tracker, rng,
                                     n_draws, n_candidates, q_now)

            with obs.span("mobo.evaluate"):
                ys_new = np.asarray(fbatch(picks), dtype=float)
            for pick, y in zip(picks, ys_new):
                configs.append(pick)
                tried.add(pick.encode())
                ys = np.vstack([ys, y[None, :]])
                if np.all(np.isfinite(y)):
                    tracker.add(_log_rows(y))
                hv_history.append(tracker.hv)
            if st is not None:
                # the HV-vs-trial trajectory, one point per MOBO round
                st.tracer.instant("mobo.hv", {"trial": len(configs),
                                              "hv": tracker.hv})
                st.metrics.gauge("mobo.hv").set(tracker.hv)
                st.metrics.counter("mobo.trials").inc()

    return DSEResult(configs, ys, hv_history, len(configs), ref)
