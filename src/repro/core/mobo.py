"""Multi-objective Bayesian optimization (paper §V-B, Algorithm 1).

GP surrogate per objective (log-space), hypervolume-based probability of
improvement acquisition [Auger et al.]: the acquisition of a candidate is the
Monte-Carlo probability that its posterior draw enlarges the current
dominated hypervolume, tie-broken by the expected enlargement.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from .hw_primitives import HWConfig
from .hw_space import HWSpace
from .pareto import default_reference, hypervolume, pareto_mask
from .surrogate import fit_gps

Objectives = Callable[[HWConfig], tuple[float, ...]]
# batched form: a population of configs -> (n, n_obj) array in one call
BatchObjectives = Callable[[Sequence[HWConfig]], np.ndarray]


def as_batch(objectives: Objectives,
             batch_objectives: BatchObjectives | None) -> BatchObjectives:
    """Promote a scalar objectives callable to the batched protocol (the
    explorers only speak batch; scalar callers pay a per-config loop)."""
    if batch_objectives is not None:
        return batch_objectives
    return lambda configs: np.array([objectives(c) for c in configs],
                                    dtype=float)


@dataclass
class DSEResult:
    configs: list[HWConfig]
    ys: np.ndarray                       # (n, n_obj), minimized
    hv_history: list[float]              # hypervolume after each trial
    evaluations: int
    ref: np.ndarray

    @property
    def pareto_configs(self) -> list[HWConfig]:
        mask = pareto_mask(self.ys)
        return [c for c, m in zip(self.configs, mask) if m]

    @property
    def pareto_ys(self) -> np.ndarray:
        return self.ys[pareto_mask(self.ys)]

    def best_under(self, constraints: dict[int, float]) -> tuple[HWConfig, np.ndarray] | None:
        """Lowest-latency (objective 0) point satisfying y[i] <= bound."""
        ok = np.ones(len(self.ys), dtype=bool)
        for i, bound in constraints.items():
            ok &= self.ys[:, i] <= bound
        if not ok.any():
            return None
        idx = int(np.argmin(np.where(ok, self.ys[:, 0], np.inf)))
        return self.configs[idx], self.ys[idx]


def _finite_rows(ys: np.ndarray) -> np.ndarray:
    return np.all(np.isfinite(ys), axis=1)


def shared_reference(results: list[DSEResult], margin: float = 1.3) -> np.ndarray:
    """A common reference point over several DSE runs so their hypervolume
    histories are comparable (paper Fig. 10 plots all methods on one axis)."""
    rows = []
    for r in results:
        m = _finite_rows(r.ys)
        if m.any():
            rows.append(np.log10(np.maximum(r.ys[m], 1e-30)))
    return default_reference(np.vstack(rows), margin=margin)


def rescore_hv_history(result: DSEResult, ref: np.ndarray) -> list[float]:
    """Recompute a run's hypervolume-vs-trial curve under a shared ref."""
    out = []
    for i in range(1, len(result.ys) + 1):
        sub = result.ys[:i]
        m = _finite_rows(sub)
        out.append(hypervolume(np.log10(np.maximum(sub[m], 1e-30)), ref)
                   if m.any() else 0.0)
    return out


def mobo(space: HWSpace, objectives: Objectives, *, n_init: int = 5,
         n_trials: int = 20, seed: int = 0, n_candidates: int = 256,
         n_draws: int = 24, ref: np.ndarray | None = None,
         batch_objectives: BatchObjectives | None = None) -> DSEResult:
    """Algorithm 1.  ``objectives`` returns minimized metrics, e.g.
    (latency_s, power_w, area_um2).  ``batch_objectives``, when given, scores
    whole populations per call (the initial design, and each picked trial)
    through the batched cost-model path."""
    rng = np.random.default_rng(seed)
    fbatch = as_batch(objectives, batch_objectives)

    configs: list[HWConfig] = space.sample(rng, n_init)
    ys = np.asarray(fbatch(configs), dtype=float)
    tried = {c.encode() for c in configs}

    fin = _finite_rows(ys)
    if ref is None:
        base = ys[fin] if fin.any() else np.ones((1, ys.shape[1]))
        ref = default_reference(np.log10(np.maximum(base, 1e-30)), margin=1.3)
    hv_history = []

    def hv_of(y: np.ndarray) -> float:
        m = _finite_rows(y)
        if not m.any():
            return 0.0
        return hypervolume(np.log10(np.maximum(y[m], 1e-30)), ref)

    for _ in range(len(configs)):
        hv_history.append(0.0)
    hv_history[-1] = hv_of(ys)

    while len(configs) < n_trials:
        fin = _finite_rows(ys)
        if fin.sum() >= 2:
            # impute illegal/failed points at a log-space penalty above the
            # observed worst so the surrogate learns to avoid them (dropping
            # them wastes the paper's scarce trials on infeasible regions)
            X = np.stack([space.encode01(c) for c in configs])
            Ylog = np.log10(np.maximum(ys, 1e-30))
            worst = np.nanmax(np.where(np.isfinite(Ylog), Ylog, np.nan),
                              axis=0)
            Y = np.where(np.isfinite(Ylog), Ylog, worst + 1.0)
            gps = fit_gps(X, Y)  # one shared kernel sweep for all objectives
        else:
            gps = None

        cands = space.sample(rng, n_candidates, exclude=tried)
        if not cands:
            break
        if gps is None:
            pick = cands[0]
        else:
            Xc = np.stack([space.encode01(c) for c in cands])
            hv_now = hv_of(ys)
            Ylog = np.log10(np.maximum(ys[fin], 1e-30))
            # stage 1: rank by HVI of the posterior mean (cheap prefilter)
            means = np.stack([g.predict(Xc)[0] for g in gps], axis=-1)
            mean_hvi = np.array([
                hypervolume(np.vstack([Ylog, m]), ref) - hv_now
                if np.all(m < ref) else 0.0 for m in means])
            top = np.argsort(-mean_hvi)[: max(8, n_candidates // 8)]
            # stage 2: MC hypervolume-PoI on the shortlist
            draws = np.stack([g.sample(Xc[top], n_draws, rng) for g in gps],
                             axis=-1)                # (draws, top, n_obj)
            prob = np.zeros(len(top))
            gain = np.zeros(len(top))
            for d in range(n_draws):
                for c in range(len(top)):
                    y_new = draws[d, c]
                    if np.any(y_new >= ref):
                        continue
                    hv_new = hypervolume(np.vstack([Ylog, y_new]), ref)
                    if hv_new > hv_now + 1e-12:
                        prob[c] += 1.0
                        gain[c] += hv_new - hv_now
            prob /= n_draws
            gain /= n_draws
            # expected hypervolume improvement as the primary signal,
            # probability-of-improvement as tie-break (Auger et al. family)
            score = gain + 1e-3 * prob * (abs(hv_now) + 1e-9)
            pick = cands[int(top[int(np.argmax(score))])]

        y = np.asarray(fbatch([pick]), dtype=float)[0]
        configs.append(pick)
        tried.add(pick.encode())
        ys = np.vstack([ys, y[None, :]])
        hv_history.append(hv_of(ys))

    return DSEResult(configs, ys, hv_history, len(configs), ref)
