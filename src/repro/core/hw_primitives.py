"""Hardware primitives (paper §V-A, Fig. 6) adapted to the TPU target.

The paper's primitives describe an FPGA/ASIC spatial accelerator; on TPU the
"accelerator instance" is a Pallas kernel resource envelope (DESIGN.md §2):

  reshapeArray([m, n])    -> MXU block shape (pe_rows, pe_cols); pe_depth is
                             the contraction block (the paper's intrinsic size
                             along the reduction).
  linkPEs(pattern)        -> fixed 'systolic' on TPU (the MXU); kept for API
                             fidelity, rejects anything else.
  addCache(kib)           -> VMEM budget the kernel's BlockSpecs may claim.
  partitionBanks(n)       -> pipeline depth: 1 = no overlap, 2 = double
                             buffering, 3 = triple.
  distributeCache(kib)    -> accumulator tile kept PE-local (VREG/VMEM
                             accumulator); enables output-stationary reuse.
  burstTransfer(bytes)    -> HBM->VMEM DMA granularity (innermost contiguous
                             block extent in bytes).

A primitive sequence builds an immutable :class:`HWConfig` — one point of the
hardware design space.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

DATAFLOWS = ("OS", "WS", "IS")  # output- / weight- / input-stationary


@dataclass(frozen=True)
class HWConfig:
    """One accelerator instance (= one Pallas kernel configuration)."""

    intrinsic: str = "GEMM"       # DOT | GEMV | GEMM | CONV2D
    pe_rows: int = 128            # MXU block M
    pe_cols: int = 128            # MXU block N
    pe_depth: int = 128           # contraction block K
    link_pattern: str = "systolic"
    vmem_kib: int = 8192          # scratchpad budget (<= 16 MiB/core on v5e)
    banks: int = 2                # pipeline depth (double buffering)
    local_accum_kib: int = 0      # PE-local accumulator (0 = none)
    burst_bytes: int = 4096       # DMA burst granularity
    dataflow: str = "OS"
    tp: int = 1                   # tensor-parallel degree (replicated chips)

    def __post_init__(self) -> None:
        if self.link_pattern != "systolic":
            raise ValueError("TPU MXU interconnect is fixed systolic "
                             "(DESIGN.md §2: linkPEs degenerates on TPU)")
        if self.dataflow not in DATAFLOWS:
            raise ValueError(f"dataflow must be one of {DATAFLOWS}")
        if not isinstance(self.tp, int) or self.tp < 1:
            raise ValueError(f"tp must be a positive int, got {self.tp!r}")

    # -- derived quantities --------------------------------------------------
    @property
    def n_pes(self) -> int:
        """PE count analogue: MXU lanes engaged by the block shape."""
        if self.intrinsic == "DOT":
            return self.pe_depth
        if self.intrinsic == "GEMV":
            return self.pe_rows * min(self.pe_depth, 128) // 128 * 8
        return self.pe_rows * self.pe_cols // 128

    @property
    def vmem_bytes(self) -> int:
        return self.vmem_kib * 1024

    def intrinsic_dims(self) -> dict[str, int]:
        """Logical intrinsic shape per intrinsic index (paper's fixed size)."""
        from .intrinsics import BINDINGS
        return BINDINGS[self.intrinsic].intrinsic_shape(self)

    def encode(self) -> tuple:
        return (self.intrinsic, self.pe_rows, self.pe_cols, self.pe_depth,
                self.vmem_kib, self.banks, self.local_accum_kib,
                self.burst_bytes, self.dataflow, self.tp)


class HWBuilder:
    """Fluent primitive API mirroring the paper's Listing 2.

    >>> hw = (HWBuilder("GEMM").reshapeArray([256, 256]).linkPEs("systolic")
    ...       .addCache(8192).partitionBanks(2).burstTransfer(4096).build())
    """

    def __init__(self, intrinsic: str = "GEMM"):
        self._cfg = HWConfig(intrinsic=intrinsic.upper())

    def reshapeArray(self, shape, depth: int | None = None) -> "HWBuilder":
        rows, cols = (shape if len(shape) == 2 else (shape[0], shape[0]))
        self._cfg = replace(self._cfg, pe_rows=int(rows), pe_cols=int(cols),
                            pe_depth=int(depth or self._cfg.pe_depth))
        return self

    def linkPEs(self, pattern: str) -> "HWBuilder":
        self._cfg = replace(self._cfg, link_pattern=pattern)
        return self

    def addCache(self, kib: int) -> "HWBuilder":
        self._cfg = replace(self._cfg, vmem_kib=int(kib))
        return self

    def partitionBanks(self, n: int) -> "HWBuilder":
        self._cfg = replace(self._cfg, banks=int(n))
        return self

    def distributeCache(self, kib: int) -> "HWBuilder":
        self._cfg = replace(self._cfg, local_accum_kib=int(kib))
        return self

    def burstTransfer(self, nbytes: int) -> "HWBuilder":
        self._cfg = replace(self._cfg, burst_bytes=int(nbytes))
        return self

    def dataflow(self, df: str) -> "HWBuilder":
        self._cfg = replace(self._cfg, dataflow=df.upper())
        return self

    def parallelize(self, tp: int) -> "HWBuilder":
        """Replicate the chip ``tp``-way (tensor parallelism): the weights
        and compute shard across ``tp`` identical instances joined by the
        target's inter-chip link (cost_model charges the per-call
        all-reduce)."""
        self._cfg = replace(self._cfg, tp=int(tp))
        return self

    def build(self) -> HWConfig:
        return self._cfg
