"""Benchmark tensor computations (paper Table I) and workload sets.

A *workload* is a TensorExpr with concrete extents.  A *workload set* is what
an application provides: many workloads sharing one co-designed accelerator
(paper §III).  The CNN sets mirror the paper's ResNet-50 / MobileNet /
Xception convolution collections (representative layer shapes from the
published architectures).
"""
from __future__ import annotations

from .tst import TensorExpr, parse


def gemm(i: int, j: int, k: int, name: str = "") -> TensorExpr:
    return parse("L[i,j] = M[i,k] * N[k,j]", {"i": i, "j": j, "k": k},
                 name=name or f"GEMM_{i}x{j}x{k}")


def gemv(i: int, j: int, name: str = "") -> TensorExpr:
    return parse("C[i] = A[i,j] * B[j]", {"i": i, "j": j},
                 name=name or f"GEMV_{i}x{j}")


def dot(i: int, name: str = "") -> TensorExpr:
    """Scalar dot product; the 1-extent output index keeps the TensorExpr
    machinery uniform (mirrors the DOT intrinsic's TST)."""
    return parse("C[o] = A[i] * B[i]", {"i": i, "o": 1},
                 name=name or f"DOT_{i}")


def conv2d(k: int, c: int, x: int, y: int, r: int = 3, s: int = 3,
           name: str = "") -> TensorExpr:
    return parse("C[k,x,y] = A[c,x+r,y+s] * B[k,c,r,s]",
                 {"k": k, "c": c, "x": x, "y": y, "r": r, "s": s},
                 name=name or f"CONV_{k}x{c}x{x}x{y}_{r}x{s}")


def ttm(i: int, j: int, k: int, l: int, name: str = "") -> TensorExpr:
    return parse("C[i,j,k] = A[i,j,l] * B[l,k]",
                 {"i": i, "j": j, "k": k, "l": l},
                 name=name or f"TTM_{i}x{j}x{k}x{l}")


def mttkrp(i: int, j: int, k: int, l: int, name: str = "") -> TensorExpr:
    return parse("D[i,j] = A[i,k,l] * B[l,j] * C[k,j]",
                 {"i": i, "j": j, "k": k, "l": l},
                 name=name or f"MTTKRP_{i}x{j}x{k}x{l}")


def mttkrp_stages(i: int, j: int, k: int, l: int, name: str = "") -> list[TensorExpr]:
    """Paper §VII-B: MTTKRP as two stages ``E[i,k,j] = Σ_l A[i,k,l]·B[l,j]``
    and ``D[i,j] = Σ_k E[i,k,j]·C[k,j]``.  Only stage 1 admits GEMM
    sub-workloads; GEMV benefits both stages."""
    base = name or f"MTTKRP_{i}x{j}x{k}x{l}"
    s1 = parse("E[i,k,j] = A[i,k,l] * B[l,j]",
               {"i": i, "j": j, "k": k, "l": l}, name=f"{base}_s1")
    s2 = parse("D[i,j] = E[i,k,j] * C[k,j]",
               {"i": i, "j": j, "k": k}, name=f"{base}_s2")
    return [s1, s2]


# ---------------------------------------------------------------------------
# Table I: ten workloads per computation, spanning the paper's compute range.
# ---------------------------------------------------------------------------

def table1_gemm() -> list[TensorExpr]:
    sizes = [(32, 16, 16), (64, 64, 64), (128, 128, 64), (256, 128, 128),
             (256, 256, 256), (512, 256, 256), (512, 512, 512),
             (1024, 512, 512), (1024, 1024, 512), (1024, 1024, 1024)]
    return [gemm(*s, name=f"gemm_w{n}") for n, s in enumerate(sizes)]


def table1_ttm() -> list[TensorExpr]:
    sizes = [(32, 32, 16, 16), (64, 32, 32, 32), (64, 64, 64, 32),
             (128, 64, 64, 64), (128, 128, 64, 64), (128, 128, 128, 64),
             (256, 128, 128, 64), (256, 256, 128, 64), (256, 256, 256, 64),
             (512, 256, 256, 64)]
    return [ttm(*s, name=f"ttm_w{n}") for n, s in enumerate(sizes)]


def table1_mttkrp() -> list[TensorExpr]:
    sizes = [(64, 32, 32, 32), (64, 64, 64, 32), (128, 64, 64, 64),
             (128, 128, 64, 64), (128, 128, 128, 64), (256, 128, 128, 64),
             (256, 256, 128, 64), (256, 256, 256, 64), (512, 256, 256, 64),
             (512, 512, 256, 64)]
    return [mttkrp(*s, name=f"mttkrp_w{n}") for n, s in enumerate(sizes)]


def table1_conv() -> list[TensorExpr]:
    sizes = [(64, 64, 56, 56, 3, 3), (64, 64, 56, 56, 1, 1),
             (128, 128, 28, 28, 3, 3), (256, 128, 28, 28, 3, 3),
             (256, 256, 14, 14, 3, 3), (512, 256, 14, 14, 3, 3),
             (512, 512, 7, 7, 3, 3), (32, 16, 112, 112, 3, 3),
             (96, 32, 56, 56, 5, 5), (192, 96, 28, 28, 7, 7)]
    return [conv2d(*s, name=f"conv_w{n}") for n, s in enumerate(sizes)]


# ---------------------------------------------------------------------------
# CNN workload sets (paper §VII-D/E): convolution layers of ResNet-50,
# MobileNet-v1 and Xception, by (k=out_ch, c=in_ch, x=y=spatial, r=s=filter).
# Strided layers are folded to their output spatial size.
# ---------------------------------------------------------------------------

_RESNET50 = [
    (64, 3, 112, 7), (64, 64, 56, 1), (64, 64, 56, 3), (256, 64, 56, 1),
    (128, 256, 28, 1), (128, 128, 28, 3), (512, 128, 28, 1),
    (256, 512, 14, 1), (256, 256, 14, 3), (1024, 256, 14, 1),
    (512, 1024, 7, 1), (512, 512, 7, 3), (2048, 512, 7, 1),
]

_MOBILENET = [
    (32, 3, 112, 3), (64, 32, 112, 1), (128, 64, 56, 1), (128, 128, 56, 1),
    (256, 128, 28, 1), (256, 256, 28, 1), (512, 256, 14, 1),
    (512, 512, 14, 1), (1024, 512, 7, 1), (1024, 1024, 7, 1),
]

_XCEPTION = [
    (32, 3, 149, 3), (64, 32, 147, 3), (128, 64, 74, 1), (128, 128, 74, 3),
    (256, 128, 37, 1), (256, 256, 37, 3), (728, 256, 19, 1),
    (728, 728, 19, 3), (1024, 728, 10, 3), (1536, 1024, 10, 3),
    (2048, 1536, 10, 3),
]


def cnn_set(name: str) -> list[TensorExpr]:
    table = {"resnet": _RESNET50, "mobilenet": _MOBILENET,
             "xception": _XCEPTION}[name.lower()]
    return [conv2d(k, c, x, x, r, r, name=f"{name}_l{n}")
            for n, (k, c, x, r) in enumerate(table)]


def xception_ground_truth() -> list[TensorExpr]:
    """The six Xception convolutions (86.7—454.2 MOPs) used as the hardware
    DSE ground-truth workloads (paper §VII-C)."""
    return [conv2d(k, c, x, x, r, r, name=f"xc_gt{n}") for n, (k, c, x, r)
            in enumerate([(128, 64, 74, 1), (128, 128, 74, 3), (256, 128, 37, 1),
                          (256, 256, 37, 3), (728, 256, 19, 1), (728, 728, 19, 3)])]
