"""The HASCO co-design flow (paper §III, Fig. 3).

  Step 1  HW/SW partitioning — tensorize choices from TST matching.
  Step 2  Solution generation — hardware DSE (MOBO over accelerator
          parameters, objective = best-software latency / power / area) and
          software DSE (heuristic + Q-learning) per workload.
  Step 3  Solution tuning — pick Pareto points meeting the user constraints;
          if none satisfy them, extend the hardware DSE with more trials.

Baselines implemented alongside (paper §VII-D/E):
  * ``separate_design``  — hardware picked with a default/naive software
    mapping (the traditional decoupled methodology of Table III).
  * ``library_schedule`` — im2col-style fixed library mapping (Fig. 11).
  * ``template_search``  — AutoTVM-style: fixed tensorize choice + source
    loop order, only tile sizes explored (Fig. 11).
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field

import numpy as np

from repro import obs

from . import sw_dse
from .hw_primitives import HWConfig
from .hw_space import HWSpace
from .matching import TensorizeChoice, partition_space
from .intrinsics import ALL_INTRINSICS
from .mobo import DSEResult, mobo
from .qlearning import DQN
from .sw_primitives import Schedule
from .sw_space import SoftwareSpace
from .tst import TensorExpr


@dataclass
class Constraints:
    """User constraints from the input description (paper Fig. 3)."""

    latency_s: float = math.inf
    power_w: float = math.inf
    area_um2: float = math.inf

    def as_bounds(self) -> dict[int, float]:
        out: dict[int, float] = {}
        if math.isfinite(self.latency_s):
            out[0] = self.latency_s
        if math.isfinite(self.power_w):
            out[1] = self.power_w
        if math.isfinite(self.area_um2):
            out[2] = self.area_um2
        return out


@dataclass
class Solution:
    """A holistic solution: one accelerator shared by the application, one
    schedule (+ interface) per workload (paper §III)."""

    hw: HWConfig
    schedules: dict[str, Schedule]
    latency_s: float
    power_w: float
    area_um2: float
    intrinsic: str

    def describe(self) -> str:
        return (f"{self.intrinsic}: pe={self.hw.pe_rows}x{self.hw.pe_cols}"
                f"x{self.hw.pe_depth} vmem={self.hw.vmem_kib}KiB "
                f"banks={self.hw.banks} df={self.hw.dataflow} | "
                f"lat={self.latency_s:.4e}s pow={self.power_w:.2f}W "
                f"area={self.area_um2:.3e}um2")


@dataclass
class CodesignReport:
    solution: Solution | None
    per_intrinsic: dict[str, DSEResult]
    partition_sizes: dict[tuple[str, str], int]
    evaluations: int
    cache_stats: dict | None = None
    # measured-autotuning extras (measure=True): per-intrinsic measurement
    # summaries, the fitted per-op Calibration, and where the tuning DB went
    measured: dict | None = None
    calibration: object | None = None
    db_path: object | None = None


def hw_objectives(workloads: list[TensorExpr], partition, intrinsic: str,
                  *, target: str = "spatial", seed: int = 0,
                  sw_budget: str = "small", cache=None,
                  engine: str = "batched"):
    """The paper's correlated objective: evaluating a hardware point runs the
    software DSE and reports the *achieved* latency plus power/area.

    Scalar protocol — one config per call; :func:`hw_objectives_batch` is the
    production form the MOBO loop uses.  ``cache`` (an
    :class:`~repro.core.cost_model.EvalCache`) is threaded into the inner
    software DSE and the final per-schedule rescore, so hardware points
    probed by several explorers — or re-refined at a bigger software budget
    in Step 3 — never re-derive a (hw, schedule) evaluation.
    """
    fbatch = hw_objectives_batch(workloads, partition, intrinsic,
                                 target=target, seed=seed,
                                 sw_budget=sw_budget, cache=cache,
                                 engine=engine)

    def f(hw: HWConfig) -> tuple[float, float, float]:
        return tuple(fbatch([hw])[0])

    return f


def hw_objectives_batch(workloads: list[TensorExpr], partition,
                        intrinsic: str, *, target: str = "spatial",
                        seed: int = 0, sw_budget: str = "small", cache=None,
                        engine: str = "batched"):
    """Batched hardware objectives (DESIGN.md §10): score a whole population
    of hardware candidates — a ``mobo(q=N)`` trial's picks, or the initial
    design — by resolving all ``len(configs) × len(workloads)`` software
    searches in ONE lock-step engine pass, then rescoring every winning
    schedule's energy through one batched cost-model call per workload."""
    from .cost_model import TARGETS, accelerator_area, evaluate_batch_reports

    tgt = TARGETS[target]

    def fbatch(configs) -> np.ndarray:
        configs = list(configs)
        specs: list[sw_dse.SearchSpec] = []
        owners: list[tuple[int, str]] = []
        for ci, hw in enumerate(configs):
            for n, w in enumerate(workloads):
                choices = partition.get((w.name, hw.intrinsic), [])
                if choices:
                    specs.append(sw_dse.SearchSpec(w, choices, hw,
                                                   seed + 17 * n))
                    owners.append((ci, w.name))
        results = sw_dse.run_searches(specs, target=target, cache=cache,
                                      engine=engine,
                                      **sw_dse.BUDGETS[sw_budget])
        per_config: list[dict[str, sw_dse.SWResult]] = \
            [{} for _ in configs]
        for (ci, wname), r in zip(owners, results):
            per_config[ci][wname] = r

        # energy rescore of every config's winning schedules: one batched
        # cost-model pass per workload over all configs (cache-hot anyway —
        # each schedule was just evaluated by its own search)
        rescore: dict[str, tuple] = {}
        for ci, res in enumerate(per_config):
            if set(res) != {w.name for w in workloads}:
                continue
            for w in workloads:
                g = rescore.setdefault(w.name, (w, [], [], []))
                g[1].append(configs[ci])
                g[2].append(res[w.name].schedule)
                g[3].append(ci)
        reps_of: dict[tuple[int, str], object] = {}
        for w, hws, scheds, cis in rescore.values():
            reps = evaluate_batch_reports(w, hws, scheds, target, cache=cache)
            for ci, rep in zip(cis, reps):
                reps_of[(ci, w.name)] = rep

        ys = np.full((len(configs), 3), math.inf)
        for ci, (hw, res) in enumerate(zip(configs, per_config)):
            if set(res) != {w.name for w in workloads}:
                continue
            lat = sw_dse.total_latency(res)
            e_tot = 0.0
            for w in workloads:
                rep = reps_of[(ci, w.name)]
                if not rep.legal:
                    break
                e_tot += rep.energy_j
            else:
                ys[ci] = (lat, e_tot / max(lat, 1e-12),
                          accelerator_area(hw, tgt))
        return ys

    return fbatch


def codesign(workloads: list[TensorExpr], *, intrinsics: list[str] = None,
             constraints: Constraints = None, target: str = "spatial",
             n_trials: int = 20, n_init: int = 5, seed: int = 0, q: int = 1,
             max_dse_extensions: int = 0, engine: str = "batched",
             sw_budget: str = "small", space_axes: dict | None = None,
             cache=None, measure: bool = False,
             measure_backend: str = "interpret", measure_top_k: int = 3,
             measure_opts=None, db_path=None, app: str = "default",
             checkpoint_dir=None, resume_from=None) -> CodesignReport:
    """Full HASCO flow over one application (= workload set).

    One :class:`~repro.core.cost_model.EvalCache` is shared across the whole
    run — every intrinsic's hardware DSE, its inner software DSE, and the
    Step-3 full-budget refinement — so identical (hw, schedule) points probed
    in different steps are evaluated exactly once.

    ``q`` is the MOBO suggestion batch size (DESIGN.md §9): each hardware-DSE
    trial proposes ``q`` configs and scores them with one batched objectives
    call, which resolves the trial's q × len(workloads) software searches in
    a single lock-step engine pass (DESIGN.md §10; ``engine="reference"``
    keeps the sequential per-search path with identical same-seed results).
    ``max_dse_extensions`` enables the paper's constraint-
    driven Step-3 extension: when no explored point satisfies the user
    constraints, the hardware DSE is re-run with a doubled trial budget (up
    to that many doublings) — the shared cache makes every previously-probed
    point free, so an extension only pays for the *new* trials.

    With ``measure=True``, Step 3 closes the loop on measured truth
    (DESIGN.md §8): the top-``measure_top_k`` constraint-feasible Pareto
    candidates of each intrinsic are refined at full software budget, their
    per-workload schedules are lowered to real Pallas kernels
    (``tuner/measure.py``, backend ``measure_backend``) and timed, and the
    committed Solution is the candidate with the lowest *measured* total
    latency (workloads without a kernel lowering fall back to their
    analytical latency).  All (analytical, measured) pairs feed a per-op
    calibration fit; records + calibration are persisted to ``db_path``
    (a tuning database, ``tuner/db.py``) when given.  Candidates the DB has
    *quarantined* (persistently failing kernels) are skipped unrun, and
    newly retry-exhausted failures join the quarantine on persist.

    Robustness (DESIGN.md §14): with ``checkpoint_dir`` set, the driver
    checkpoints its round state after every completed intrinsic — MOBO
    observations (the DSEResult), running best solution, calibration
    samples, and the EvalCache contents — through
    :class:`~repro.ft.CheckpointManager` payloads.  ``resume_from`` restores
    the newest clean checkpoint and skips the already-completed intrinsics;
    because each intrinsic's DSE is self-seeded and the cache only affects
    speed, a killed-and-resumed run commits a solution bit-identical to an
    uninterrupted one.  A checkpoint written by a *different* invocation
    (mismatched workloads/parameters) is ignored with a warning.
    """
    with obs.span("codesign.run",
                  {"workloads": [w.name for w in workloads],
                   "n_trials": n_trials, "q": q, "measure": measure}
                  if obs.enabled() else None):
        return _codesign_body(
            workloads, intrinsics=intrinsics, constraints=constraints,
            target=target, n_trials=n_trials, n_init=n_init, seed=seed, q=q,
            max_dse_extensions=max_dse_extensions, engine=engine,
            sw_budget=sw_budget, space_axes=space_axes, cache=cache,
            measure=measure, measure_backend=measure_backend,
            measure_top_k=measure_top_k, measure_opts=measure_opts,
            db_path=db_path, app=app, checkpoint_dir=checkpoint_dir,
            resume_from=resume_from)


def _codesign_signature(workloads, intrinsics, constraints, target, n_trials,
                        n_init, seed, q, max_dse_extensions, engine,
                        sw_budget, space_axes, measure, measure_backend,
                        measure_top_k) -> tuple:
    """What makes two codesign invocations "the same run" for resume: the
    workload identities and every parameter that steers the search.  A
    checkpoint whose signature differs must not be resumed (it would splice
    state from a different trajectory into this one)."""
    from .cost_model import _fingerprint

    return (tuple(_fingerprint(w) for w in workloads),
            tuple(i.upper() for i in intrinsics),
            (constraints.latency_s, constraints.power_w,
             constraints.area_um2),
            target, n_trials, n_init, seed, q, max_dse_extensions, engine,
            sw_budget, repr(sorted((space_axes or {}).items())),
            measure, measure_backend, measure_top_k)


def _codesign_body(workloads: list[TensorExpr], *, intrinsics, constraints,
                   target, n_trials, n_init, seed, q, max_dse_extensions,
                   engine, sw_budget, space_axes, cache, measure,
                   measure_backend, measure_top_k, measure_opts, db_path,
                   app, checkpoint_dir=None,
                   resume_from=None) -> CodesignReport:
    from .cost_model import EvalCache

    intrinsics = intrinsics or ["GEMM", "GEMV", "DOT", "CONV2D"]
    constraints = constraints or Constraints()
    cache = cache if cache is not None else EvalCache()

    quarantine: set[str] = set()
    if measure:
        from repro.tuner.measure import MeasureOptions
        measure_opts = measure_opts or MeasureOptions(backend=measure_backend)
        if db_path is not None:
            from repro.tuner.db import TuningDB
            quarantine = TuningDB.load(db_path).quarantined_keys()

    # Step 1: partition space
    intr_tsts = [ALL_INTRINSICS[i.upper()] for i in intrinsics]
    partition = partition_space(intr_tsts, workloads)
    sizes = {k: len(v) for k, v in partition.items()}

    per_intrinsic: dict[str, DSEResult] = {}
    evals = 0
    best: Solution | None = None
    best_rank: tuple[int, float] | None = None
    measured_summary: dict[str, dict] = {}
    calib_samples: list = []
    measure_points: list = []   # (workload, rep, MeasureResult) for the DB
    measure_failures: list = []  # failure dicts for the DB's diagnostics

    # periodic checkpoint + resume (DESIGN.md §14): one payload checkpoint
    # per completed intrinsic; resume restores the newest clean one
    sig = _codesign_signature(workloads, intrinsics, constraints, target,
                              n_trials, n_init, seed, q, max_dse_extensions,
                              engine, sw_budget, space_axes, measure,
                              measure_backend, measure_top_k)
    completed: set[str] = set()
    ckpt = None
    if checkpoint_dir is not None:
        from repro.ft import CheckpointManager
        ckpt = CheckpointManager(checkpoint_dir, keep=8)
    if resume_from is not None:
        from repro.ft import CheckpointManager
        state = CheckpointManager(resume_from, keep=8).restore_payload()
        if state is None:
            pass   # nothing restorable: start fresh
        elif state.get("signature") != sig:
            warnings.warn("codesign resume: checkpoint signature does not "
                          "match this invocation; starting fresh",
                          stacklevel=3)
        else:
            completed = set(state["done"])
            per_intrinsic.update(state["per_intrinsic"])
            evals = state["evals"]
            best, best_rank = state["best"], state["best_rank"]
            measured_summary.update(state["measured_summary"])
            calib_samples.extend(state["calib_samples"])
            measure_points.extend(state["measure_points"])
            measure_failures.extend(state["measure_failures"])
            cache._data.update(state["cache_data"])

    for intrinsic in intrinsics:
        intrinsic = intrinsic.upper()
        if intrinsic in completed:   # resumed past this one
            continue
        # the intrinsic must cover every workload of the application
        if not all((w.name, intrinsic) in partition for w in workloads):
            continue
        with obs.span("codesign.intrinsic",
                      {"intrinsic": intrinsic} if obs.enabled() else None):
            space = HWSpace(intrinsic)
            if space_axes:
                space = HWSpace(intrinsic, axes={**space.axes, **space_axes})
            fb = hw_objectives_batch(workloads, partition, intrinsic,
                                     target=target, seed=seed,
                                     sw_budget=sw_budget, cache=cache,
                                     engine=engine)
            # scalar fallback view of the same batch objective (mobo only calls
            # it when batch_objectives is absent, i.e. never here)
            f = lambda hw: tuple(fb([hw])[0])
            with obs.span("codesign.hw_dse"):
                res = mobo(space, f, batch_objectives=fb, n_init=n_init,
                           n_trials=n_trials, seed=seed, q=q)
            bounds = constraints.as_bounds()
            for ext in range(1, max_dse_extensions + 1):
                if not bounds or res.best_under(bounds) is not None:
                    break
                # constraint-driven extension (paper Fig. 3 Step 3): nothing on
                # the frontier meets the constraints, so widen the search
                with obs.span("codesign.hw_dse_extension"):
                    res = mobo(space, f, batch_objectives=fb, n_init=n_init,
                               seed=seed, q=q, n_trials=n_trials * (2 ** ext))
            per_intrinsic[intrinsic] = res
            evals += res.evaluations

            if not measure:
                pick = res.best_under(constraints.as_bounds())
                if pick is not None:
                    hw, y = pick
                    # Step 3: refine the chosen point at full software
                    # budget — the shared cache makes every Step-2 probe
                    # of this point free
                    with obs.span("codesign.refine"):
                        results = sw_dse.optimize_set(
                            workloads, partition, hw, target=target,
                            seed=seed, budget="full", cache=cache,
                            engine=engine)
                    lat = sw_dse.total_latency(results)
                    sol = Solution(hw,
                                   {k: r.schedule for k, r in results.items()},
                                   min(lat, y[0]), y[1], y[2], intrinsic)
                    if best is None or sol.latency_s < best.latency_s:
                        best = sol
            else:
                # Step 3 (measured): re-rank the feasible frontier by real
                # kernels
                with obs.span("codesign.measure_rerank"):
                    sol, rank, summary = _measure_rerank(
                        workloads, partition, res, constraints, intrinsic,
                        target, seed, cache, measure_opts, measure_top_k,
                        calib_samples, measure_points, measure_failures,
                        engine=engine, quarantine=quarantine)
                if summary:
                    measured_summary[intrinsic] = summary
                if sol is not None and (best is None or rank < best_rank):
                    best, best_rank = sol, rank

        completed.add(intrinsic)
        if ckpt is not None:
            # everything a resumed run needs to continue to a bit-identical
            # committed solution, pickled atomically per intrinsic round
            ckpt.save_payload(len(completed), {
                "signature": sig, "done": sorted(completed),
                "per_intrinsic": per_intrinsic, "evals": evals,
                "best": best, "best_rank": best_rank,
                "measured_summary": measured_summary,
                "calib_samples": calib_samples,
                "measure_points": measure_points,
                "measure_failures": measure_failures,
                "cache_data": dict(cache._data)})

    calibration = None
    saved_db = None
    if measure:
        from repro import tuner as _tuner
        calibration = _tuner.calibrate.fit(calib_samples)
        if db_path is not None:
            saved_db = _persist_tuning(db_path, app, best, calibration,
                                       measure_points, measure_failures)

    st = obs.state()
    if st is not None:
        cs = cache.stats()
        st.metrics.gauge("evalcache.entries").set(cs["entries"])
        st.metrics.gauge("evalcache.hits").set(cs["hits"])
        st.metrics.gauge("evalcache.misses").set(cs["misses"])

    return CodesignReport(best, per_intrinsic, sizes, evals, cache.stats(),
                          measured_summary or None, calibration, saved_db)


def _measure_rerank(workloads, partition, res: DSEResult,
                    constraints: Constraints, intrinsic: str, target: str,
                    seed: int, cache, measure_opts, top_k: int,
                    calib_samples: list, measure_points: list,
                    measure_failures: list, engine: str = "batched",
                    quarantine: set[str] | None = None
                    ) -> tuple[Solution | None, tuple[int, float] | None,
                               dict]:
    """Measured Step 3 for one intrinsic: refine the top feasible candidates
    at full software budget, time their kernels, commit to measured truth."""
    from repro.tuner import calibrate as C
    from repro.tuner import measure as M

    from .cost_model import evaluate

    bounds = constraints.as_bounds()
    ok = np.ones(len(res.ys), dtype=bool)
    for i, bound in bounds.items():
        ok &= res.ys[:, i] <= bound
    order = np.argsort(np.where(ok, res.ys[:, 0], math.inf))
    cand_idx = [int(i) for i in order[:top_k] if ok[i]]
    if not cand_idx:
        return None, None, {}

    best_sol: Solution | None = None
    best_rank: tuple[int, float] | None = None
    n_measured = n_fallback = n_quarantined = n_illegal = 0
    for i in cand_idx:
        hw, y = res.configs[i], res.ys[i]
        results = sw_dse.optimize_set(workloads, partition, hw, target=target,
                                      seed=seed, budget="full", cache=cache,
                                      engine=engine)
        if set(r for r in results) != {w.name for w in workloads}:
            continue
        total = 0.0
        cand_fallbacks = 0
        for w in workloads:
            sched = results[w.name].schedule
            rep = evaluate(w, sched, hw, target, cache=cache)
            mres = M.measure_one(w, hw, sched, measure_opts, quarantine)
            if mres.ok and rep.legal:
                total += mres.latency_s
                n_measured += 1
                calib_samples.extend(C.collect_samples(w, [rep], [mres]))
                measure_points.append((w, rep, mres))
            else:  # no lowering / failed run: analytical latency stands in
                total += rep.latency_s
                cand_fallbacks += 1
                if mres.error_type == "Quarantined":
                    n_quarantined += 1   # skipped unrun, not a new failure
                elif mres.error_type == "Illegal":
                    # statically rejected by the legality verifier ahead of
                    # lowering (DESIGN.md §16.2): counted, but never recorded
                    # as a failure — no kernel ever ran, so there is nothing
                    # to retry or quarantine
                    n_illegal += 1
                elif mres.error:
                    measure_failures.append({
                        "workload": w.name, "intrinsic": intrinsic,
                        "backend": measure_opts.backend,
                        "error_type": mres.error_type, "error": mres.error,
                        "elapsed_s": mres.elapsed_s,
                        # only retry-exhausted kernel runs carry a point;
                        # its key is what _persist_tuning quarantines
                        "key": (M.quarantine_key(mres.point)
                                if mres.point is not None else "")})
        n_fallback += cand_fallbacks
        # rank lexicographically by (fallback count, total): analytical
        # stand-ins live on a different scale than wall-clock measurements,
        # so a candidate that could not be measured must never displace one
        # that was — fallback totals only compare against each other
        rank = (cand_fallbacks, total)
        sol = Solution(hw, {k: r.schedule for k, r in results.items()},
                       total, y[1], y[2], intrinsic)
        if best_rank is None or rank < best_rank:
            best_sol, best_rank = sol, rank
    summary = {"candidates": len(cand_idx), "measured": n_measured,
               "fallbacks": n_fallback, "quarantined": n_quarantined,
               "illegal": n_illegal,
               "best_measured_total_s":
                   best_sol.latency_s if best_sol else math.inf,
               # True when the committed candidate's total mixes analytical
               # stand-ins with wall-clock measurements: downstream consumers
               # must not read best_measured_total_s as measured truth then
               "best_has_fallbacks":
                   bool(best_rank[0] > 0) if best_rank else False}
    return best_sol, best_rank, summary


def _persist_tuning(db_path, app: str, best: Solution | None, calibration,
                    measure_points: list, measure_failures: list = ()):
    """Write measured records + calibration + failure diagnostics (+ the
    winning app solution) into the tuning database at ``db_path``
    (merge-on-save, atomic)."""
    from dataclasses import asdict

    from repro.tuner.db import TuningDB, TuningRecord

    db = TuningDB.load(db_path)
    for w, rep, mres in measure_points:
        pt = mres.point
        if pt is None:
            continue
        db.record(TuningRecord(pt.op, pt.shape, pt.dtype, pt.backend,
                               pt.block_map, mres.latency_s, rep.latency_s,
                               app))
    db.add_failures({**f, "app": app} for f in measure_failures)
    # retry-exhausted kernel candidates (they carry a quarantine key) join
    # the persistent quarantine: future runs skip them unrun
    for f in measure_failures:
        key = f.get("key", "")
        if key:
            db.quarantine_candidate(key, {
                "app": app, "workload": f.get("workload", ""),
                "error_type": f.get("error_type", ""),
                "error": str(f.get("error", ""))[:200]})
    db.set_calibration(calibration)
    if best is not None:
        db.set_app(app, {
            "hw": asdict(best.hw), "intrinsic": best.intrinsic,
            "latency_s": best.latency_s, "power_w": best.power_w,
            "area_um2": best.area_um2,
        })
    return db.save(db_path)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def separate_design(workloads: list[TensorExpr], hw: HWConfig, *,
                    target: str = "spatial", seed: int = 0,
                    tuned_software: bool = True, cache=None) -> Solution:
    """The traditional decoupled flow (Table III baseline): the accelerator
    ``hw`` was fixed without feedback from software DSE; software is then
    tuned (AutoTVM-style if ``tuned_software``) for that fixed hardware."""
    from .cost_model import TARGETS, accelerator_area, evaluate

    intr = ALL_INTRINSICS[hw.intrinsic]
    partition = partition_space([intr], workloads)
    schedules: dict[str, Schedule] = {}
    lat = 0.0
    e_tot = 0.0
    for w in workloads:
        choices = partition.get((w.name, hw.intrinsic))
        if not choices:
            return Solution(hw, {}, math.inf, math.inf,
                            accelerator_area(hw, TARGETS[target]), hw.intrinsic)
        if tuned_software:
            r = template_search(w, choices[0], hw, target=target, seed=seed,
                                cache=cache)
            schedules[w.name] = r
        else:
            schedules[w.name] = SoftwareSpace(w, choices, hw, target).default_schedule()
        rep = evaluate(w, schedules[w.name], hw, target, cache=cache)
        lat += rep.latency_s
        e_tot += rep.energy_j if rep.legal else math.inf
    area = accelerator_area(hw, TARGETS[target])
    return Solution(hw, schedules, lat, e_tot / max(lat, 1e-12), area,
                    hw.intrinsic)


def template_search(workload: TensorExpr, choice: TensorizeChoice,
                    hw: HWConfig, *, target: str = "spatial", seed: int = 0,
                    budget: int = 64, cache=None) -> Schedule:
    """AutoTVM-style fixed-template tuning (paper §VII-D): the tensorize
    choice and loop order are fixed by the template author; only the sizes of
    tensorized sub-workloads (tile factors) are explored.  The whole tile
    population is scored with one batched cost-model call."""
    from .cost_model import evaluate_batch

    rng = np.random.default_rng(seed)
    ext = workload.extents
    mapped = list(choice.mapped_compute_indices)
    order = tuple(workload.all_indices())  # source order: template-fixed

    def random_tiles() -> tuple[tuple[str, int], ...]:
        ts = []
        for c in mapped:
            hi = int(math.log2(max(1, ext[c])))
            ts.append((c, min(ext[c], 1 << int(rng.integers(0, hi + 1)))))
        return tuple(sorted(ts))

    population = [Schedule(choice, random_tiles(), order, 0)
                  for _ in range(budget)]
    lats = evaluate_batch(workload, hw, population, target, cache=cache)[:, 0]
    return population[int(np.argmin(lats))]


def human_template_choice(workload: TensorExpr,
                          choices: list[TensorizeChoice]) -> TensorizeChoice:
    """The choice a template author would write by hand: maximize the compute
    the intrinsic covers (product of mapped loop extents), untransposed."""
    def score(c):
        prod = 1
        for l in c.mapped_compute_indices:
            prod *= workload.extents[l]
        return (prod, not c.transposed)
    return max(choices, key=score)


HOST_DMA_GBPS = 2.0   # im2col/col2im run host-side (Gemmini library [24]):
# the expansion is materialized through the host DMA path, not accelerator
# HBM — this is exactly why the paper's Fig. 11 shows the conversion
# dominating whole-layer latency.


def library_schedule(workload: TensorExpr, hw: HWConfig,
                     target: str = "spatial"):
    """The im2col library mapping (paper §VII-D, [24]): convert the
    convolution to one big GEMM (unfold operands), then split by the array
    shape.  Models the im2col/col2im traffic overhead explicitly:
    the unfolded matrix ``A'[c·r·s, x·y]`` is materialized host-side (one
    write + read of the expanded operand) and the output is folded back."""
    from .cost_model import DTYPE_BYTES, TARGETS, evaluate
    from .matching import match
    from .intrinsics import GEMM
    from .workloads import gemm as make_gemm

    tgt = TARGETS[target]
    ext = workload.extents
    if set(ext) >= {"k", "c", "x", "y", "r", "s"}:  # a convolution
        gm = make_gemm(ext["k"], ext["x"] * ext["y"], ext["c"] * ext["r"] * ext["s"],
                       name=f"{workload.name}_im2col")
        expanded = (ext["c"] * ext["r"] * ext["s"] * ext["x"] * ext["y"]
                    * DTYPE_BYTES)
        folded = ext["k"] * ext["x"] * ext["y"] * DTYPE_BYTES
        # write + read of A' at im2col, write + read of L at col2im
        conv_overhead_s = 2.0 * (expanded + folded) / (HOST_DMA_GBPS * 1e9)
    else:
        gm, conv_overhead_s = workload, 0.0

    choices = match(GEMM, gm)
    space = SoftwareSpace(gm, choices, hw, target)
    sched = space.default_schedule()
    # the library splits by array shape & scratchpad: grow tiles while legal
    for loop in list(sched.tile_map):
        while True:
            bigger = sched.with_tile(loop, min(gm.extents[loop],
                                               sched.tile_map[loop] * 2))
            if bigger.tile_map[loop] == sched.tile_map[loop]:
                break
            if not evaluate(gm, bigger, hw, target).legal:
                break
            sched = bigger
    rep = evaluate(gm, sched, hw, target)
    return sched, rep.latency_s + conv_overhead_s, conv_overhead_s
