"""Gaussian-process surrogate for MOBO (paper §V-B: "we use a Gaussian
Process as the surrogate model").

Pure-numpy GP regression with an RBF kernel.  Lengthscale/noise are selected
by maximizing the log marginal likelihood over a small deterministic grid —
cheap, robust, and good enough for the ≤ a-few-hundred observations a DSE run
produces.  One independent GP per objective (standard MOBO practice).
"""
from __future__ import annotations

import numpy as np


def _rbf(a: np.ndarray, b: np.ndarray, ls: float) -> np.ndarray:
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return np.exp(-0.5 * d2 / (ls * ls))


class GP:
    """GP regression on inputs normalized to [0,1]^d, standardized targets."""

    def __init__(self, lengthscales=(0.1, 0.2, 0.5, 1.0),
                 noises=(1e-6, 1e-4, 1e-2)):
        self._ls_grid = lengthscales
        self._noise_grid = noises
        self._fitted = False

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GP":
        fitted = fit_gps(X, np.asarray(y, dtype=float).ravel()[:, None],
                         self._ls_grid, self._noise_grid)[0]
        self.X = fitted.X
        self.y_mean = fitted.y_mean
        self.y_std = fitted.y_std
        self.ls = fitted.ls
        self.L = fitted.L
        self.alpha = fitted.alpha
        self._fitted = True
        return self

    def predict(self, Xs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Posterior mean and variance at ``Xs`` (de-standardized)."""
        assert self._fitted
        Xs = np.asarray(Xs, dtype=float)
        Ks = _rbf(self.X, Xs, self.ls)             # (n, m)
        mean = Ks.T @ self.alpha
        v = np.linalg.solve(self.L, Ks)            # (n, m)
        var = np.clip(1.0 - (v * v).sum(axis=0), 1e-12, None)
        return (mean * self.y_std + self.y_mean, var * self.y_std ** 2)

    def sample(self, Xs: np.ndarray, n_draws: int,
               rng: np.random.Generator) -> np.ndarray:
        """Independent-marginal posterior draws, shape (n_draws, m)."""
        mean, var = self.predict(Xs)
        return mean[None, :] + np.sqrt(var)[None, :] * rng.standard_normal(
            (n_draws, len(mean)))


def fit_gps(X: np.ndarray, Y: np.ndarray,
            lengthscales=(0.1, 0.2, 0.5, 1.0),
            noises=(1e-6, 1e-4, 1e-2)) -> list[GP]:
    """Fit one GP per objective column of ``Y`` (n, n_obj) sharing the
    kernel work across objectives.

    All objectives observe the same inputs, so the RBF Gram matrix and its
    Cholesky factor per (lengthscale, noise) grid point are computed once and
    reused for every objective's marginal-likelihood evaluation — fitting a
    3-objective surrogate costs one grid sweep instead of three.  Each
    objective still selects its own hyperparameters.  This is the single
    grid-search implementation: ``GP.fit`` delegates here with one column.
    """
    X = np.asarray(X, dtype=float)
    Y = np.asarray(Y, dtype=float)
    if Y.ndim == 1:
        Y = Y[:, None]
    n, n_obj = len(X), Y.shape[1]

    yn = np.empty_like(Y)
    gps = [GP(lengthscales, noises) for _ in range(n_obj)]
    for j, gp in enumerate(gps):
        gp.X = X
        gp.y_mean = float(Y[:, j].mean())
        gp.y_std = float(Y[:, j].std()) or 1.0
        yn[:, j] = (Y[:, j] - gp.y_mean) / gp.y_std

    best = [(-np.inf, None, None, None)] * n_obj
    for ls in lengthscales:
        K0 = _rbf(X, X, ls)
        for noise in noises:
            K = K0 + noise * np.eye(n)
            try:
                L = np.linalg.cholesky(K)
            except np.linalg.LinAlgError:
                continue
            alphas = np.linalg.solve(L.T, np.linalg.solve(L, yn))  # (n, n_obj)
            logdet = np.log(np.diag(L)).sum()
            for j in range(n_obj):
                lml = (-0.5 * yn[:, j] @ alphas[:, j] - logdet
                       - 0.5 * n * np.log(2 * np.pi))
                if lml > best[j][0]:
                    best[j] = (lml, ls, L, alphas[:, j])
    fallback = None
    for j, gp in enumerate(gps):
        if best[j][1] is None:  # pathological; fall back to heavy noise
            if fallback is None:
                K = _rbf(X, X, 1.0) + 1e-1 * np.eye(n)
                fallback = np.linalg.cholesky(K)
            L = fallback
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn[:, j]))
            best[j] = (0.0, 1.0, L, alpha)
        _, gp.ls, gp.L, gp.alpha = best[j]
        gp._fitted = True
    return gps
