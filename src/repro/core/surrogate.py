"""Gaussian-process surrogate for MOBO (paper §V-B: "we use a Gaussian
Process as the surrogate model").

Pure-numpy GP regression with an RBF kernel.  Lengthscale/noise are selected
by maximizing the log marginal likelihood over a small deterministic grid —
cheap, robust, and good enough for the ≤ a-few-hundred observations a DSE run
produces.  One independent GP per objective (standard MOBO practice).
"""
from __future__ import annotations

import numpy as np


def _rbf(a: np.ndarray, b: np.ndarray, ls: float) -> np.ndarray:
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return np.exp(-0.5 * d2 / (ls * ls))


class GP:
    """GP regression on inputs normalized to [0,1]^d, standardized targets."""

    def __init__(self, lengthscales=(0.1, 0.2, 0.5, 1.0),
                 noises=(1e-6, 1e-4, 1e-2)):
        self._ls_grid = lengthscales
        self._noise_grid = noises
        self._fitted = False

    def fit(self, X: np.ndarray, y: np.ndarray) -> "GP":
        X = np.asarray(X, dtype=float)
        y = np.asarray(y, dtype=float).ravel()
        self.X = X
        self.y_mean = float(y.mean())
        self.y_std = float(y.std()) or 1.0
        yn = (y - self.y_mean) / self.y_std

        best = (-np.inf, None, None, None)
        n = len(X)
        for ls in self._ls_grid:
            K0 = _rbf(X, X, ls)
            for noise in self._noise_grid:
                K = K0 + noise * np.eye(n)
                try:
                    L = np.linalg.cholesky(K)
                except np.linalg.LinAlgError:
                    continue
                alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
                # log marginal likelihood
                lml = (-0.5 * yn @ alpha - np.log(np.diag(L)).sum()
                       - 0.5 * n * np.log(2 * np.pi))
                if lml > best[0]:
                    best = (lml, ls, L, alpha)
        if best[1] is None:  # pathological; fall back to heavy noise
            K = _rbf(X, X, 1.0) + 1e-1 * np.eye(n)
            L = np.linalg.cholesky(K)
            alpha = np.linalg.solve(L.T, np.linalg.solve(L, yn))
            best = (0.0, 1.0, L, alpha)
        _, self.ls, self.L, self.alpha = best
        self._fitted = True
        return self

    def predict(self, Xs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Posterior mean and variance at ``Xs`` (de-standardized)."""
        assert self._fitted
        Xs = np.asarray(Xs, dtype=float)
        Ks = _rbf(self.X, Xs, self.ls)             # (n, m)
        mean = Ks.T @ self.alpha
        v = np.linalg.solve(self.L, Ks)            # (n, m)
        var = np.clip(1.0 - (v * v).sum(axis=0), 1e-12, None)
        return (mean * self.y_std + self.y_mean, var * self.y_std ** 2)

    def sample(self, Xs: np.ndarray, n_draws: int,
               rng: np.random.Generator) -> np.ndarray:
        """Independent-marginal posterior draws, shape (n_draws, m)."""
        mean, var = self.predict(Xs)
        return mean[None, :] + np.sqrt(var)[None, :] * rng.standard_normal(
            (n_draws, len(mean)))
