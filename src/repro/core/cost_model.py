"""Analytical accelerator cost model — the Maestro analogue (paper §III,
§VII-A "Metrics"; DESIGN.md §4).

Estimates (latency, power, area) for running one workload under a schedule on
one accelerator instance.  Two targets share the same machinery:

  * ``spatial`` — paper-faithful: the accelerator's peak is 2·PEs·freq, PE
    arrays may be small (8×8 …), exactly the regime of the paper's FPGA/ASIC
    prototypes.  Used to reproduce Fig. 7 / Table II / Table III.
  * ``tpu``     — v5e-class constants (197 TFLOP/s bf16, 819 GB/s HBM) where
    the "PE array" is the Pallas block shape and utilization includes MXU
    (128-lane) alignment.  Used for kernel tuning and the roofline bridge.

The reuse model is the classic stationarity-from-loop-order analysis: an
operand is re-fetched from DRAM/HBM each time the innermost loop that indexes
it advances; loops strictly inner to that reuse the scratchpad-resident tile.
This is what makes p1-vs-p2-style loop-order effects (paper Fig. 2) visible.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .hw_primitives import HWConfig
from .sw_primitives import Schedule
from .tst import TensorExpr

DTYPE_BYTES = 2       # bf16 operands
ACC_BYTES = 4         # f32 accumulation

# -- target constants ---------------------------------------------------------


@dataclass(frozen=True)
class Target:
    name: str
    freq_hz: float            # PE MAC rate (spatial) / MXU clock (tpu)
    hbm_gbps: float           # off-chip bandwidth
    dma_overhead_bytes: int   # per-descriptor fixed cost (burst model)
    mxu_aligned: bool         # apply 128-lane alignment penalties
    startup_s: float          # kernel/interface launch overhead
    # energy constants (pJ)
    e_mac_pj: float
    e_sram_pj_b: float
    e_dram_pj_b: float
    # area constants (um^2)
    a_pe_um2: float
    a_mem_um2_b: float
    static_w_per_norm: float  # static power at full resource envelope


SPATIAL = Target("spatial", freq_hz=940e6, hbm_gbps=32.0,
                 dma_overhead_bytes=64, mxu_aligned=False, startup_s=2e-7,
                 # dma_overhead 64B ~ AXI4 burst setup on FPGA DDR,
                 # startup = instruction-issue cost of one tensorize-interface
                 # invocation (the paper's interfaces are accelerator
                 # instruction sequences, not host launches)
                 e_mac_pj=0.6, e_sram_pj_b=1.0, e_dram_pj_b=30.0,
                 a_pe_um2=1.0e5, a_mem_um2_b=120.0, static_w_per_norm=2.0)

TPU_V5E = Target("tpu", freq_hz=940e6, hbm_gbps=819.0,
                 dma_overhead_bytes=512, mxu_aligned=True, startup_s=1e-6,
                 e_mac_pj=0.25, e_sram_pj_b=0.6, e_dram_pj_b=15.0,
                 a_pe_um2=1.0e5, a_mem_um2_b=120.0, static_w_per_norm=4.0)

TARGETS = {"spatial": SPATIAL, "tpu": TPU_V5E}


@dataclass(frozen=True)
class CostReport:
    latency_s: float
    energy_j: float
    power_w: float
    area_um2: float
    flops: float              # padded (actually executed) flops
    useful_flops: float       # the workload's mathematical flops
    hbm_bytes: float
    compute_s: float
    memory_s: float
    calls: int                # tensorize-interface invocations
    vmem_bytes: int           # scratchpad working set claimed
    legal: bool
    why_illegal: str = ""

    @property
    def objectives(self) -> tuple[float, float, float]:
        """(latency, power, area) — all minimized (paper's Table II axes)."""
        return (self.latency_s, self.power_w, self.area_um2)

    @property
    def utilization(self) -> float:
        return self.useful_flops / max(self.flops, 1.0)


ILLEGAL = CostReport(math.inf, math.inf, math.inf, math.inf, 0, 0, 0,
                     math.inf, math.inf, 0, 0, False)


def n_pes(hw: HWConfig) -> int:
    """PE count per intrinsic family (paper Fig. 7 fixes a PE *budget*)."""
    if hw.intrinsic in ("GEMM", "CONV2D"):
        return hw.pe_rows * hw.pe_cols
    if hw.intrinsic == "GEMV":
        return hw.pe_rows * min(hw.pe_depth, 128)
    return min(hw.pe_depth, 4096)  # DOT: a reduction lane


def accelerator_area(hw: HWConfig, target: Target) -> float:
    mem = hw.vmem_bytes + hw.local_accum_kib * 1024
    return (target.a_pe_um2 * n_pes(hw)
            + target.a_mem_um2_b * mem * (1.0 + 0.05 * (hw.banks - 1)))


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _mxu_eff(dim: int, lanes: int) -> float:
    """Fraction of the 128-lane MXU filled by a block dim (tpu target)."""
    return dim / (_ceil(dim, lanes) * lanes) if dim else 1.0


def evaluate(workload: TensorExpr, schedule: Schedule, hw: HWConfig,
             target: Target | str = "spatial") -> CostReport:
    """Latency/power/area of running ``workload`` with ``schedule`` on ``hw``."""
    tgt = TARGETS[target] if isinstance(target, str) else target
    choice = schedule.choice
    if choice.intrinsic_name != hw.intrinsic:
        return ILLEGAL

    ext = workload.extents
    tiles = schedule.tile_map
    mapped = dict(choice.index_map)                # intrinsic idx -> compute idx
    inv_mapped = {c: q for q, c in mapped.items()}
    block = hw.intrinsic_dims()                    # intrinsic idx -> block extent

    # --- interface tile per mapped loop, padded to the intrinsic block -------
    tile: dict[str, int] = {}
    ptile: dict[str, int] = {}
    align_eff = 1.0
    for q, c in mapped.items():
        t = max(1, min(tiles.get(c, ext[c]), ext[c]))
        b = max(1, block[q])
        pt = _ceil(t, b) * b
        tile[c] = t
        ptile[c] = pt
        align_eff *= t / pt
    if align_eff <= 0:
        return ILLEGAL

    # --- outer software loops (trip counts use the LOGICAL tile: padding is
    # waste inside each call, not fewer calls) --------------------------------
    all_loops = list(workload.all_indices())
    trips = {l: (_ceil(ext[l], tile[l]) if l in inv_mapped else ext[l])
             for l in all_loops}
    order = [l for l in schedule.order if l in trips]
    order += [l for l in all_loops if l not in order]      # robustness
    calls = 1
    for l in all_loops:
        calls *= trips[l]

    # --- per-call footprints (bytes) -------------------------------------------
    tensors = workload.tensors()
    foot: dict[str, int] = {}
    contig: dict[str, int] = {}
    for tname, dims in tensors.items():
        sz = 1
        for dim in dims:
            contrib = sum(ptile.get(i, 1) for i in dim) - (len(dim) - 1)
            sz *= max(1, contrib)
        foot[tname] = sz * DTYPE_BYTES
        last = dims[-1]
        contig[tname] = max(1, sum(ptile.get(i, 1) for i in last)
                            - (len(last) - 1)) * DTYPE_BYTES
    out_foot = 1
    for i in workload.out_indices:
        out_foot *= ptile.get(i, 1)
    out_bytes = out_foot * ACC_BYTES
    out_contig = ptile.get(workload.out_indices[-1], 1) * ACC_BYTES

    # --- scratchpad legality ----------------------------------------------------
    buffered = 2 if hw.banks >= 2 else 1
    local = hw.local_accum_kib * 1024
    out_in_vmem = out_bytes if out_bytes > local else 0
    working = sum(foot.values()) * buffered + out_in_vmem
    if working > hw.vmem_bytes:
        return CostReport(math.inf, math.inf, math.inf,
                          accelerator_area(hw, tgt), 0, 0, 0, math.inf,
                          math.inf, calls, working, False,
                          f"working set {working}B > vmem {hw.vmem_bytes}B")

    # --- compute time --------------------------------------------------------
    pes = n_pes(hw)
    peak = 2.0 * pes * tgt.freq_hz
    eff = 1.0
    if tgt.mxu_aligned:
        eff *= _mxu_eff(hw.pe_rows, 8) * _mxu_eff(hw.pe_cols, 128)
        if hw.intrinsic in ("GEMV", "DOT"):
            eff *= 0.5  # rank-deficient MXU issue
    # dataflow consistency (paper: order must match the accelerator dataflow)
    stationary = {"OS": "__out__", "WS": list(tensors)[-1],
                  "IS": list(tensors)[0]}[hw.dataflow]
    innermost = order[-1] if order else all_loops[-1]
    idx_of = {t: {i for dim in dims for i in dim} for t, dims in tensors.items()}
    idx_of["__out__"] = set(workload.out_indices)
    if innermost in idx_of.get(stationary, set()):
        eff *= 0.85  # stationary operand thrashes: pipeline drain per call
    flops_call = 2.0
    for c in mapped.values():
        flops_call *= ptile[c]
    # unmapped loops run outside the intrinsic — one call covers mapped dims
    total_flops = flops_call * calls
    compute_s = total_flops / (peak * max(eff, 1e-6)) + tgt.startup_s * calls

    # --- memory traffic with loop-order reuse ----------------------------------
    pos = {l: k for k, l in enumerate(order)}

    def fetches(index_set: set[str]) -> int:
        inner = max((pos[l] for l in order if l in index_set), default=-1)
        f = 1
        for l in order[: inner + 1]:
            f *= trips[l]
        return f

    hbm_bytes = 0.0
    mem_s = 0.0
    for tname in tensors:
        n_fetch = fetches(idx_of[tname])
        burst = min(hw.burst_bytes, contig[tname])
        dma_eff = burst / (burst + tgt.dma_overhead_bytes)
        tb = n_fetch * foot[tname]
        hbm_bytes += tb
        mem_s += tb / (tgt.hbm_gbps * 1e9 * dma_eff)
    # output: revisit when a reduced loop is outer to the O-resident span
    p_out = max((pos[l] for l in order if l in idx_of["__out__"]), default=-1)
    revisit = any(l in workload.reduced for l in order[: p_out + 1]
                  if l not in idx_of["__out__"])
    n_out = fetches(idx_of["__out__"])
    out_total = n_out * out_bytes * (2 if revisit else 1)
    burst = min(hw.burst_bytes, out_contig)
    dma_eff = burst / (burst + tgt.dma_overhead_bytes)
    hbm_bytes += out_total
    mem_s += out_total / (tgt.hbm_gbps * 1e9 * dma_eff)

    # --- combine ----------------------------------------------------------------
    if hw.banks >= 2:
        latency = max(compute_s, mem_s) + min(compute_s, mem_s) / max(calls, 1)
    else:
        latency = compute_s + mem_s

    # --- energy / power / area ---------------------------------------------------
    macs = total_flops / 2.0
    sram_bytes = 3.0 * macs * DTYPE_BYTES / max(1, min(hw.pe_rows, 128))
    area = accelerator_area(hw, tgt)
    area_norm = (tgt.a_pe_um2 * pes) / (tgt.a_pe_um2 * 4096) \
        + (hw.vmem_bytes * tgt.a_mem_um2_b) / (16384 * 1024 * tgt.a_mem_um2_b)
    energy = (macs * tgt.e_mac_pj + sram_bytes * tgt.e_sram_pj_b
              + hbm_bytes * tgt.e_dram_pj_b) * 1e-12 \
        + tgt.static_w_per_norm * area_norm * latency
    power = energy / max(latency, 1e-12)

    return CostReport(latency, energy, power, area, total_flops,
                      float(workload.flops()), hbm_bytes, compute_s, mem_s,
                      calls, int(working), True)
