"""Analytical accelerator cost model — the Maestro analogue (paper §III,
§VII-A "Metrics"; DESIGN.md §4).

Estimates (latency, power, area) for running one workload under a schedule on
one accelerator instance.  Two evaluation paths share one set of formulas:

  * ``evaluate``       — scalar: one (schedule, hw) pair -> CostReport.  A
    thin memo-aware wrapper over the scalar core.
  * ``evaluate_batch`` — the DSE hot path (DESIGN.md §4.3): N candidate
    (hw, schedule) pairs -> an (N, 3) objectives array in one vectorized
    pass.  Candidates are grouped by tensorize choice; within a group the
    reuse/stationarity analysis runs structure-of-arrays over NumPy (tile
    sizes as (N, M) integer arrays, loop orders as permutation indices).
    An optional :class:`EvalCache` memoizes full reports keyed by
    (workload, schedule, hw, target) so repeated probes across MOBO
    iterations and Step-2/Step-3 of the co-design flow are free.

Two targets share the same machinery:

  * ``spatial`` — paper-faithful: the accelerator's peak is 2·PEs·freq, PE
    arrays may be small (8×8 …), exactly the regime of the paper's FPGA/ASIC
    prototypes.  Used to reproduce Fig. 7 / Table II / Table III.
  * ``tpu``     — v5e-class constants (197 TFLOP/s bf16, 819 GB/s HBM) where
    the "PE array" is the Pallas block shape and utilization includes MXU
    (128-lane) alignment.  Used for kernel tuning and the roofline bridge.

The reuse model is the classic stationarity-from-loop-order analysis: an
operand is re-fetched from DRAM/HBM each time the innermost loop that indexes
it advances; loops strictly inner to that reuse the scratchpad-resident tile.
This is what makes p1-vs-p2-style loop-order effects (paper Fig. 2) visible.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .hw_primitives import HWConfig
from .matching import TensorizeChoice
from .sw_primitives import Schedule
from .tst import TensorExpr

DTYPE_BYTES = 2       # bf16 operands
ACC_BYTES = 4         # f32 accumulation

# -- target constants ---------------------------------------------------------


@dataclass(frozen=True)
class Target:
    name: str
    freq_hz: float            # PE MAC rate (spatial) / MXU clock (tpu)
    hbm_gbps: float           # off-chip bandwidth
    dma_overhead_bytes: int   # per-descriptor fixed cost (burst model)
    mxu_aligned: bool         # apply 128-lane alignment penalties
    startup_s: float          # kernel/interface launch overhead
    # energy constants (pJ)
    e_mac_pj: float
    e_sram_pj_b: float
    e_dram_pj_b: float
    # area constants (um^2)
    a_pe_um2: float
    a_mem_um2_b: float
    static_w_per_norm: float  # static power at full resource envelope
    # inter-chip link bandwidth (GB/s per chip) — the tensor-parallel
    # all-reduce term; only read when hw.tp > 1
    link_gbps: float = 100.0


SPATIAL = Target("spatial", freq_hz=940e6, hbm_gbps=32.0,
                 dma_overhead_bytes=64, mxu_aligned=False, startup_s=2e-7,
                 # dma_overhead 64B ~ AXI4 burst setup on FPGA DDR,
                 # startup = instruction-issue cost of one tensorize-interface
                 # invocation (the paper's interfaces are accelerator
                 # instruction sequences, not host launches)
                 e_mac_pj=0.6, e_sram_pj_b=1.0, e_dram_pj_b=30.0,
                 a_pe_um2=1.0e5, a_mem_um2_b=120.0, static_w_per_norm=2.0,
                 # board-to-board serial links: far below HBM, the reason
                 # TP only pays off once a chip is bandwidth-bound
                 link_gbps=16.0)

TPU_V5E = Target("tpu", freq_hz=940e6, hbm_gbps=819.0,
                 dma_overhead_bytes=512, mxu_aligned=True, startup_s=1e-6,
                 e_mac_pj=0.25, e_sram_pj_b=0.6, e_dram_pj_b=15.0,
                 a_pe_um2=1.0e5, a_mem_um2_b=120.0, static_w_per_norm=4.0,
                 link_gbps=200.0)   # ICI, per chip

TARGETS = {"spatial": SPATIAL, "tpu": TPU_V5E}


@dataclass(frozen=True)
class CostReport:
    latency_s: float
    energy_j: float
    power_w: float
    area_um2: float
    flops: float              # padded (actually executed) flops
    useful_flops: float       # the workload's mathematical flops
    hbm_bytes: float
    compute_s: float
    memory_s: float
    calls: int                # tensorize-interface invocations
    vmem_bytes: int           # scratchpad working set claimed
    legal: bool
    why_illegal: str = ""

    @property
    def objectives(self) -> tuple[float, float, float]:
        """(latency, power, area) — all minimized (paper's Table II axes)."""
        return (self.latency_s, self.power_w, self.area_um2)

    @property
    def utilization(self) -> float:
        return self.useful_flops / max(self.flops, 1.0)


ILLEGAL = CostReport(math.inf, math.inf, math.inf, math.inf, 0, 0, 0,
                     math.inf, math.inf, 0, 0, False)


def n_pes(hw: HWConfig) -> int:
    """PE count per intrinsic family (paper Fig. 7 fixes a PE *budget*)."""
    if hw.intrinsic in ("GEMM", "CONV2D"):
        return hw.pe_rows * hw.pe_cols
    if hw.intrinsic == "GEMV":
        return hw.pe_rows * min(hw.pe_depth, 128)
    return min(hw.pe_depth, 4096)  # DOT: a reduction lane


def accelerator_area(hw: HWConfig, target: Target) -> float:
    mem = hw.vmem_bytes + hw.local_accum_kib * 1024
    return (target.a_pe_um2 * n_pes(hw)
            + target.a_mem_um2_b * mem
            * (1.0 + 0.05 * (hw.banks - 1))) * hw.tp


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _mxu_eff(dim: int, lanes: int) -> float:
    """Fraction of the 128-lane MXU filled by a block dim (tpu target)."""
    return dim / (_ceil(dim, lanes) * lanes) if dim else 1.0


def _evaluate_reference(workload: TensorExpr, schedule: Schedule, hw: HWConfig,
                        target: Target | str = "spatial") -> CostReport:
    """Scalar reference implementation of the cost model.

    This is the original pure-Python evaluation the vectorized batch path
    must agree with elementwise (tests/test_batched_eval.py asserts it on
    random populations).  Production callers use :func:`evaluate` /
    :func:`evaluate_batch` instead.
    """
    tgt = TARGETS[target] if isinstance(target, str) else target
    choice = schedule.choice
    if choice.intrinsic_name != hw.intrinsic:
        return ILLEGAL

    ext = workload.extents
    tiles = schedule.tile_map
    mapped = dict(choice.index_map)                # intrinsic idx -> compute idx
    inv_mapped = {c: q for q, c in mapped.items()}
    block = hw.intrinsic_dims()                    # intrinsic idx -> block extent

    # --- interface tile per mapped loop, padded to the intrinsic block -------
    tile: dict[str, int] = {}
    ptile: dict[str, int] = {}
    align_eff = 1.0
    for q, c in mapped.items():
        t = max(1, min(tiles.get(c, ext[c]), ext[c]))
        b = max(1, block[q])
        pt = _ceil(t, b) * b
        tile[c] = t
        ptile[c] = pt
        align_eff *= t / pt
    if align_eff <= 0:
        return ILLEGAL

    # --- outer software loops (trip counts use the LOGICAL tile: padding is
    # waste inside each call, not fewer calls) --------------------------------
    all_loops = list(workload.all_indices())
    trips = {l: (_ceil(ext[l], tile[l]) if l in inv_mapped else ext[l])
             for l in all_loops}
    order = [l for l in schedule.order if l in trips]
    order += [l for l in all_loops if l not in order]      # robustness
    calls = 1
    for l in all_loops:
        calls *= trips[l]

    # --- per-call footprints (bytes) -------------------------------------------
    tensors = workload.tensors()
    foot: dict[str, int] = {}
    contig: dict[str, int] = {}
    for tname, dims in tensors.items():
        sz = 1
        for dim in dims:
            contrib = sum(ptile.get(i, 1) for i in dim) - (len(dim) - 1)
            sz *= max(1, contrib)
        foot[tname] = sz * DTYPE_BYTES
        last = dims[-1]
        contig[tname] = max(1, sum(ptile.get(i, 1) for i in last)
                            - (len(last) - 1)) * DTYPE_BYTES
    out_foot = 1
    for i in workload.out_indices:
        out_foot *= ptile.get(i, 1)
    out_bytes = out_foot * ACC_BYTES
    out_contig = ptile.get(workload.out_indices[-1], 1) * ACC_BYTES

    # --- scratchpad legality ----------------------------------------------------
    buffered = 2 if hw.banks >= 2 else 1
    local = hw.local_accum_kib * 1024
    out_in_vmem = out_bytes if out_bytes > local else 0
    working = sum(foot.values()) * buffered + out_in_vmem
    if working > hw.vmem_bytes:
        return CostReport(math.inf, math.inf, math.inf,
                          accelerator_area(hw, tgt), 0, 0, 0, math.inf,
                          math.inf, calls, working, False,
                          f"working set {working}B > vmem {hw.vmem_bytes}B")

    # --- compute time --------------------------------------------------------
    # tp > 1 replicates the chip: peak compute and aggregate HBM scale with
    # tp, weights/outputs shard, and every call pays a ring all-reduce of
    # its partial outputs over the inter-chip link (the interconnect term)
    pes = n_pes(hw)
    peak = 2.0 * pes * tgt.freq_hz * hw.tp
    eff = 1.0
    if tgt.mxu_aligned:
        eff *= _mxu_eff(hw.pe_rows, 8) * _mxu_eff(hw.pe_cols, 128)
        if hw.intrinsic in ("GEMV", "DOT"):
            eff *= 0.5  # rank-deficient MXU issue
    # dataflow consistency (paper: order must match the accelerator dataflow)
    stationary = {"OS": "__out__", "WS": list(tensors)[-1],
                  "IS": list(tensors)[0]}[hw.dataflow]
    innermost = order[-1] if order else all_loops[-1]
    idx_of = {t: {i for dim in dims for i in dim} for t, dims in tensors.items()}
    idx_of["__out__"] = set(workload.out_indices)
    if innermost in idx_of.get(stationary, set()):
        eff *= 0.85  # stationary operand thrashes: pipeline drain per call
    flops_call = 2.0
    for c in mapped.values():
        flops_call *= ptile[c]
    # unmapped loops run outside the intrinsic — one call covers mapped dims
    total_flops = flops_call * calls
    compute_s = total_flops / (peak * max(eff, 1e-6)) + tgt.startup_s * calls

    # --- memory traffic with loop-order reuse ----------------------------------
    pos = {l: k for k, l in enumerate(order)}

    def fetches(index_set: set[str]) -> int:
        inner = max((pos[l] for l in order if l in index_set), default=-1)
        f = 1
        for l in order[: inner + 1]:
            f *= trips[l]
        return f

    hbm_bytes = 0.0
    mem_s = 0.0
    bw = tgt.hbm_gbps * 1e9 * hw.tp
    for tname in tensors:
        n_fetch = fetches(idx_of[tname])
        burst = min(hw.burst_bytes, contig[tname])
        dma_eff = burst / (burst + tgt.dma_overhead_bytes)
        tb = n_fetch * foot[tname]
        hbm_bytes += tb
        mem_s += tb / (bw * dma_eff)
    # output: revisit when a reduced loop is outer to the O-resident span
    p_out = max((pos[l] for l in order if l in idx_of["__out__"]), default=-1)
    revisit = any(l in workload.reduced for l in order[: p_out + 1]
                  if l not in idx_of["__out__"])
    n_out = fetches(idx_of["__out__"])
    out_total = n_out * out_bytes * (2 if revisit else 1)
    burst = min(hw.burst_bytes, out_contig)
    dma_eff = burst / (burst + tgt.dma_overhead_bytes)
    hbm_bytes += out_total
    mem_s += out_total / (bw * dma_eff)

    # --- combine ----------------------------------------------------------------
    if hw.banks >= 2:
        latency = max(compute_s, mem_s) + min(compute_s, mem_s) / max(calls, 1)
    else:
        latency = compute_s + mem_s

    # --- interconnect (tensor parallelism) ----------------------------------
    # ring all-reduce of each call's partial outputs: 2(t-1)/t of the output
    # bytes cross every chip's link; exactly zero at tp=1
    ic_bytes = calls * out_bytes * (2.0 * (hw.tp - 1) / hw.tp)
    latency += ic_bytes / (tgt.link_gbps * 1e9)

    # --- energy / power / area ---------------------------------------------------
    macs = total_flops / 2.0
    sram_bytes = 3.0 * macs * DTYPE_BYTES / max(1, min(hw.pe_rows, 128))
    area = accelerator_area(hw, tgt)
    area_norm = ((tgt.a_pe_um2 * pes) / (tgt.a_pe_um2 * 4096)
                 + (hw.vmem_bytes * tgt.a_mem_um2_b)
                 / (16384 * 1024 * tgt.a_mem_um2_b)) * hw.tp
    energy = (macs * tgt.e_mac_pj + sram_bytes * tgt.e_sram_pj_b
              + hbm_bytes * tgt.e_dram_pj_b
              + ic_bytes * tgt.e_dram_pj_b) * 1e-12 \
        + tgt.static_w_per_norm * area_norm * latency
    power = energy / max(latency, 1e-12)

    return CostReport(latency, energy, power, area, total_flops,
                      float(workload.flops()), hbm_bytes, compute_s, mem_s,
                      calls, int(working), True)


# ---------------------------------------------------------------------------
# Batched evaluation (DESIGN.md §4.3): the DSE hot path
# ---------------------------------------------------------------------------


class EvalCache:
    """Keyed memo of full CostReports over (workload, schedule, hw, target).

    One cache instance is threaded through a whole co-design run (Step 2's
    hardware DSE, its inner software DSE, and Step 3's refinement), so any
    (hw, schedule) pair probed twice — across MOBO iterations, across
    explorers, across budget tiers — is evaluated once.
    """

    def __init__(self, maxsize: int = 1 << 20):
        self._data: dict = {}
        self._maxsize = maxsize
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._data)

    def key(self, workload: TensorExpr, schedule: Schedule, hw: HWConfig,
            tgt: Target) -> tuple:
        return (_fingerprint(workload), tgt.name, hw.encode(), schedule)

    def get(self, key: tuple) -> CostReport | None:
        rep = self._data.get(key)
        if rep is None:
            self.misses += 1
        else:
            self.hits += 1
        return rep

    def put(self, key: tuple, rep: CostReport) -> None:
        if len(self._data) < self._maxsize:
            self._data[key] = rep

    @property
    def hit_rate(self) -> float:
        """Fraction of lookups served from the cache (0.0 when untouched)."""
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def stats(self) -> dict:
        return {"entries": len(self._data), "hits": self.hits,
                "misses": self.misses, "hit_rate": self.hit_rate}


def _fingerprint(workload: TensorExpr) -> tuple:
    """Stable identity of a workload for cache/prep keys (TensorExpr is a
    mutable dataclass, so it cannot key a dict itself)."""
    fp = getattr(workload, "_cm_fingerprint", None)
    if fp is None:
        fp = (workload.name, workload.output, tuple(workload.out_indices),
              tuple(sorted(workload.extents.items())), repr(workload.body))
        workload._cm_fingerprint = fp
    return fp


class _Prep:
    """Static per-workload metadata for the batched path.

    Everything that does NOT vary across candidates — loop lists, tensor
    index structure, stationarity membership masks — is derived once here;
    per-candidate state reduces to integer arrays over these.  Per
    tensorize-choice metadata (which loops the intrinsic covers and which
    hardware knob sizes each block dim) is cached in :meth:`choice_meta`, so
    one vectorized pass handles a population that mixes tensorize choices:
    an *unmapped* loop is exactly a mapped loop with tile = block = 1 (its
    trip count is the full extent and it contributes nothing to padding or
    per-call flops), which lets every candidate share full-width arrays.
    """

    __slots__ = ("loops", "loop_id", "loop_set", "ext", "tensor_names",
                 "tensor_dims", "tensor_masks", "out_ids", "out_mask",
                 "out_last_id", "red_not_out", "df_masks", "n_loops",
                 "useful_flops", "_choice_meta")

    def __init__(self, workload: TensorExpr):
        self.loops = list(workload.all_indices())
        self.n_loops = len(self.loops)
        self.loop_id = {l: k for k, l in enumerate(self.loops)}
        self.loop_set = frozenset(self.loops)
        self.ext = np.array([workload.extents[l] for l in self.loops],
                            dtype=np.int64)
        self._choice_meta: dict[int, tuple] = {}

        tensors = workload.tensors()
        self.tensor_names = list(tensors)
        self.tensor_dims = [tuple(tuple(self.loop_id[i] for i in dim)
                                  for dim in dims)
                            for dims in tensors.values()]
        self.tensor_masks = []
        for dims in tensors.values():
            m = np.zeros(self.n_loops, dtype=bool)
            for dim in dims:
                for i in dim:
                    m[self.loop_id[i]] = True
            self.tensor_masks.append(m)

        self.out_ids = [self.loop_id[i] for i in workload.out_indices
                        if i in self.loop_id]
        self.out_mask = np.zeros(self.n_loops, dtype=bool)
        self.out_mask[self.out_ids] = True
        last = workload.out_indices[-1]
        self.out_last_id = self.loop_id.get(last, -1)
        self.red_not_out = np.array(
            [l in workload.reduced and not self.out_mask[k]
             for k, l in enumerate(self.loops)], dtype=bool)

        # stationary-operand membership by dataflow code (OS=0, WS=1, IS=2)
        self.df_masks = np.stack([
            self.out_mask,
            self.tensor_masks[-1],
            self.tensor_masks[0],
        ])
        self.useful_flops = float(workload.flops())

    def choice_meta(self, choice: TensorizeChoice) -> tuple:
        """(intrinsic, icode, tile_sig, cols_list, cols_np, srcs) for one
        tensorize choice; keyed by object identity (the stored reference
        pins the id).  ``tile_sig`` is the sorted mapped-loop-name tuple —
        the order Schedule.tiles uses — and ``cols_*`` are the loop columns
        each sorted slot scatters into.  ``srcs`` names the hardware knob
        (or fixed constant) sizing each slot's intrinsic block dim."""
        meta = self._choice_meta.get(id(choice))
        if meta is None:
            from .intrinsics import BINDINGS

            binding = BINDINGS[choice.intrinsic_name]
            knobs = dict(binding.shape_knobs)
            fixed = dict(binding.fixed_dims)
            src_of = {}
            for q, c in choice.index_map:
                src_of[c] = (("const", fixed[q]) if q in fixed
                             else ("knob", knobs[q]))
            tile_sig = tuple(sorted(src_of))
            cols_list = [self.loop_id[c] for c in tile_sig]
            icode = {"GEMV": 1, "DOT": 2}.get(choice.intrinsic_name, 0)
            meta = (choice, choice.intrinsic_name, icode, tile_sig,
                    cols_list, np.array(cols_list, dtype=np.int64),
                    [src_of[c] for c in tile_sig])
            self._choice_meta[id(choice)] = meta
        return meta


_PREP_CACHE: dict[tuple, _Prep] = {}
_DF_CODE = {"OS": 0, "WS": 1, "IS": 2}


def _get_prep(workload: TensorExpr) -> _Prep:
    key = _fingerprint(workload)
    prep = _PREP_CACHE.get(key)
    if prep is None:
        prep = _Prep(workload)
        if len(_PREP_CACHE) < 4096:
            _PREP_CACHE[key] = prep
    return prep


def _order_perm_row(prep: _Prep, order: tuple[str, ...]) -> np.ndarray:
    """Robust (slow-path) order row: positions for known loops in first-seen
    order, unknown loops dropped, missing loops appended in source order —
    matching the scalar path's robustness append."""
    L = prep.n_loops
    prow = np.full(L, -1, dtype=np.int64)
    p = 0
    for l in order:
        i = prep.loop_id.get(l)
        if i is not None and prow[i] < 0:
            prow[i] = p
            p += 1
    for i in range(L):
        if prow[i] < 0:
            prow[i] = p
            p += 1
    return np.argsort(prow).astype(np.int64)


def _assemble(prep: _Prep, schedules: Sequence[Schedule],
              hws: Sequence[HWConfig], single_hw: bool) -> tuple:
    """Structure-of-arrays candidate state, full loop width:

      tiles/block (n, L) — interface tile and intrinsic block per loop,
        1 on loops a candidate's tensorize choice leaves unmapped;
      perm/pos (n, L)    — loop order as permutation indices + inverse;
      icode (n,)         — intrinsic family (0 GEMM/CONV2D, 1 GEMV, 2 DOT);
      mismatch (n,)      — choice intrinsic != hw intrinsic (illegal).

    The common case (tiles sorted over exactly the mapped loops, order a
    permutation of all loops) is assembled with a tight loop; irregular
    schedules fall back to the robust path per row.
    """
    n = len(schedules)
    L = prep.n_loops
    loop_id = prep.loop_id
    tiles = np.ones((n, L), dtype=np.int64)
    block = np.ones((n, L), dtype=np.int64)
    perm = np.empty((n, L), dtype=np.int64)
    icode = np.empty(n, dtype=np.int64)
    mismatch = np.zeros(n, dtype=bool)
    order_rows: dict[tuple, np.ndarray] = {}
    block_rows: dict[int, np.ndarray] = {}
    hw0 = hws[0] if hws else None
    for r, s in enumerate(schedules):
        choice = s.choice
        _, intr, ic, tile_sig, cols_list, cols_np, srcs = \
            prep.choice_meta(choice)
        h = hw0 if single_hw else hws[r]
        icode[r] = ic
        if h.intrinsic != intr:
            mismatch[r] = True
        st = s.tiles
        M = len(tile_sig)
        ok = len(st) == M
        if ok:
            trow = tiles[r]
            for j in range(M):
                lname, v = st[j]
                if lname != tile_sig[j]:
                    ok = False
                    break
                trow[cols_list[j]] = v
        if not ok:  # irregular tile tuple: robust per-row path
            tm = s.tile_map
            trow = tiles[r]
            trow[:] = 1
            for j, lname in enumerate(tile_sig):
                trow[cols_list[j]] = tm.get(lname, prep.ext[cols_list[j]])
        if single_hw:
            vals = block_rows.get(id(choice))
            if vals is None:
                vals = np.array([v if kind == "const" else getattr(h, v)
                                 for kind, v in srcs], dtype=np.int64)
                block_rows[id(choice)] = vals
            block[r, cols_np] = vals
        else:
            brow = block[r]
            for j, (kind, v) in enumerate(srcs):
                brow[cols_list[j]] = v if kind == "const" else getattr(h, v)
        o = s.order
        row_o = order_rows.get(o)
        if row_o is None:
            if len(o) == L and prep.loop_set.issuperset(o) and len(set(o)) == L:
                row_o = np.fromiter((loop_id[l] for l in o), np.int64, L)
            else:
                row_o = _order_perm_row(prep, o)
            order_rows[o] = row_o
        perm[r] = row_o
    pos = np.empty((n, L), dtype=np.int64)
    np.put_along_axis(pos, perm,
                      np.broadcast_to(np.arange(L, dtype=np.int64), (n, L)),
                      axis=1)
    return tiles, block, perm, pos, icode, mismatch


def _batch_group(prep: _Prep, tgt: Target, hws: Sequence[HWConfig],
                 schedules: Sequence[Schedule]) -> dict[str, np.ndarray]:
    """Vectorized cost model over N candidates of one workload (tensorize
    choices may differ per candidate).

    Mirrors ``_evaluate_reference`` formula-for-formula; returns all
    CostReport fields as (N,) arrays.
    """
    n = len(schedules)
    L = prep.n_loops

    # --- structure-of-arrays candidate state --------------------------------
    single_hw = all(h is hws[0] for h in hws)
    def hw_arr(attr):
        if single_hw:
            return np.full(n, getattr(hws[0], attr))
        return np.array([getattr(h, attr) for h in hws])

    pe_rows = hw_arr("pe_rows").astype(np.int64)
    pe_cols = hw_arr("pe_cols").astype(np.int64)
    pe_depth = hw_arr("pe_depth").astype(np.int64)
    vmem = hw_arr("vmem_kib").astype(np.int64) * 1024
    banks = hw_arr("banks").astype(np.int64)
    local_kib = hw_arr("local_accum_kib").astype(np.int64)
    burst_cap = hw_arr("burst_bytes").astype(np.int64)
    tp = hw_arr("tp").astype(np.int64)
    if single_hw:
        df_code = np.full(n, _DF_CODE[hws[0].dataflow], dtype=np.int64)
    else:
        df_code = np.array([_DF_CODE[h.dataflow] for h in hws], dtype=np.int64)

    tiles, block, perm, pos, icode, mismatch = \
        _assemble(prep, schedules, hws, single_hw)

    # --- interface tile per mapped loop, padded to the intrinsic block ------
    # (full loop width: unmapped loops carry tile = block = 1, so they pad
    # nothing and their trip count below is the full extent)
    t = np.clip(tiles, 1, prep.ext[None, :])
    pt = -(-t // block) * block
    align_eff = np.prod(t / pt, axis=1)

    # --- outer software loops (logical-tile trip counts) --------------------
    trips = (-(-prep.ext[None, :] // t)).astype(np.float64)
    calls = np.prod(trips, axis=1)
    ptile = pt

    # --- per-call footprints (bytes) ----------------------------------------
    foot = []
    contig = []
    for dims in prep.tensor_dims:
        sz = np.ones(n, dtype=np.int64)
        for dim in dims:
            contrib = ptile[:, list(dim)].sum(axis=1) - (len(dim) - 1)
            sz *= np.maximum(1, contrib)
        foot.append(sz * DTYPE_BYTES)
        last = dims[-1]
        contig.append(np.maximum(
            1, ptile[:, list(last)].sum(axis=1) - (len(last) - 1))
            * DTYPE_BYTES)
    if prep.out_ids:
        out_foot = np.prod(ptile[:, prep.out_ids], axis=1)
    else:
        out_foot = np.ones(n, dtype=np.int64)
    out_bytes = out_foot * ACC_BYTES
    out_contig = (ptile[:, prep.out_last_id] if prep.out_last_id >= 0
                  else np.ones(n, dtype=np.int64)) * ACC_BYTES

    # --- scratchpad legality ------------------------------------------------
    buffered = np.where(banks >= 2, 2, 1)
    local = local_kib * 1024
    out_in_vmem = np.where(out_bytes > local, out_bytes, 0)
    working = sum(foot) * buffered + out_in_vmem
    overflow = working > vmem

    # --- compute time -------------------------------------------------------
    pes = np.where(icode == 0, pe_rows * pe_cols,
                   np.where(icode == 1, pe_rows * np.minimum(pe_depth, 128),
                            np.minimum(pe_depth, 4096)))
    peak = 2.0 * pes * tgt.freq_hz * tp
    eff = np.ones(n)
    if tgt.mxu_aligned:
        eff = (pe_rows / (-(-pe_rows // 8) * 8)
               * (pe_cols / (-(-pe_cols // 128) * 128)))
        eff = np.where(icode >= 1, eff * 0.5, eff)  # GEMV/DOT: rank-deficient
    # dataflow consistency: stationary operand indexed by the innermost loop
    innermost = perm[:, L - 1]
    thrash = prep.df_masks[df_code, innermost]
    eff = np.where(thrash, eff * 0.85, eff)
    flops_call = 2.0 * np.prod(pt.astype(np.float64), axis=1)
    total_flops = flops_call * calls
    compute_s = (total_flops / (peak * np.maximum(eff, 1e-6))
                 + tgt.startup_s * calls)

    # --- memory traffic with loop-order reuse -------------------------------
    rows = np.arange(n)
    trips_in_order = np.take_along_axis(trips, perm, axis=1)
    cp = np.cumprod(trips_in_order, axis=1)              # prefix trip products

    def fetches(mask: np.ndarray) -> np.ndarray:
        ids = np.flatnonzero(mask)
        if len(ids) == 0:
            return np.ones(n)
        inner = pos[:, ids].max(axis=1)
        return cp[rows, inner]

    hbm_bytes = np.zeros(n)
    mem_s = np.zeros(n)
    bw = tgt.hbm_gbps * 1e9 * tp
    for mask, ft, cg in zip(prep.tensor_masks, foot, contig):
        n_fetch = fetches(mask)
        burst = np.minimum(burst_cap, cg)
        dma_eff = burst / (burst + tgt.dma_overhead_bytes)
        tb = n_fetch * ft
        hbm_bytes += tb
        mem_s += tb / (bw * dma_eff)
    # output: revisit when a reduced loop is outer to the O-resident span
    if prep.out_ids:
        p_out = pos[:, prep.out_ids].max(axis=1)
        n_out = cp[rows, p_out]
        reduced_outer = np.cumsum(prep.red_not_out[perm], axis=1)
        revisit = reduced_outer[rows, p_out] > 0
    else:
        n_out = np.ones(n)
        revisit = np.zeros(n, dtype=bool)
    out_total = n_out * out_bytes * np.where(revisit, 2, 1)
    burst = np.minimum(burst_cap, out_contig)
    dma_eff = burst / (burst + tgt.dma_overhead_bytes)
    hbm_bytes = hbm_bytes + out_total
    mem_s = mem_s + out_total / (bw * dma_eff)

    # --- combine ------------------------------------------------------------
    overlap = (np.maximum(compute_s, mem_s)
               + np.minimum(compute_s, mem_s) / np.maximum(calls, 1))
    latency = np.where(banks >= 2, overlap, compute_s + mem_s)

    # --- interconnect (tensor parallelism): per-call output all-reduce ------
    ic_bytes = calls * out_bytes * (2.0 * (tp - 1) / tp)
    latency = latency + ic_bytes / (tgt.link_gbps * 1e9)

    # --- energy / power / area ----------------------------------------------
    macs = total_flops / 2.0
    sram_bytes = (3.0 * macs * DTYPE_BYTES
                  / np.maximum(1, np.minimum(pe_rows, 128)))
    mem_bytes_cfg = vmem + local_kib * 1024
    area = (tgt.a_pe_um2 * pes
            + tgt.a_mem_um2_b * mem_bytes_cfg
            * (1.0 + 0.05 * (banks - 1))) * tp
    area_norm = ((tgt.a_pe_um2 * pes) / (tgt.a_pe_um2 * 4096)
                 + (vmem * tgt.a_mem_um2_b)
                 / (16384 * 1024 * tgt.a_mem_um2_b)) * tp
    energy = ((macs * tgt.e_mac_pj + sram_bytes * tgt.e_sram_pj_b
               + hbm_bytes * tgt.e_dram_pj_b
               + ic_bytes * tgt.e_dram_pj_b) * 1e-12
              + tgt.static_w_per_norm * area_norm * latency)
    power = energy / np.maximum(latency, 1e-12)

    # --- legality overlays --------------------------------------------------
    legal = ~(mismatch | overflow | (align_eff <= 0))
    bad = overflow & ~mismatch
    for arr in (latency, energy, power, compute_s, mem_s):
        arr[bad] = math.inf
    for arr in (total_flops, hbm_bytes):
        arr[bad] = 0.0
    if mismatch.any() or (align_eff <= 0).any():
        dead = mismatch | (align_eff <= 0)
        for arr in (latency, energy, power, area, compute_s, mem_s):
            arr[dead] = math.inf
        for arr in (total_flops, hbm_bytes, calls, working):
            arr[dead] = 0

    return {"latency_s": latency, "energy_j": energy, "power_w": power,
            "area_um2": area, "flops": total_flops, "hbm_bytes": hbm_bytes,
            "compute_s": compute_s, "memory_s": mem_s, "calls": calls,
            "vmem_bytes": working, "legal": legal, "overflow": bad,
            "vmem_cap": vmem}


def _report_at(prep: _Prep, out: dict[str, np.ndarray], i: int) -> CostReport:
    """Materialize one CostReport row from the batch arrays."""
    legal = bool(out["legal"][i])
    if not legal and not math.isfinite(out["area_um2"][i]):
        return ILLEGAL
    why = ""
    if out["overflow"][i]:
        why = (f"working set {int(out['vmem_bytes'][i])}B "
               f"> vmem {int(out['vmem_cap'][i])}B")
    return CostReport(
        float(out["latency_s"][i]), float(out["energy_j"][i]),
        float(out["power_w"][i]), float(out["area_um2"][i]),
        float(out["flops"][i]),
        prep.useful_flops if legal else 0.0,
        float(out["hbm_bytes"][i]), float(out["compute_s"][i]),
        float(out["memory_s"][i]), int(out["calls"][i]),
        int(out["vmem_bytes"][i]), legal, why)


def _broadcast_hws(hw_configs, n: int) -> list[HWConfig]:
    if isinstance(hw_configs, HWConfig):
        return [hw_configs] * n
    hws = list(hw_configs)
    if len(hws) == 1 and n > 1:
        return hws * n
    if len(hws) != n:
        raise ValueError(f"{len(hws)} hw configs for {n} schedules")
    return hws


def evaluate_batch(workload: TensorExpr,
                   hw_configs: HWConfig | Sequence[HWConfig],
                   schedules: Sequence[Schedule],
                   target: Target | str = "spatial",
                   cache: EvalCache | None = None) -> np.ndarray:
    """Score N candidate (hw, schedule) pairs in one vectorized pass.

    Returns an (N, 3) float array of minimized objectives
    (latency_s, power_w, area_um2) — the paper's Table II axes.  Rows of an
    illegal candidate are +inf in latency/power (area stays finite for a
    scratchpad overflow, matching the scalar path).  ``hw_configs`` may be a
    single config (broadcast over all schedules) or one per schedule.  With
    ``cache``, previously seen candidates are served from the memo and new
    ones are added to it.
    """
    schedules = list(schedules)
    n = len(schedules)
    if n == 0:
        return np.empty((0, 3))
    tgt = TARGETS[target] if isinstance(target, str) else target
    hws = _broadcast_hws(hw_configs, n)

    if cache is not None:
        reports = evaluate_batch_reports(workload, hws, schedules, tgt, cache)
        ys = np.empty((n, 3))
        for i, rep in enumerate(reports):
            ys[i] = rep.objectives
        return ys

    # cache-free fast path: arrays only, no CostReport materialization
    prep = _get_prep(workload)
    out = _batch_group(prep, tgt, hws, schedules)
    return np.stack([out["latency_s"], out["power_w"], out["area_um2"]],
                    axis=1)


def evaluate_batch_reports(workload: TensorExpr,
                           hw_configs: HWConfig | Sequence[HWConfig],
                           schedules: Sequence[Schedule],
                           target: Target | str = "spatial",
                           cache: EvalCache | None = None) -> list[CostReport]:
    """Like :func:`evaluate_batch` but returns full CostReports."""
    schedules = list(schedules)
    n = len(schedules)
    tgt = TARGETS[target] if isinstance(target, str) else target
    hws = _broadcast_hws(hw_configs, n)

    reports: list[CostReport | None] = [None] * n
    keys: list[tuple | None] = [None] * n
    todo: list[int] = []
    if cache is not None:
        for i in range(n):
            keys[i] = cache.key(workload, schedules[i], hws[i], tgt)
            reports[i] = cache.get(keys[i])
            if reports[i] is None:
                todo.append(i)
    else:
        todo = list(range(n))

    if todo:
        prep = _get_prep(workload)
        out = _batch_group(prep, tgt, [hws[i] for i in todo],
                           [schedules[i] for i in todo])
        for j, i in enumerate(todo):
            rep = _report_at(prep, out, j)
            reports[i] = rep
            if cache is not None:
                cache.put(keys[i], rep)
    return reports  # type: ignore[return-value]


def evaluate(workload: TensorExpr, schedule: Schedule, hw: HWConfig,
             target: Target | str = "spatial",
             cache: EvalCache | None = None) -> CostReport:
    """Latency/power/area of running ``workload`` with ``schedule`` on ``hw``.

    Thin memo-aware wrapper over the scalar core: a cache hit (including one
    populated by :func:`evaluate_batch`) is free; a miss computes one
    CostReport and stores it.  Agrees elementwise with ``evaluate_batch``
    (asserted by tests/test_batched_eval.py).
    """
    if cache is None:
        return _evaluate_reference(workload, schedule, hw, target)
    tgt = TARGETS[target] if isinstance(target, str) else target
    key = cache.key(workload, schedule, hw, tgt)
    rep = cache.get(key)
    if rep is None:
        rep = _evaluate_reference(workload, schedule, hw, tgt)
        cache.put(key, rep)
    return rep
