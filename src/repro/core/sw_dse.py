"""Software DSE driver (paper §VI-B, Fig. 5(a)):

  initialize a candidate pool of random primitive sequences  →  repeat:
  heuristic top-k picks valuable candidates  →  Q-learning picks the most
  promising revision choice per candidate  →  evaluate, learn, iterate.

The DQN is shared across all design points of one software space (paper).

Evaluation is batched (DESIGN.md §4.3): the initial pool, the whole revision
frontier of each round, and each refill are scored through
``SoftwareSpace.latency_batch`` — one vectorized cost-model pass per batch —
and the DQN scores all chosen candidates with a single network forward.  An
optional :class:`~repro.core.cost_model.EvalCache` makes re-probed
(hw, schedule) points free across rounds, budget tiers, and co-design steps.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .cost_model import EvalCache
from .heuristic import top_k
from .hw_primitives import HWConfig
from .matching import TensorizeChoice
from .qlearning import DQN
from .sw_primitives import Schedule
from .sw_space import SoftwareSpace
from .tst import TensorExpr


@dataclass
class SWResult:
    schedule: Schedule
    latency_s: float
    evaluations: int
    history: list[float] = field(default_factory=list)  # best-so-far curve


def optimize(workload: TensorExpr, choices: list[TensorizeChoice],
             hw: HWConfig, *, target: str = "spatial", pool_size: int = 24,
             rounds: int = 12, k: int = 6, seed: int = 0,
             dqn: DQN | None = None, use_qlearning: bool = True,
             cache: EvalCache | None = None) -> SWResult:
    """Find a low-latency schedule for one workload on one accelerator."""
    space = SoftwareSpace(workload, choices, hw, target, cache=cache)
    rng = np.random.default_rng(seed)

    pool: list[Schedule] = [space.default_schedule()]
    pool += [space.random_schedule(rng) for _ in range(pool_size - 1)]
    lat = [float(l) for l in space.latency_batch(pool)]
    evals = len(pool)
    history = [min(lat)]

    if use_qlearning and dqn is None:
        dqn = DQN(space.n_features, len(space.moves), seed=seed)

    for _ in range(rounds):
        chosen = top_k(pool, lat, k)
        # the round's whole revision frontier in three batched calls: one
        # feature stack, one DQN forward for every candidate, one vectorized
        # cost-model pass over every revised schedule
        feats = np.stack([space.features(pool[i]) for i in chosen])
        if use_qlearning:
            acts = dqn.select_batch(feats)
        else:
            acts = rng.integers(len(space.moves), size=len(chosen))
        revised = [space.apply(pool[i], space.moves[int(a)], rng)
                   for i, a in zip(chosen, acts)]
        new_lat = space.latency_batch(revised)
        evals += len(revised)
        for j, (i, s2) in enumerate(zip(chosen, revised)):
            l2 = float(new_lat[j])
            if use_qlearning:
                # reward: relative improvement over the revised candidate
                if math.isfinite(l2) and math.isfinite(lat[i]) and lat[i] > 0:
                    r = float(np.clip((lat[i] - l2) / lat[i], -1.0, 1.0))
                else:
                    r = -1.0 if not math.isfinite(l2) else 0.0
                dqn.record(feats[j], int(acts[j]), r, space.features(s2))
                dqn.train_step()
            pool.append(s2)
            lat.append(l2)
        # keep the pool bounded: retain the most valuable half + fresh random
        keep = top_k(pool, lat, max(pool_size // 2, k))
        pool = [pool[i] for i in keep]
        lat = [lat[i] for i in keep]
        refill = [space.random_schedule(rng)
                  for _ in range(pool_size - len(pool))]
        if refill:
            lat += [float(l) for l in space.latency_batch(refill)]
            pool += refill
            evals += len(refill)
        history.append(min(lat))

    best_i = int(np.argmin(lat))
    return SWResult(pool[best_i], lat[best_i], evals, history)


def optimize_set(workloads: list[TensorExpr],
                 partition: dict[tuple[str, str], list[TensorizeChoice]],
                 hw: HWConfig, *, target: str = "spatial", seed: int = 0,
                 budget: str = "small", dqn: DQN | None = None,
                 cache: EvalCache | None = None) -> dict[str, SWResult]:
    """Per-workload schedules on a shared accelerator (paper §III: one
    accelerator per application, one program per workload)."""
    sizes = {"small": dict(pool_size=12, rounds=4, k=4),
             "full": dict(pool_size=24, rounds=12, k=6)}[budget]
    out: dict[str, SWResult] = {}
    shared_dqn = dqn
    for n, w in enumerate(workloads):
        choices = partition.get((w.name, hw.intrinsic), [])
        if not choices:
            continue
        if shared_dqn is None:
            space = SoftwareSpace(w, choices, hw, target, cache=cache)
            shared_dqn = DQN(space.n_features, len(space.moves), seed=seed)
        out[w.name] = optimize(w, choices, hw, target=target,
                               seed=seed + 17 * n, dqn=shared_dqn,
                               cache=cache, **sizes)
    return out


def total_latency(results: dict[str, SWResult]) -> float:
    """Application latency: the sum over workloads (paper Table III runs
    whole CNNs through one accelerator)."""
    if not results:
        return math.inf
    return sum(r.latency_s for r in results.values())
