"""Software DSE driver (paper §VI-B, Fig. 5(a)):

  initialize a candidate pool of random primitive sequences  →  repeat:
  heuristic top-k picks valuable candidates  →  Q-learning picks the most
  promising revision choice per candidate  →  evaluate, learn, iterate.

The DQN is shared across all design points of one software space (paper).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .heuristic import top_k
from .hw_primitives import HWConfig
from .matching import TensorizeChoice
from .qlearning import DQN
from .sw_primitives import Schedule
from .sw_space import SoftwareSpace
from .tst import TensorExpr


@dataclass
class SWResult:
    schedule: Schedule
    latency_s: float
    evaluations: int
    history: list[float] = field(default_factory=list)  # best-so-far curve


def optimize(workload: TensorExpr, choices: list[TensorizeChoice],
             hw: HWConfig, *, target: str = "spatial", pool_size: int = 24,
             rounds: int = 12, k: int = 6, seed: int = 0,
             dqn: DQN | None = None, use_qlearning: bool = True) -> SWResult:
    """Find a low-latency schedule for one workload on one accelerator."""
    space = SoftwareSpace(workload, choices, hw, target)
    rng = np.random.default_rng(seed)

    pool: list[Schedule] = [space.default_schedule()]
    pool += [space.random_schedule(rng) for _ in range(pool_size - 1)]
    lat = [space.latency(s) for s in pool]
    evals = len(pool)
    history = [min(lat)]

    if use_qlearning and dqn is None:
        dqn = DQN(space.n_features, len(space.moves), seed=seed)

    for _ in range(rounds):
        chosen = top_k(pool, lat, k)
        best = min(lat)
        for i in chosen:
            s = pool[i]
            feat = space.features(s)
            if use_qlearning:
                a = dqn.select(feat)
            else:
                a = int(rng.integers(len(space.moves)))
            s2 = space.apply(s, space.moves[a], rng)
            l2 = space.latency(s2)
            evals += 1
            if use_qlearning:
                # reward: relative improvement over the revised candidate
                if math.isfinite(l2) and math.isfinite(lat[i]) and lat[i] > 0:
                    r = float(np.clip((lat[i] - l2) / lat[i], -1.0, 1.0))
                else:
                    r = -1.0 if not math.isfinite(l2) else 0.0
                dqn.record(feat, a, r, space.features(s2))
                dqn.train_step()
            pool.append(s2)
            lat.append(l2)
        # keep the pool bounded: retain the most valuable half + fresh random
        keep = top_k(pool, lat, max(pool_size // 2, k))
        pool = [pool[i] for i in keep]
        lat = [lat[i] for i in keep]
        while len(pool) < pool_size:
            s = space.random_schedule(rng)
            pool.append(s)
            lat.append(space.latency(s))
            evals += 1
        history.append(min(lat))

    best_i = int(np.argmin(lat))
    return SWResult(pool[best_i], lat[best_i], evals, history)


def optimize_set(workloads: list[TensorExpr],
                 partition: dict[tuple[str, str], list[TensorizeChoice]],
                 hw: HWConfig, *, target: str = "spatial", seed: int = 0,
                 budget: str = "small",
                 dqn: DQN | None = None) -> dict[str, SWResult]:
    """Per-workload schedules on a shared accelerator (paper §III: one
    accelerator per application, one program per workload)."""
    sizes = {"small": dict(pool_size=12, rounds=4, k=4),
             "full": dict(pool_size=24, rounds=12, k=6)}[budget]
    out: dict[str, SWResult] = {}
    shared_dqn = dqn
    for n, w in enumerate(workloads):
        choices = partition.get((w.name, hw.intrinsic), [])
        if not choices:
            continue
        if shared_dqn is None:
            space = SoftwareSpace(w, choices, hw, target)
            shared_dqn = DQN(space.n_features, len(space.moves), seed=seed)
        out[w.name] = optimize(w, choices, hw, target=target,
                               seed=seed + 17 * n, dqn=shared_dqn, **sizes)
    return out


def total_latency(results: dict[str, SWResult]) -> float:
    """Application latency: the sum over workloads (paper Table III runs
    whole CNNs through one accelerator)."""
    if not results:
        return math.inf
    return sum(r.latency_s for r in results.values())
