"""Software DSE driver (paper §VI-B, Fig. 5(a)):

  initialize a candidate pool of random primitive sequences  →  repeat:
  heuristic top-k picks valuable candidates  →  Q-learning picks the most
  promising revision choice per candidate  →  evaluate, learn, iterate.

Two engines share these semantics (DESIGN.md §10):

  * ``engine="reference"`` — :func:`optimize` per search, sequentially.  One
    software space, one DQN, one candidate pool; the round's frontier is
    still scored through the batched cost model (DESIGN.md §4.3), but every
    search pays its own DQN forwards, per-transition train steps, and
    cost-model calls.
  * ``engine="batched"``  — :func:`run_searches` advances N searches (all
    workloads of a hardware candidate × all candidates of a ``mobo(q=N)``
    batch) round-by-round in lock-step: one stacked feature array and one
    vmapped DQN forward select every search's revisions, one jitted
    multi-transition train scan applies every search's replay inserts +
    updates, and one cost-model pass per distinct workload scores the union
    of every search's revision frontier and refill.

Each lock-step search keeps its own RNG streams and its own DQN slot (the
paper reuses a DQN within one software space, i.e. per (workload, hw) pair),
so the batched engine reproduces the reference results bit-for-bit —
``tests/test_sw_engine.py`` asserts it, ``benchmarks/bench_sw_dse.py`` gates
the speedup.  An optional :class:`~repro.core.cost_model.EvalCache` makes
re-probed (hw, schedule) points free across rounds, budget tiers, engines,
and co-design steps.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro import obs

from .cost_model import EvalCache, _fingerprint, evaluate_batch_reports
from .heuristic import top_k
from .hw_primitives import HWConfig
from .matching import TensorizeChoice
from .qlearning import DQN, DQNBank
from .sw_primitives import Schedule
from .sw_space import SoftwareSpace
from .tst import TensorExpr


# software-DSE budget tiers (paper §VI-B: Step-2 probes are cheap, the
# committed Step-3 refinement runs the full search)
BUDGETS = {"small": dict(pool_size=12, rounds=4, k=4),
           "full": dict(pool_size=24, rounds=12, k=6)}


@dataclass
class SWResult:
    schedule: Schedule
    latency_s: float
    evaluations: int
    history: list[float] = field(default_factory=list)  # best-so-far curve


@dataclass
class SearchSpec:
    """One software search: a workload to schedule on one accelerator."""

    workload: TensorExpr
    choices: list[TensorizeChoice]
    hw: HWConfig
    seed: int = 0


def optimize(workload: TensorExpr, choices: list[TensorizeChoice],
             hw: HWConfig, *, target: str = "spatial", pool_size: int = 24,
             rounds: int = 12, k: int = 6, seed: int = 0,
             dqn: DQN | None = None, use_qlearning: bool = True,
             cache: EvalCache | None = None) -> SWResult:
    """Find a low-latency schedule for one workload on one accelerator.

    This is the scalar reference engine: one search, sequential rounds,
    per-transition DQN train steps.  :func:`run_searches` advances many of
    these in lock-step with identical results.
    """
    space = SoftwareSpace(workload, choices, hw, target, cache=cache)
    rng = np.random.default_rng(seed)

    pool: list[Schedule] = [space.default_schedule()]
    pool += [space.random_schedule(rng) for _ in range(pool_size - 1)]
    lat = [float(l) for l in space.latency_batch(pool)]
    evals = len(pool)
    history = [min(lat)]

    if use_qlearning and dqn is None:
        dqn = DQN(space.n_features, len(space.moves), seed=seed)

    # fixed keep/refill split: ``top_k`` filters infeasible candidates, so
    # the kept set may come up short — the refill count stays constant (the
    # pool temporarily shrinks) to keep the reference and lock-step engines
    # on identical RNG streams
    n_keep = max(pool_size // 2, k)
    n_refill = pool_size - n_keep

    for _ in range(rounds):
        chosen = top_k(pool, lat, k)   # may be < k: only feasible candidates
        if chosen:
            # the round's whole revision frontier in three batched calls: one
            # feature stack, one DQN forward for every candidate, one
            # vectorized cost-model pass over every revised schedule
            feats = space.features_batch([pool[i] for i in chosen])
            if use_qlearning:
                acts = dqn.select_batch(feats)
            else:
                acts = rng.integers(len(space.moves), size=len(chosen))
            revised = [space.apply(pool[i], space.moves[int(a)], rng)
                       for i, a in zip(chosen, acts)]
            new_reports = space.report_batch(revised)
            evals += len(revised)
            if use_qlearning:
                next_feats = space.features_batch(revised,
                                                  reports=new_reports)
            for j, (i, s2) in enumerate(zip(chosen, revised)):
                l2 = float(new_reports[j].latency_s)
                if use_qlearning:
                    dqn.record(feats[j], int(acts[j]),
                               _reward(lat[i], l2), next_feats[j])
                    dqn.train_step()
                pool.append(s2)
                lat.append(l2)
        # keep the pool bounded: retain the most valuable feasible candidates
        # + a fixed count of fresh randoms
        keep = _keep_indices(pool, lat, n_keep)
        pool = [pool[i] for i in keep]
        lat = [lat[i] for i in keep]
        refill = [space.random_schedule(rng) for _ in range(n_refill)]
        if refill:
            lat += [float(l) for l in space.latency_batch(refill)]
            pool += refill
            evals += len(refill)
        history.append(min(lat) if lat else math.inf)

    best_i = int(np.argmin(lat))
    return SWResult(pool[best_i], lat[best_i], evals, history)


def _keep_indices(pool: list, lat: list[float], n_keep: int) -> list[int]:
    """Pool-bounding survivors: the most valuable feasible candidates; if
    the whole pool is infeasible, the newest ``n_keep`` survive instead so
    the search stays bounded without stalling on an empty pool.  Shared by
    both engines — part of the same-seed parity contract."""
    keep = top_k(pool, lat, n_keep)
    if not keep:
        keep = list(range(max(0, len(pool) - n_keep), len(pool)))
    return keep


def _reward(prev: float, new: float) -> float:
    """Relative-improvement reward of revising a candidate (paper Fig. 5)."""
    if math.isfinite(new) and math.isfinite(prev) and prev > 0:
        return float(np.clip((prev - new) / prev, -1.0, 1.0))
    return -1.0 if not math.isfinite(new) else 0.0


# ---------------------------------------------------------------------------
# Lock-step batched engine (DESIGN.md §10)
# ---------------------------------------------------------------------------


def _union_reports(spaces: list[SoftwareSpace],
                   sched_lists: list[list[Schedule]], target: str,
                   cache: EvalCache | None) -> list[list]:
    """CostReports for every search's schedules with one vectorized
    cost-model pass per *distinct workload* — searches sharing a workload
    (e.g. the same layer on q different hardware candidates) ride one call
    with per-row hardware configs."""
    groups: dict[tuple, tuple] = {}
    for si, (space, scheds) in enumerate(zip(spaces, sched_lists)):
        if not scheds:
            continue
        g = groups.setdefault(_fingerprint(space.workload),
                              (space.workload, [], [], []))
        for j, sched in enumerate(scheds):
            g[1].append(space.hw)
            g[2].append(sched)
            g[3].append((si, j))
    out: list[list] = [[None] * len(s) for s in sched_lists]
    for workload, hws, scheds, refs in groups.values():
        reps = evaluate_batch_reports(workload, hws, scheds, target,
                                      cache=cache)
        for (si, j), rep in zip(refs, reps):
            out[si][j] = rep
    return out


def run_searches(specs: list[SearchSpec], *, target: str = "spatial",
                 pool_size: int = 24, rounds: int = 12, k: int = 6,
                 use_qlearning: bool = True, cache: EvalCache | None = None,
                 engine: str = "batched") -> list[SWResult]:
    """Run N software searches, one :class:`SWResult` per spec.

    ``engine="batched"`` (production) advances all searches round-by-round in
    lock-step; ``engine="reference"`` runs :func:`optimize` per spec
    sequentially.  Same seeds ⇒ identical results either way.
    """
    if engine not in ("batched", "reference"):
        raise ValueError(f"unknown software-DSE engine: {engine!r}")
    if not specs:
        return []
    k = min(k, pool_size)   # both engines must agree on the frontier size,
    # or the same-seed contract below breaks for degenerate k > pool_size
    if engine == "reference":
        return [optimize(sp.workload, sp.choices, sp.hw, target=target,
                         pool_size=pool_size, rounds=rounds, k=k,
                         seed=sp.seed, use_qlearning=use_qlearning,
                         cache=cache) for sp in specs]
    return _run_batched(specs, target=target, pool_size=pool_size,
                        rounds=rounds, k=k, use_qlearning=use_qlearning,
                        cache=cache)


def _run_batched(specs: list[SearchSpec], *, target: str, pool_size: int,
                 rounds: int, k: int, use_qlearning: bool,
                 cache: EvalCache | None) -> list[SWResult]:
    """The lock-step engine: per round, ONE stacked feature array, ONE
    vmapped DQN selection forward, ONE jitted multi-transition train scan,
    and one cost-model pass per distinct workload over the union of every
    search's revision frontier + refill."""
    with obs.span("sw_dse.run_searches",
                  {"n": len(specs), "rounds": rounds}
                  if obs.enabled() else None):
        return _run_batched_body(specs, target=target, pool_size=pool_size,
                                 rounds=rounds, k=k,
                                 use_qlearning=use_qlearning, cache=cache)


def _run_batched_body(specs: list[SearchSpec], *, target: str,
                      pool_size: int, rounds: int, k: int,
                      use_qlearning: bool,
                      cache: EvalCache | None) -> list[SWResult]:
    N = len(specs)
    spaces = [SoftwareSpace(sp.workload, sp.choices, sp.hw, target,
                            cache=cache) for sp in specs]
    rngs = [np.random.default_rng(sp.seed) for sp in specs]
    n_moves = len(spaces[0].moves)     # MAX_LOOPS-derived: same for every
    n_feat = spaces[0].n_features      # space, which is what lets one bank
    # serve heterogeneous searches

    # per-search report/feature memos: every schedule is evaluated exactly
    # once per search (the shared EvalCache additionally dedups across
    # searches probing identical (hw, schedule) points)
    repmaps: list[dict] = [{} for _ in range(N)]
    fmaps: list[dict] = [{} for _ in range(N)]

    def remember(si: int, scheds: list[Schedule], reps: list) -> list[float]:
        rm = repmaps[si]
        for s, rep in zip(scheds, reps):
            rm[s] = rep
        return [float(rep.latency_s) for rep in reps]

    def feat_of(si: int, sched: Schedule) -> np.ndarray:
        f = fmaps[si].get(sched)
        if f is None:
            f = spaces[si].features(sched, repmaps[si].get(sched))
            fmaps[si][sched] = f
        return f

    pools: list[list[Schedule]] = []
    for space, rng in zip(spaces, rngs):
        pools.append([space.default_schedule()]
                     + [space.random_schedule(rng)
                        for _ in range(pool_size - 1)])
    init_reps = _union_reports(spaces, pools, target, cache)
    lats = [remember(si, pools[si], init_reps[si]) for si in range(N)]
    evals = [pool_size] * N
    history = [[min(l)] for l in lats]

    bank = (DQNBank(n_feat, n_moves, [sp.seed for sp in specs])
            if use_qlearning else None)
    n_keep = max(pool_size // 2, k)
    n_refill = pool_size - n_keep

    for _ in range(rounds):
        with obs.span("sw_dse.round"):
            # frontiers are feasible-only (top_k filters non-finite latencies),
            # so they may be ragged: search si revises m_si <= k candidates.
            # The stacked arrays stay (N, k, ...) — zero-padded rows feed the
            # network forward (no RNG) and are masked out of replay/training —
            # while every per-search RNG draw is sized m_si, exactly matching
            # the reference engine's stream.
            chosen = [top_k(pools[si], lats[si], k) for si in range(N)]
            counts = [len(c) for c in chosen]
            feats = np.zeros((N, k, n_feat), np.float32)
            for si in range(N):
                for j, i in enumerate(chosen[si]):
                    feats[si, j] = feat_of(si, pools[si][i])
            if use_qlearning:
                acts = bank.select_round(feats, counts=counts)    # one forward
            else:
                acts = np.zeros((N, k), int)
                for si in range(N):
                    if counts[si]:
                        acts[si, :counts[si]] = rngs[si].integers(
                            n_moves, size=counts[si])
            revised = [[spaces[si].apply(pools[si][i], spaces[si].moves[int(a)],
                                         rngs[si])
                        for i, a in zip(chosen[si], acts[si][:counts[si]])]
                       for si in range(N)]
            refills = [[spaces[si].random_schedule(rngs[si])
                        for _ in range(n_refill)] for si in range(N)]
            # the round's entire evaluation demand — every search's frontier and
            # refill — in one union pass
            union = _union_reports(spaces,
                                   [revised[si] + refills[si] for si in range(N)],
                                   target, cache)
            new_lats = [remember(si, revised[si], union[si][:counts[si]])
                        for si in range(N)]
            refill_lats = [remember(si, refills[si], union[si][counts[si]:])
                           for si in range(N)]

            if use_qlearning:
                next_feats = np.zeros((N, k, n_feat), np.float32)
                rewards = np.zeros((N, k))
                for si in range(N):
                    for j, i in enumerate(chosen[si]):
                        next_feats[si, j] = feat_of(si, revised[si][j])
                        rewards[si, j] = _reward(lats[si][i], new_lats[si][j])
                with obs.span("sw_dse.train_round"):
                    bank.train_round(feats, acts, rewards, next_feats,
                                     counts=counts)               # one scan

            for si in range(N):
                pools[si] += revised[si]
                lats[si] += new_lats[si]
                evals[si] += counts[si]
                keep = _keep_indices(pools[si], lats[si], n_keep)
                pools[si] = [pools[si][i] for i in keep]
                lats[si] = [lats[si][i] for i in keep]
                pools[si] += refills[si]
                lats[si] += refill_lats[si]
                evals[si] += n_refill
                history[si].append(min(lats[si]) if lats[si] else math.inf)

    out = []
    for si in range(N):
        best_i = int(np.argmin(lats[si]))
        out.append(SWResult(pools[si][best_i], lats[si][best_i], evals[si],
                            history[si]))
    return out


def optimize_set(workloads: list[TensorExpr],
                 partition: dict[tuple[str, str], list[TensorizeChoice]],
                 hw: HWConfig, *, target: str = "spatial", seed: int = 0,
                 budget: str = "small", dqn: DQN | None = None,
                 cache: EvalCache | None = None,
                 engine: str = "batched") -> dict[str, SWResult]:
    """Per-workload schedules on a shared accelerator (paper §III: one
    accelerator per application, one program per workload).

    All workloads advance in lock-step through the batched engine by
    default; ``engine="reference"`` runs them sequentially with identical
    results.  Passing ``dqn`` keeps the legacy explicitly-shared-agent
    sequential path.
    """
    sizes = BUDGETS[budget]
    specs = [SearchSpec(w, partition[(w.name, hw.intrinsic)], hw,
                        seed + 17 * n)
             for n, w in enumerate(workloads)
             if partition.get((w.name, hw.intrinsic))]
    if dqn is not None:
        return {sp.workload.name:
                optimize(sp.workload, sp.choices, sp.hw, target=target,
                         seed=sp.seed, dqn=dqn, cache=cache, **sizes)
                for sp in specs}
    results = run_searches(specs, target=target, cache=cache, engine=engine,
                           **sizes)
    return {sp.workload.name: r for sp, r in zip(specs, results)}


def total_latency(results: dict[str, SWResult]) -> float:
    """Application latency: the sum over workloads (paper Table III runs
    whole CNNs through one accelerator)."""
    if not results:
        return math.inf
    return sum(r.latency_s for r in results.values())
