"""NSGA-II baseline (paper §VII-C compares MOBO against it).

Standard elitist non-dominated sorting GA [Deb et al. 2002]: fast
non-dominated sort, crowding distance, binary tournament, uniform crossover
and ordinal mutation over the hardware design space encoding.  Sorting and
crowding are vectorized (one dominance matrix / one argsort per generation
instead of the double Python loop over the dominance relation), and the
hypervolume history rides the incremental front tracker (DESIGN.md §9).
"""
from __future__ import annotations

import numpy as np

from .hw_space import HWSpace
from .mobo import (BatchObjectives, DSEResult, Objectives, _finite_rows,
                   _log_rows, as_batch)
from .pareto import IncrementalHV, default_reference


def _fast_nondominated_sort(ys: np.ndarray) -> list[list[int]]:
    """Deb's rank peeling on a vectorized dominance matrix: ``dom[p, q]`` is
    "p dominates q"; rank-r members are those whose domination count hits
    zero once ranks < r are peeled off."""
    ys = np.asarray(ys, dtype=float)
    n = len(ys)
    if n == 0:
        return []
    le = np.all(ys[:, None, :] <= ys[None, :, :], axis=-1)
    lt = np.any(ys[:, None, :] < ys[None, :, :], axis=-1)
    dom = le & lt
    counts = dom.sum(axis=0).astype(np.int64)
    fronts: list[list[int]] = []
    current = np.flatnonzero(counts == 0)
    while current.size:
        fronts.append([int(i) for i in current])
        counts -= dom[current].sum(axis=0)
        counts[current] = -1            # retire assigned rows
        current = np.flatnonzero(counts == 0)
    return fronts


def _crowding(ys: np.ndarray, front: list[int]) -> dict[int, float]:
    if len(front) <= 2:
        return {i: np.inf for i in front}
    arr = ys[front]                                  # (k, n_obj)
    order = np.argsort(arr, axis=0)                  # per-objective ranking
    svals = np.take_along_axis(arr, order, axis=0)
    span = svals[-1] - svals[0]
    span = np.where(span != 0, span, 1.0)
    gaps = (svals[2:] - svals[:-2]) / span           # (k-2, n_obj)
    contrib = np.zeros_like(arr)
    np.put_along_axis(contrib, order[1:-1], gaps, axis=0)
    dist = contrib.sum(axis=1)
    dist[order[0]] = np.inf                          # boundary points
    dist[order[-1]] = np.inf
    return {front[k]: float(dist[k]) for k in range(len(front))}


def nsga2(space: HWSpace, objectives: Objectives, *, pop_size: int = 5,
          n_trials: int = 20, seed: int = 0,
          batch_objectives: BatchObjectives | None = None,
          children_per_gen: int = 1) -> DSEResult:
    """Evaluate at most ``n_trials`` distinct design points (the paper caps
    all methods by trial count — evaluations are the expensive resource).

    The initial population and each generation's offspring are scored
    through one batched objectives call; ``children_per_gen > 1`` evaluates
    a whole brood per generation (clipped to the trial budget) before
    environmental selection.
    """
    rng = np.random.default_rng(seed)
    fbatch = as_batch(objectives, batch_objectives)
    configs = space.sample(rng, pop_size)
    ys = np.asarray(fbatch(configs), dtype=float)
    tried = {c.encode(): i for i, c in enumerate(configs)}

    all_configs = list(configs)
    all_ys = ys.copy()

    fin = _finite_rows(all_ys)
    base = all_ys[fin] if fin.any() else np.ones((1, all_ys.shape[1]))
    ref = default_reference(_log_rows(base), margin=1.3)

    tracker = IncrementalHV(ref)
    for y in all_ys:
        if np.all(np.isfinite(y)):
            tracker.add(_log_rows(y))
    hv_history = [0.0] * (len(all_configs) - 1) + [tracker.hv]

    pop_idx = list(range(len(configs)))
    while len(all_configs) < n_trials:
        pys = all_ys[pop_idx]
        fronts = _fast_nondominated_sort(pys)
        rank = {}
        crowd = {}
        for r, f in enumerate(fronts):
            c = _crowding(pys, f)
            for i in f:
                rank[i] = r
                crowd[i] = c[i]

        def tournament() -> int:
            a, b = rng.integers(len(pop_idx)), rng.integers(len(pop_idx))
            if rank.get(a, 0) != rank.get(b, 0):
                return pop_idx[a] if rank.get(a, 0) < rank.get(b, 0) else pop_idx[b]
            return pop_idx[a] if crowd.get(a, 0) >= crowd.get(b, 0) else pop_idx[b]

        # produce this generation's brood of unseen offspring, then score
        # the whole brood with one batched objectives call
        brood: list = []
        brood_keys = set()
        want = min(max(1, children_per_gen), n_trials - len(all_configs))
        for _ in range(64 * want):
            if len(brood) >= want:
                break
            pa = all_configs[tournament()]
            pb = all_configs[tournament()]
            c = space.mutate(space.crossover(pa, pb, rng), rng)
            key = c.encode()
            if key not in tried and key not in brood_keys:
                brood.append(c)
                brood_keys.add(key)
        if len(brood) < want:
            extra = space.sample(rng, want - len(brood),
                                 exclude=set(tried) | brood_keys)
            brood += extra
            if not brood:
                break
        ys_brood = np.asarray(fbatch(brood), dtype=float)
        new_idx = []
        for child, y in zip(brood, ys_brood):
            tried[child.encode()] = len(all_configs)
            new_idx.append(len(all_configs))
            all_configs.append(child)
            all_ys = np.vstack([all_ys, y[None, :]])
            if np.all(np.isfinite(y)):
                tracker.add(_log_rows(y))
            hv_history.append(tracker.hv)

        # environmental selection on the union
        union = pop_idx + new_idx
        uys = all_ys[union]
        fronts = _fast_nondominated_sort(uys)
        new_pop: list[int] = []
        for f in fronts:
            if len(new_pop) + len(f) <= pop_size:
                new_pop += [union[i] for i in f]
            else:
                c = _crowding(uys, f)
                rest = sorted(f, key=lambda i: -c[i])
                new_pop += [union[i] for i in rest[: pop_size - len(new_pop)]]
                break
        pop_idx = new_pop

    return DSEResult(all_configs, all_ys, hv_history, len(all_configs), ref)
