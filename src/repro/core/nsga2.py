"""NSGA-II baseline (paper §VII-C compares MOBO against it).

Standard elitist non-dominated sorting GA [Deb et al. 2002]: fast
non-dominated sort, crowding distance, binary tournament, uniform crossover
and ordinal mutation over the hardware design space encoding.
"""
from __future__ import annotations

import numpy as np

from .hw_space import HWSpace
from .mobo import (BatchObjectives, DSEResult, Objectives, _finite_rows,
                   as_batch)
from .pareto import default_reference, hypervolume


def _fast_nondominated_sort(ys: np.ndarray) -> list[list[int]]:
    n = len(ys)
    S = [[] for _ in range(n)]
    counts = np.zeros(n, dtype=int)
    fronts: list[list[int]] = [[]]
    for p in range(n):
        for q in range(n):
            if p == q:
                continue
            if np.all(ys[p] <= ys[q]) and np.any(ys[p] < ys[q]):
                S[p].append(q)
            elif np.all(ys[q] <= ys[p]) and np.any(ys[q] < ys[p]):
                counts[p] += 1
        if counts[p] == 0:
            fronts[0].append(p)
    i = 0
    while fronts[i]:
        nxt: list[int] = []
        for p in fronts[i]:
            for q in S[p]:
                counts[q] -= 1
                if counts[q] == 0:
                    nxt.append(q)
        i += 1
        fronts.append(nxt)
    return fronts[:-1]


def _crowding(ys: np.ndarray, front: list[int]) -> dict[int, float]:
    dist = {i: 0.0 for i in front}
    if len(front) <= 2:
        return {i: np.inf for i in front}
    arr = ys[front]
    for m in range(ys.shape[1]):
        order = np.argsort(arr[:, m])
        span = arr[order[-1], m] - arr[order[0], m] or 1.0
        dist[front[order[0]]] = np.inf
        dist[front[order[-1]]] = np.inf
        for k in range(1, len(front) - 1):
            dist[front[order[k]]] += (arr[order[k + 1], m]
                                      - arr[order[k - 1], m]) / span
    return dist


def nsga2(space: HWSpace, objectives: Objectives, *, pop_size: int = 5,
          n_trials: int = 20, seed: int = 0,
          batch_objectives: BatchObjectives | None = None,
          children_per_gen: int = 1) -> DSEResult:
    """Evaluate at most ``n_trials`` distinct design points (the paper caps
    all methods by trial count — evaluations are the expensive resource).

    The initial population and each generation's offspring are scored
    through one batched objectives call; ``children_per_gen > 1`` evaluates
    a whole brood per generation (clipped to the trial budget) before
    environmental selection.
    """
    rng = np.random.default_rng(seed)
    fbatch = as_batch(objectives, batch_objectives)
    configs = space.sample(rng, pop_size)
    ys = np.asarray(fbatch(configs), dtype=float)
    tried = {c.encode(): i for i, c in enumerate(configs)}

    all_configs = list(configs)
    all_ys = ys.copy()

    fin = _finite_rows(all_ys)
    base = all_ys[fin] if fin.any() else np.ones((1, all_ys.shape[1]))
    ref = default_reference(np.log10(np.maximum(base, 1e-30)), margin=1.3)

    def hv_of(y):
        m = _finite_rows(y)
        return hypervolume(np.log10(np.maximum(y[m], 1e-30)), ref) if m.any() else 0.0

    hv_history = [0.0] * (len(all_configs) - 1) + [hv_of(all_ys)]

    pop_idx = list(range(len(configs)))
    while len(all_configs) < n_trials:
        pys = all_ys[pop_idx]
        fronts = _fast_nondominated_sort(pys)
        rank = {}
        crowd = {}
        for r, f in enumerate(fronts):
            c = _crowding(pys, f)
            for i in f:
                rank[i] = r
                crowd[i] = c[i]

        def tournament() -> int:
            a, b = rng.integers(len(pop_idx)), rng.integers(len(pop_idx))
            if rank.get(a, 0) != rank.get(b, 0):
                return pop_idx[a] if rank.get(a, 0) < rank.get(b, 0) else pop_idx[b]
            return pop_idx[a] if crowd.get(a, 0) >= crowd.get(b, 0) else pop_idx[b]

        # produce this generation's brood of unseen offspring, then score
        # the whole brood with one batched objectives call
        brood: list = []
        brood_keys = set()
        want = min(max(1, children_per_gen), n_trials - len(all_configs))
        for _ in range(64 * want):
            if len(brood) >= want:
                break
            pa = all_configs[tournament()]
            pb = all_configs[tournament()]
            c = space.mutate(space.crossover(pa, pb, rng), rng)
            key = c.encode()
            if key not in tried and key not in brood_keys:
                brood.append(c)
                brood_keys.add(key)
        if len(brood) < want:
            extra = space.sample(rng, want - len(brood),
                                 exclude=set(tried) | brood_keys)
            brood += extra
            if not brood:
                break
        ys_brood = np.asarray(fbatch(brood), dtype=float)
        new_idx = []
        for child, y in zip(brood, ys_brood):
            tried[child.encode()] = len(all_configs)
            new_idx.append(len(all_configs))
            all_configs.append(child)
            all_ys = np.vstack([all_ys, y[None, :]])
            hv_history.append(hv_of(all_ys))

        # environmental selection on the union
        union = pop_idx + new_idx
        uys = all_ys[union]
        fronts = _fast_nondominated_sort(uys)
        new_pop: list[int] = []
        for f in fronts:
            if len(new_pop) + len(f) <= pop_size:
                new_pop += [union[i] for i in f]
            else:
                c = _crowding(uys, f)
                rest = sorted(f, key=lambda i: -c[i])
                new_pop += [union[i] for i in rest[: pop_size - len(new_pop)]]
                break
        pop_idx = new_pop

    return DSEResult(all_configs, all_ys, hv_history, len(all_configs), ref)
