"""Two-step tensorize matching (paper §IV-B).

Given an intrinsic TST ``Q`` and a compute TST, enumerate every legal
*tensorize choice*: a bijective mapping from the intrinsic's leaf occurrences
onto a subset ``P`` of the compute tree's leaves such that

  index matching:
    ① |P| = |Q|  (leaf-for-leaf),
    ② leaves of Q carrying the same index map to compute leaves carrying the
      same index (and distinct intrinsic indices map to distinct compute
      indices) — i.e. the mapping factors through an injective index map σ,
    ②' occurrence counts agree: if an intrinsic index occurs r times, its
      image must occur exactly r times in the compute tree (otherwise an
      unmapped occurrence of the same loop would vary *inside* one intrinsic
      call, which no fixed-operand intrinsic can implement),
    ②'' reduction soundness: an index the intrinsic reduces must map to an
      index the computation reduces (the intrinsic's output has summed it
      away — mapping it to a free index would be irrecoverable).  The
      converse is fine: a compute-reduced index mapped to an intrinsic-free
      index is accumulated by the software loop nest (Listing 1's ``sC +=``).

  structure matching:
    for every pair of intrinsic leaves (νa, νb), the operation kind of
    LCA(μa, μb) in the compute tree equals the kind of LCA(νa, νb) in the
    intrinsic tree.  This rejects e.g. mapping GEMM's (i, k) onto conv's
    (y, s), whose LCA is the affine ``y+s`` node rather than an access.

Unmapped compute loops become the *software loops* that the schedule
(``repro.core.sw_primitives``) splits/reorders/fuses around the interface.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from .tst import Leaf, TensorExpr, lca_kind, leaves


@dataclass(frozen=True)
class TensorizeChoice:
    """One legal HW/SW partitioning of ``workload`` onto ``intrinsic``."""

    intrinsic_name: str
    workload_name: str
    index_map: tuple[tuple[str, str], ...]   # (intrinsic index -> compute index)
    leaf_map: tuple[tuple[tuple[int, ...], tuple[int, ...]], ...]  # ν path -> μ path
    software_loops: tuple[str, ...]          # unmapped compute indices
    accumulation: bool                       # software must accumulate output
    transposed: bool                         # operand order differs from canonical

    @property
    def mapped_compute_indices(self) -> tuple[str, ...]:
        return tuple(c for _, c in self.index_map)

    @property
    def leaf_subset(self) -> frozenset[tuple[int, ...]]:
        """The compute-leaf subset P (the paper counts choices by subsets)."""
        return frozenset(mu for _, mu in self.leaf_map)

    def describe(self) -> str:
        m = ", ".join(f"{q}->{c}" for q, c in self.index_map)
        sw = ",".join(self.software_loops)
        flags = []
        if self.accumulation:
            flags.append("accum")
        if self.transposed:
            flags.append("transposed")
        return (f"{self.workload_name} on {self.intrinsic_name}: [{m}] "
                f"software loops [{sw}]" + (f" ({'+'.join(flags)})" if flags else ""))


def _group_by_index(ls: list[Leaf]) -> dict[str, list[Leaf]]:
    out: dict[str, list[Leaf]] = {}
    for l in ls:
        out.setdefault(l.index, []).append(l)
    return out


def match(intrinsic: TensorExpr, workload: TensorExpr,
          max_choices: int = 4096) -> list[TensorizeChoice]:
    """Enumerate all legal tensorize choices of ``workload`` on ``intrinsic``.

    Complexity is bounded by the paper's O(C(m,n) · l); we enumerate at the
    index level (injective maps σ) and then occurrence pairings, which visits
    a subset of the C(m,n) leaf subsets.
    """
    q_leaves = leaves(intrinsic.body)
    c_leaves = leaves(workload.body)
    q_groups = _group_by_index(q_leaves)
    c_groups = _group_by_index(c_leaves)

    q_indices = sorted(q_groups, key=lambda i: (-len(q_groups[i]), i))
    c_index_pool = sorted(c_groups)

    choices: list[TensorizeChoice] = []

    def candidates(qi: str) -> list[str]:
        out = []
        for ci in c_index_pool:
            if len(c_groups[ci]) != len(q_groups[qi]):
                continue  # ②' occurrence counts must agree
            if qi in intrinsic.reduced and ci not in workload.reduced:
                continue  # ②'' intrinsic-reduced -> compute-reduced only
            out.append(ci)
        return out

    def structure_ok(leaf_map: dict[tuple[int, ...], tuple[int, ...]]) -> bool:
        items = list(leaf_map.items())
        for (na, ma), (nb, mb) in itertools.combinations(items, 2):
            if lca_kind(intrinsic.body, na, nb) != lca_kind(workload.body, ma, mb):
                return False
        return True

    def rec(pos: int, sigma: dict[str, str], used: set[str]) -> None:
        if len(choices) >= max_choices:
            return
        if pos == len(q_indices):
            _emit(sigma)
            return
        qi = q_indices[pos]
        for ci in candidates(qi):
            if ci in used:
                continue
            sigma[qi] = ci
            used.add(ci)
            rec(pos + 1, sigma, used)
            used.discard(ci)
            del sigma[qi]

    def _emit(sigma: dict[str, str]) -> None:
        # enumerate occurrence pairings for multi-occurrence indices
        per_index_pairings: list[list[list[tuple[Leaf, Leaf]]]] = []
        for qi, ci in sigma.items():
            qs, cs = q_groups[qi], c_groups[ci]
            pairings = [list(zip(qs, perm)) for perm in itertools.permutations(cs)]
            per_index_pairings.append(pairings)
        for combo in itertools.product(*per_index_pairings):
            leaf_map = {q.path: c.path for pairing in combo for q, c in pairing}
            if not structure_ok(leaf_map):
                continue
            software = tuple(i for i in workload.all_indices()
                             if i not in sigma.values())
            # software loops that are reduced, or compute-reduced indices mapped
            # to intrinsic-free ones, require accumulation outside the call
            accum = any(i in workload.reduced for i in software) or any(
                ci in workload.reduced and qi not in intrinsic.reduced
                for qi, ci in sigma.items())
            transposed = _is_transposed(intrinsic, workload, leaf_map)
            choices.append(TensorizeChoice(
                intrinsic.name, workload.name,
                tuple(sorted(sigma.items())),
                tuple(sorted(leaf_map.items())),
                software, accum, transposed))
            if len(choices) >= max_choices:
                return

    rec(0, {}, set())

    # deduplicate identical leaf maps (possible via symmetric pairings)
    uniq: dict[tuple, TensorizeChoice] = {}
    for ch in choices:
        uniq.setdefault(ch.leaf_map, ch)
    return list(uniq.values())


def _is_transposed(intrinsic: TensorExpr, workload: TensorExpr,
                   leaf_map: dict[tuple[int, ...], tuple[int, ...]]) -> bool:
    """True if any mapped operand's leaf order differs from the intrinsic's —
    i.e. the interface must rearrange data (Fig. 4 choice #3)."""
    q_leaves = {l.path: l for l in leaves(intrinsic.body)}
    c_leaves = {l.path: l for l in leaves(workload.body)}
    by_tensor: dict[str, list[tuple[tuple[int, ...], tuple[int, ...]]]] = {}
    for nu, mu in leaf_map.items():
        by_tensor.setdefault(q_leaves[nu].tensor, []).append((nu, mu))
    for pairs in by_tensor.values():
        pairs.sort(key=lambda p: p[0])  # intrinsic dim order
        mu_dims = [ (c_leaves[mu].tensor, c_leaves[mu].dim) for _, mu in pairs ]
        if any(mu_dims[i][0] == mu_dims[i + 1][0] and mu_dims[i][1] > mu_dims[i + 1][1]
               for i in range(len(mu_dims) - 1)):
            return True
    return False


def legal_leaf_subsets(intrinsic: TensorExpr, workload: TensorExpr) -> set[frozenset]:
    """The paper reports choice counts as distinct legal leaf *subsets*
    (e.g. six for GEMM on 2D convolution)."""
    return {c.leaf_subset for c in match(intrinsic, workload)}


def partition_space(intrinsics: list[TensorExpr],
                    workloads: list[TensorExpr]) -> dict[tuple[str, str], list[TensorizeChoice]]:
    """Step 1 of Fig. 3: the full partition space, keyed by
    (workload, intrinsic)."""
    space: dict[tuple[str, str], list[TensorizeChoice]] = {}
    for w in workloads:
        for q in intrinsics:
            found = match(q, w)
            if found:
                space[(w.name, q.name)] = found
    return space
