"""Pareto sets, hypervolume, and the vectorized acquisition engine
(paper §V-B, §VII-C; DESIGN.md §9).

All objectives are *minimized*.  Hypervolume is measured against a reference
point that every point must dominate; exact algorithms for 2-D and 3-D (the
paper's latency/power/area case), Monte-Carlo fallback for higher dims.

Three layers:

  * scalar primitives — ``dominates``, ``pareto_mask``/``pareto_front``
    (vectorized dominance matrix), ``hypervolume`` (vectorized exact 2-D/3-D,
    MC beyond).
  * :class:`BoxDecomposition` — a partition of the region *not dominated* by
    a front (below the reference) into axis-aligned boxes, built once per
    front; ``hvi(cands)`` then scores the exclusive hypervolume contribution
    of M candidates in one array pass.  ``hvi_batch`` is the one-shot
    convenience wrapper.
  * :class:`IncrementalHV` — maintains a non-dominated front and its
    hypervolume as observations arrive, so per-trial hypervolume histories
    cost one box-decomposition query instead of a from-scratch recompute.

The pre-engine scalar implementations are kept verbatim as
``_reference_pareto_mask`` / ``_reference_hypervolume``: the property tests
and ``benchmarks/bench_acquisition.py`` assert the vectorized engine matches
them (masks exactly, hypervolume within 1e-9).
"""
from __future__ import annotations

import numpy as np

_INF = float("inf")


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """a dominates b (minimization): a <= b everywhere, < somewhere."""
    return bool(np.all(a <= b) and np.any(a < b))


# ---------------------------------------------------------------------------
# Reference (pre-engine) implementations — parity targets, never hot-path.
# ---------------------------------------------------------------------------

def _reference_pareto_mask(points: np.ndarray) -> np.ndarray:
    """O(n^2) Python-loop non-dominated mask (the pre-engine implementation)."""
    pts = np.asarray(points, dtype=float)
    n = len(pts)
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominated = np.all(pts <= pts[i], axis=1) & np.any(pts < pts[i], axis=1)
        if dominated.any():
            mask[i] = False
        else:
            # i dominates others -> knock them out early
            kills = np.all(pts[i] <= pts, axis=1) & np.any(pts[i] < pts, axis=1)
            mask &= ~kills
            mask[i] = True
    return mask


def _hv2d(front: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-D hypervolume of a non-dominated front (scalar sweep)."""
    pts = front[np.argsort(front[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in pts:
        if y < prev_y:
            hv += (ref[0] - x) * (prev_y - y)
            prev_y = y
    return hv


def _hv3d(front: np.ndarray, ref: np.ndarray) -> float:
    """Exact 3-D hypervolume by sweeping the third axis (scalar slabs)."""
    pts = front[np.argsort(front[:, 2])]
    zs = np.concatenate([pts[:, 2], [ref[2]]])
    hv = 0.0
    for i in range(len(pts)):
        dz = zs[i + 1] - zs[i]
        if dz <= 0:
            continue
        # points active in this slab: z <= zs[i]
        active = pts[pts[:, 2] <= zs[i]][:, :2]
        if len(active):
            fr = active[_reference_pareto_mask(active)]
            hv += _hv2d(fr, ref[:2]) * dz
    return hv


def _reference_hypervolume(points: np.ndarray, ref: np.ndarray,
                           mc_samples: int = 200_000, seed: int = 0) -> float:
    """Hypervolume via the pre-engine scalar code paths."""
    pts = np.asarray(points, dtype=float)
    ref = np.asarray(ref, dtype=float)
    if pts.ndim != 2 or len(pts) == 0:
        return 0.0
    keep = np.all(pts < ref, axis=1)
    pts = pts[keep]
    if len(pts) == 0:
        return 0.0
    front = pts[_reference_pareto_mask(pts)]
    d = front.shape[1]
    if d == 1:
        return float(ref[0] - front.min())
    if d == 2:
        return _hv2d(front, ref)
    if d == 3:
        return _hv3d(front, ref)
    # Monte-Carlo fallback (deterministic seed)
    rng = np.random.default_rng(seed)
    lo = front.min(axis=0)
    samples = rng.uniform(lo, ref, size=(mc_samples, d))
    dominated = np.zeros(mc_samples, dtype=bool)
    for p in front:
        dominated |= np.all(samples >= p, axis=1)
    box = float(np.prod(ref - lo))
    return box * dominated.mean()


# ---------------------------------------------------------------------------
# Vectorized engine
# ---------------------------------------------------------------------------

def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows (vectorized dominance matrix).

    ``dom[i, j]`` is "row i dominates row j"; a row survives iff no other row
    dominates it.  Column-chunked so huge populations stay within a bounded
    temporary footprint.
    """
    pts = np.asarray(points, dtype=float)
    n = len(pts)
    if n == 0:
        return np.zeros(0, dtype=bool)
    d = pts.shape[1]
    mask = np.empty(n, dtype=bool)
    step = max(1, (1 << 22) // max(1, n * d))
    for j0 in range(0, n, step):
        blk = pts[j0:j0 + step]
        le = np.all(pts[:, None, :] <= blk[None, :, :], axis=-1)
        lt = np.any(pts[:, None, :] < blk[None, :, :], axis=-1)
        mask[j0:j0 + step] = ~np.any(le & lt, axis=0)
    return mask


def pareto_front(points: np.ndarray) -> np.ndarray:
    pts = np.asarray(points, dtype=float)
    return pts[pareto_mask(pts)]


def _hv2d_vec(pts: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-D hypervolume, vectorized staircase (any point set)."""
    if len(pts) == 0:
        return 0.0
    order = np.argsort(pts[:, 0], kind="stable")
    stair = np.minimum.accumulate(pts[order, 1])
    prev = np.concatenate([[ref[1]], stair[:-1]])
    return float(np.sum((ref[0] - pts[order, 0])
                        * np.clip(prev - stair, 0.0, None)))


def _hv3d_vec(front: np.ndarray, ref: np.ndarray) -> float:
    """Exact 3-D hypervolume: z-slab sweep with a vectorized 2-D staircase."""
    pts = front[np.argsort(front[:, 2], kind="stable")]
    zs = np.concatenate([pts[:, 2], [ref[2]]])
    hv = 0.0
    for i in range(len(pts)):
        dz = zs[i + 1] - zs[i]
        if dz <= 0:
            continue
        hv += _hv2d_vec(pts[: i + 1, :2], ref[:2]) * dz
    return hv


def hypervolume(points: np.ndarray, ref: np.ndarray, mc_samples: int = 200_000,
                seed: int = 0) -> float:
    """Hypervolume of the Pareto front of ``points`` w.r.t. ``ref``."""
    pts = np.asarray(points, dtype=float)
    ref = np.asarray(ref, dtype=float)
    if pts.ndim != 2 or len(pts) == 0:
        return 0.0
    # clip points that exceed the reference (contribute nothing)
    keep = np.all(pts < ref, axis=1)
    pts = pts[keep]
    if len(pts) == 0:
        return 0.0
    front = pts[pareto_mask(pts)]
    d = front.shape[1]
    if d == 1:
        return float(ref[0] - front.min())
    if d == 2:
        return _hv2d_vec(front, ref)
    if d == 3:
        return _hv3d_vec(front, ref)
    # Monte-Carlo fallback (deterministic seed; identical sampling to the
    # reference implementation, so d>3 estimates match it bit-for-bit)
    rng = np.random.default_rng(seed)
    lo = front.min(axis=0)
    samples = rng.uniform(lo, ref, size=(mc_samples, d))
    dominated = np.zeros(mc_samples, dtype=bool)
    for p in front:
        dominated |= np.all(samples >= p, axis=1)
    box = float(np.prod(ref - lo))
    return box * dominated.mean()


def _reduce_front(points: np.ndarray, ref: np.ndarray) -> np.ndarray:
    """Distinct non-dominated rows strictly below ``ref`` in every dim."""
    pts = np.asarray(points, dtype=float).reshape(-1, len(ref))
    if len(pts):
        pts = pts[np.all(np.isfinite(pts), axis=1) & np.all(pts < ref, axis=1)]
    if len(pts):
        pts = np.unique(pts, axis=0)
        pts = pts[pareto_mask(pts)]
    return pts


def _staircase_boxes(front2: np.ndarray, ref2: np.ndarray
                     ) -> tuple[np.ndarray, np.ndarray]:
    """2-D columns partitioning the region not dominated by ``front2`` below
    ``ref2``.  Returns (lo, hi) of shape (T, 2); lower corners are -inf."""
    f = _reduce_front(front2, ref2)
    if len(f) == 0:
        return (np.array([[-_INF, -_INF]]), np.array([list(ref2)], dtype=float))
    f = f[np.argsort(f[:, 0], kind="stable")]   # x asc => y strictly desc
    xs, ys = f[:, 0], f[:, 1]
    lx = np.concatenate([[-_INF], xs])
    rx = np.concatenate([xs, [ref2[0]]])
    v = np.concatenate([[ref2[1]], ys])
    lo = np.stack([lx, np.full(len(v), -_INF)], axis=1)
    hi = np.stack([rx, v], axis=1)
    return lo[rx > lx], hi[rx > lx]


def _boxes_of(front: np.ndarray, ref: np.ndarray
              ) -> tuple[np.ndarray, np.ndarray]:
    """Box partition of the non-dominated region below ``ref`` (d <= 3)."""
    d = len(ref)
    if d == 1:
        hi = ref[0] if len(front) == 0 else float(front.min())
        return np.array([[-_INF]]), np.array([[hi]])
    if d == 2:
        return _staircase_boxes(front, ref)
    # d == 3: staircase columns per z-slab
    los, his = [], []
    zs = np.unique(front[:, 2]) if len(front) else np.empty(0)
    zb = np.concatenate([[-_INF], zs, [ref[2]]])
    for s in range(len(zb) - 1):
        z0, z1 = zb[s], zb[s + 1]
        if z1 <= z0:
            continue
        active = front[front[:, 2] <= z0][:, :2] if len(front) else front
        lo2, hi2 = _staircase_boxes(active, ref[:2])
        los.append(np.column_stack([lo2, np.full(len(lo2), z0)]))
        his.append(np.column_stack([hi2, np.full(len(hi2), z1)]))
    return np.concatenate(los), np.concatenate(his)


class BoxDecomposition:
    """Box partition of the region *not dominated* by ``front`` below ``ref``.

    Built once per front (the per-trial precompute of the acquisition
    engine); :meth:`hvi` then scores the exclusive hypervolume contribution
    of M candidate points in one vectorized pass: each candidate's
    contribution is the sum over boxes of ``vol([cand, ref] ∩ box)``.

    Exact for d <= 3 (2-D staircase columns, 3-D staircase × z-slabs);
    Monte-Carlo for d > 3 with a deterministic seed (samples are drawn per
    :meth:`hvi` call so the sampling box can cover the candidates).
    """

    def __init__(self, front: np.ndarray, ref: np.ndarray, *,
                 mc_samples: int = 50_000, seed: int = 0):
        self.ref = np.asarray(ref, dtype=float).reshape(-1)
        self.d = len(self.ref)
        self.front = _reduce_front(front, self.ref)
        self.mc_samples = int(mc_samples)
        self.seed = int(seed)
        if self.d <= 3:
            self._lo, self._hi = _boxes_of(self.front, self.ref)

    @property
    def n_boxes(self) -> int:
        return len(self._lo) if self.d <= 3 else 0

    def hvi(self, cands: np.ndarray, chunk: int = 1 << 22) -> np.ndarray:
        """Exclusive hypervolume contribution of each candidate row, i.e.
        ``hypervolume(front ∪ {c}) - hypervolume(front)``, shape (M,)."""
        C = np.asarray(cands, dtype=float).reshape(-1, self.d)
        # non-finite candidates (failed/imputed draws) contribute nothing
        C = np.where(np.isfinite(C), C, _INF)
        if self.d > 3:
            return self._hvi_mc(C)
        lo, hi = self._lo, self._hi
        out = np.empty(len(C))
        step = max(1, chunk // max(1, len(lo) * self.d))
        for i0 in range(0, len(C), step):
            blk = C[i0:i0 + step]
            w = hi[None, :, :] - np.maximum(lo[None, :, :], blk[:, None, :])
            out[i0:i0 + step] = np.clip(w, 0.0, None).prod(axis=-1).sum(axis=-1)
        return out

    def _hvi_mc(self, C: np.ndarray) -> np.ndarray:
        fin = np.all(np.isfinite(C), axis=1)
        if not fin.any():
            return np.zeros(len(C))
        lo = C[fin].min(axis=0)
        if len(self.front):
            lo = np.minimum(lo, self.front.min(axis=0))
        rng = np.random.default_rng(self.seed)
        samples = rng.uniform(lo, self.ref, size=(self.mc_samples, self.d))
        front_dom = np.zeros(self.mc_samples, dtype=bool)
        for p in self.front:
            front_dom |= np.all(samples >= p, axis=1)
        free = ~front_dom
        box = float(np.prod(self.ref - lo))
        out = np.zeros(len(C))
        step = max(1, (1 << 24) // max(1, self.mc_samples))
        idx = np.flatnonzero(fin)
        for i0 in range(0, len(idx), step):
            blk = idx[i0:i0 + step]
            newly = np.all(samples[None, :, :] >= C[blk, None, :], axis=-1)
            out[blk] = box * (newly & free[None, :]).mean(axis=1)
        return out


def hvi_batch(front: np.ndarray, ref: np.ndarray, cands: np.ndarray, *,
              mc_samples: int = 50_000, seed: int = 0) -> np.ndarray:
    """One-shot batched hypervolume improvement: decompose once, score M
    candidates in one pass.  Callers scoring several batches against the same
    front should hold a :class:`BoxDecomposition` (or :class:`IncrementalHV`)
    instead of re-decomposing per batch."""
    return BoxDecomposition(front, ref, mc_samples=mc_samples,
                            seed=seed).hvi(cands)


class IncrementalHV:
    """Non-dominated front + hypervolume maintained incrementally.

    ``add(y)`` folds one observation in: its hypervolume gain is scored
    against the current front's box decomposition (exact for d <= 3) and the
    front is updated in place, so a T-trial hypervolume history costs T
    decomposition queries instead of T from-scratch recomputes.  For d > 3
    the tracker recomputes the MC estimate on the (small) current front so
    histories match ``hypervolume`` exactly rather than accumulating MC
    noise.
    """

    def __init__(self, ref: np.ndarray, *, mc_samples: int = 200_000,
                 seed: int = 0):
        self.ref = np.asarray(ref, dtype=float).reshape(-1)
        self.d = len(self.ref)
        self.mc_samples = int(mc_samples)
        self.seed = int(seed)
        self.front = np.empty((0, self.d))
        self._hv = 0.0
        self._decomp: BoxDecomposition | None = None

    @property
    def hv(self) -> float:
        return self._hv

    @property
    def decomposition(self) -> BoxDecomposition:
        if self._decomp is None:
            self._decomp = BoxDecomposition(self.front, self.ref,
                                            mc_samples=self.mc_samples,
                                            seed=self.seed)
        return self._decomp

    def copy(self) -> "IncrementalHV":
        out = IncrementalHV(self.ref, mc_samples=self.mc_samples,
                            seed=self.seed)
        out.front = self.front.copy()
        out._hv = self._hv
        out._decomp = self._decomp   # immutable once built; add() re-derives
        return out

    def add(self, y: np.ndarray) -> float:
        """Fold one observation in; returns the updated hypervolume."""
        y = np.asarray(y, dtype=float).reshape(-1)
        if not (np.all(np.isfinite(y)) and np.all(y < self.ref)):
            return self._hv          # contributes nothing, front unchanged
        if len(self.front):
            dominated = np.any(np.all(self.front <= y, axis=1)
                               & np.any(self.front < y, axis=1))
            if dominated or np.any(np.all(self.front == y, axis=1)):
                return self._hv      # gain is exactly zero
        if self.d <= 3:
            self._hv += float(self.decomposition.hvi(y[None])[0])
        if len(self.front):
            keep = ~(np.all(y <= self.front, axis=1)
                     & np.any(y < self.front, axis=1))
            self.front = np.vstack([self.front[keep], y[None]])
        else:
            self.front = y[None].copy()
        self._decomp = None
        if self.d > 3:
            self._hv = hypervolume(self.front, self.ref, self.mc_samples,
                                   self.seed)
        return self._hv


def default_reference(points: np.ndarray, margin: float = 1.1) -> np.ndarray:
    """A reference point slightly beyond the observed worst per objective."""
    pts = np.asarray(points, dtype=float)
    worst = pts.max(axis=0)
    best = pts.min(axis=0)
    span = np.where(worst > best, worst - best, np.abs(worst) + 1e-9)
    return worst + (margin - 1.0) * span + 1e-12
