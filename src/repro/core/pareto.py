"""Pareto sets and the hypervolume indicator (paper §V-B, §VII-C).

All objectives are *minimized*.  Hypervolume is measured against a reference
point that every point must dominate; exact algorithms for 2-D and 3-D (the
paper's latency/power/area case), Monte-Carlo fallback for higher dims.
"""
from __future__ import annotations

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """a dominates b (minimization): a <= b everywhere, < somewhere."""
    return bool(np.all(a <= b) and np.any(a < b))


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows."""
    pts = np.asarray(points, dtype=float)
    n = len(pts)
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominated = np.all(pts <= pts[i], axis=1) & np.any(pts < pts[i], axis=1)
        if dominated.any():
            mask[i] = False
        else:
            # i dominates others -> knock them out early
            kills = np.all(pts[i] <= pts, axis=1) & np.any(pts[i] < pts, axis=1)
            mask &= ~kills
            mask[i] = True
    return mask


def pareto_front(points: np.ndarray) -> np.ndarray:
    pts = np.asarray(points, dtype=float)
    return pts[pareto_mask(pts)]


def _hv2d(front: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-D hypervolume of a non-dominated front."""
    pts = front[np.argsort(front[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in pts:
        if y < prev_y:
            hv += (ref[0] - x) * (prev_y - y)
            prev_y = y
    return hv


def _hv3d(front: np.ndarray, ref: np.ndarray) -> float:
    """Exact 3-D hypervolume by sweeping the third axis (slab decomposition)."""
    pts = front[np.argsort(front[:, 2])]
    zs = np.concatenate([pts[:, 2], [ref[2]]])
    hv = 0.0
    for i in range(len(pts)):
        dz = zs[i + 1] - zs[i]
        if dz <= 0:
            continue
        # points active in this slab: z <= zs[i]
        active = pts[pts[:, 2] <= zs[i]][:, :2]
        if len(active):
            fr = pareto_front(active)
            hv += _hv2d(fr, ref[:2]) * dz
    return hv


def hypervolume(points: np.ndarray, ref: np.ndarray, mc_samples: int = 200_000,
                seed: int = 0) -> float:
    """Hypervolume of the Pareto front of ``points`` w.r.t. ``ref``."""
    pts = np.asarray(points, dtype=float)
    ref = np.asarray(ref, dtype=float)
    if pts.ndim != 2 or len(pts) == 0:
        return 0.0
    # clip points that exceed the reference (contribute nothing)
    keep = np.all(pts < ref, axis=1)
    pts = pts[keep]
    if len(pts) == 0:
        return 0.0
    front = pareto_front(pts)
    d = front.shape[1]
    if d == 1:
        return float(ref[0] - front.min())
    if d == 2:
        return _hv2d(front, ref)
    if d == 3:
        return _hv3d(front, ref)
    # Monte-Carlo fallback (deterministic seed)
    rng = np.random.default_rng(seed)
    lo = front.min(axis=0)
    samples = rng.uniform(lo, ref, size=(mc_samples, d))
    dominated = np.zeros(mc_samples, dtype=bool)
    for p in front:
        dominated |= np.all(samples >= p, axis=1)
    box = float(np.prod(ref - lo))
    return box * dominated.mean()


def default_reference(points: np.ndarray, margin: float = 1.1) -> np.ndarray:
    """A reference point slightly beyond the observed worst per objective."""
    pts = np.asarray(points, dtype=float)
    worst = pts.max(axis=0)
    best = pts.min(axis=0)
    span = np.where(worst > best, worst - best, np.abs(worst) + 1e-9)
    return worst + (margin - 1.0) * span + 1e-12
