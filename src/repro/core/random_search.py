"""Random-search baseline for the hardware DSE comparison (paper §VII-C)."""
from __future__ import annotations

import numpy as np

from .hw_space import HWSpace
from .mobo import (BatchObjectives, DSEResult, Objectives, _finite_rows,
                   as_batch)
from .pareto import default_reference, hypervolume


def random_search(space: HWSpace, objectives: Objectives, *,
                  n_trials: int = 20, seed: int = 0,
                  batch_objectives: BatchObjectives | None = None) -> DSEResult:
    rng = np.random.default_rng(seed)
    configs = space.sample(rng, n_trials)
    ys = np.asarray(as_batch(objectives, batch_objectives)(configs),
                    dtype=float)

    fin = _finite_rows(ys)
    base = ys[fin] if fin.any() else np.ones((1, ys.shape[1]))
    ref = default_reference(np.log10(np.maximum(base, 1e-30)), margin=1.3)

    hv_history = []
    for i in range(1, len(configs) + 1):
        sub = ys[:i]
        m = _finite_rows(sub)
        hv_history.append(
            hypervolume(np.log10(np.maximum(sub[m], 1e-30)), ref)
            if m.any() else 0.0)
    return DSEResult(configs, ys, hv_history, len(configs), ref)
