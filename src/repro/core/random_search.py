"""Random-search baseline for the hardware DSE comparison (paper §VII-C)."""
from __future__ import annotations

import numpy as np

from .hw_space import HWSpace
from .mobo import (BatchObjectives, DSEResult, Objectives, _finite_rows,
                   _log_rows, as_batch)
from .pareto import IncrementalHV, default_reference


def random_search(space: HWSpace, objectives: Objectives, *,
                  n_trials: int = 20, seed: int = 0,
                  batch_objectives: BatchObjectives | None = None) -> DSEResult:
    rng = np.random.default_rng(seed)
    configs = space.sample(rng, n_trials)
    ys = np.asarray(as_batch(objectives, batch_objectives)(configs),
                    dtype=float)

    fin = _finite_rows(ys)
    base = ys[fin] if fin.any() else np.ones((1, ys.shape[1]))
    ref = default_reference(_log_rows(base), margin=1.3)

    tracker = IncrementalHV(ref)
    hv_history = []
    for y in ys:
        if np.all(np.isfinite(y)):
            tracker.add(_log_rows(y))
        hv_history.append(tracker.hv)
    return DSEResult(configs, ys, hv_history, len(configs), ref)
