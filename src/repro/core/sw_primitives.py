"""Software primitives and schedules (paper §VI-A, Fig. 5(c)).

A *schedule* concretizes one tensorize choice: ``split`` factors pick the
interface-level sub-workload size for each mapped loop, ``reorder`` fixes the
outer software loop order, ``fuse`` collapses outermost loops, ``tensorize``
marks the HW/SW boundary.  We keep the declarative form (tiles + order) as
the canonical representation and provide the primitive-sequence view for
fidelity with the paper's Fig. 5(c).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from .matching import TensorizeChoice
from .tst import TensorExpr


@dataclass(frozen=True)
class Primitive:
    """One schedule primitive: split/reorder/fuse/tensorize."""

    kind: str                 # 'split' | 'reorder' | 'fuse' | 'tensorize'
    args: tuple = ()

    def __repr__(self) -> str:
        return f"{self.kind}{self.args}"


@dataclass(frozen=True)
class Schedule:
    """A concrete software optimization for one workload on one accelerator.

    ``tiles`` maps each *mapped* compute loop to its interface tile (the
    sub-workload extent handled by one tensorize-interface call).  ``order``
    is the outer software loop order, outermost first, over ALL compute loops
    (mapped loops appear via their outer counter).  ``fuse_outer`` fuses the
    n outermost loops into one (launch-overhead reduction).
    """

    choice: TensorizeChoice
    tiles: tuple[tuple[str, int], ...]
    order: tuple[str, ...]
    fuse_outer: int = 0

    @property
    def tile_map(self) -> dict[str, int]:
        return dict(self.tiles)

    def with_tile(self, loop: str, value: int) -> "Schedule":
        tiles = tuple((l, value if l == loop else v) for l, v in self.tiles)
        return replace(self, tiles=tiles)

    def with_order(self, order: tuple[str, ...]) -> "Schedule":
        return replace(self, order=tuple(order))

    def to_primitives(self, workload: TensorExpr) -> list[Primitive]:
        """The Fig. 5(c) view: [split..., reorder, fuse, tensorize]."""
        seq: list[Primitive] = []
        for loop, t in self.tiles:
            if t < workload.extents[loop]:
                seq.append(Primitive("split", (loop, t)))
        seq.append(Primitive("reorder", tuple(self.order)))
        if self.fuse_outer > 1:
            seq.append(Primitive("fuse", (self.fuse_outer,)))
        seq.append(Primitive("tensorize",
                             (self.choice.intrinsic_name,
                              tuple(c for _, c in self.choice.index_map))))
        return seq

    def describe(self) -> str:
        t = ", ".join(f"{l}={v}" for l, v in self.tiles)
        return (f"[{self.choice.intrinsic_name}] tiles({t}) "
                f"order({'>'.join(self.order)}) fuse={self.fuse_outer}")


def schedule_from_primitives(workload: TensorExpr, choice: TensorizeChoice,
                             seq: list[Primitive]) -> Schedule:
    """Build a Schedule by *applying* a primitive sequence (paper-style API).

    Unlisted mapped loops default to full-extent tiles; the reorder primitive
    must mention every loop it keeps outer.
    """
    mapped = set(choice.mapped_compute_indices)
    tiles = {l: workload.extents[l] for l in mapped}
    order = tuple(workload.all_indices())
    fuse = 0
    for p in seq:
        if p.kind == "split":
            loop, t = p.args
            if loop in mapped:
                tiles[loop] = int(t)
        elif p.kind == "reorder":
            order = tuple(p.args[0]) if len(p.args) == 1 else tuple(p.args)
        elif p.kind == "fuse":
            fuse = int(p.args[0])
        elif p.kind == "tensorize":
            pass  # boundary marker; the choice is already given
        else:
            raise ValueError(f"unknown primitive {p.kind}")
    return Schedule(choice, tuple(sorted(tiles.items())), order, fuse)
