"""Hardware intrinsics (paper §II-B, §IV): DOT / GEMV / GEMM / CONV2D.

Each intrinsic is (a) a TST used by the two-step matcher, (b) a binding to a
Pallas TPU kernel in ``repro.kernels`` that implements it, and (c) the set of
hardware parameters that size it (``repro.core.hw_space``).  The intrinsic's
*logical* shape (which the paper fixes to the PE-array shape, e.g. 16×16) maps
on TPU to the MXU block shape of the kernel.
"""
from __future__ import annotations

from dataclasses import dataclass

from .tst import TensorExpr, parse

# Loop extents here are symbolic placeholders (the matcher ignores ranges —
# paper: "the matching does not decide the range of each node").
_E = 16

DOT = parse("C[o] = A[i] * B[i]", {"i": _E, "o": 1}, name="DOT")
GEMV = parse("C[i] = A[i,j] * B[j]", {"i": _E, "j": _E}, name="GEMV")
GEMM = parse("L[i,j] = M[i,k] * N[k,j]", {"i": _E, "j": _E, "k": _E}, name="GEMM")
CONV2D = parse(
    "C[k,x,y] = A[c,x+r,y+s] * B[k,c,r,s]",
    {"k": _E, "x": _E, "y": _E, "c": _E, "r": 3, "s": 3},
    name="CONV2D",
)

# NOTE: DOT's output is a scalar; we model it as a 1-extent index ``o`` so the
# TensorExpr machinery is uniform.  The matcher never maps ``o`` because it
# has no leaf occurrence in the body.

ALL_INTRINSICS: dict[str, TensorExpr] = {
    t.name: t for t in (DOT, GEMV, GEMM, CONV2D)
}


@dataclass(frozen=True)
class IntrinsicBinding:
    """How an intrinsic lowers to a TPU kernel."""

    name: str
    kernel: str                    # module in repro.kernels
    # which hardware parameters size the intrinsic call: intrinsic index ->
    # hardware knob ('pe_rows'/'pe_cols'/'pe_depth').  On TPU these become the
    # MXU block dims of the Pallas kernel.
    shape_knobs: tuple[tuple[str, str], ...]
    # dims the intrinsic fixes outright (CONV2D's 3x3 filter, paper §VII-B —
    # the source of its redundant computation on 5x5/7x7 workloads)
    fixed_dims: tuple[tuple[str, int], ...] = ()

    def intrinsic_shape(self, hw) -> dict[str, int]:
        out = {idx: getattr(hw, knob) for idx, knob in self.shape_knobs}
        out.update(dict(self.fixed_dims))
        return out


BINDINGS: dict[str, IntrinsicBinding] = {
    "DOT": IntrinsicBinding("DOT", "dotprod", (("i", "pe_depth"),)),
    "GEMV": IntrinsicBinding("GEMV", "gemv", (("i", "pe_rows"), ("j", "pe_depth"))),
    "GEMM": IntrinsicBinding(
        "GEMM", "gemm", (("i", "pe_rows"), ("j", "pe_cols"), ("k", "pe_depth"))),
    "CONV2D": IntrinsicBinding(
        "CONV2D", "conv2d",
        (("k", "pe_cols"), ("x", "pe_rows"), ("y", "pe_rows"), ("c", "pe_depth")),
        fixed_dims=(("r", 3), ("s", 3))),
}


def intrinsic(name: str) -> TensorExpr:
    return ALL_INTRINSICS[name.upper()]
