"""Robust JSON artifact I/O shared by the solution registry and the tuning
database (DESIGN.md §8.3).

The contract both persistence layers promise: a corrupt, missing, or
foreign artifact loads as empty with a warning — a bad file must never take
down a launch — and writes are atomic (tmp file + rename) so a concurrent
reader never observes a torn artifact.
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from pathlib import Path


def read_json_object(path: Path, label: str = "artifact") -> dict:
    """The JSON object at ``path``, or {} (with a warning) on any defect."""
    try:
        text = path.read_text()
    except FileNotFoundError:
        return {}
    except OSError as e:
        warnings.warn(f"{label} {path}: unreadable ({e}); treating as empty",
                      stacklevel=3)
        return {}
    try:
        data = json.loads(text)
    except json.JSONDecodeError as e:
        warnings.warn(f"{label} {path}: corrupt JSON ({e}); treating as "
                      f"empty", stacklevel=3)
        return {}
    if not isinstance(data, dict):
        warnings.warn(f"{label} {path}: expected an object, got "
                      f"{type(data).__name__}; treating as empty",
                      stacklevel=3)
        return {}
    return data


def atomic_write_json(path: Path, payload: dict) -> None:
    """Write ``payload`` via tmp file + rename (same-directory, so the
    rename is atomic on POSIX)."""
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name,
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
