"""Robust artifact I/O shared by the solution registry, the tuning
database, and the checkpoint manager (DESIGN.md §8.3, §14).

The contract every persistence layer promises: a corrupt, missing, or
foreign artifact loads as empty with a warning — a bad file must never take
down a launch — and writes are atomic (tmp file + rename) so a concurrent
reader never observes a torn artifact.  Both directions carry fault-
injection sites (``artifacts.read`` / ``artifacts.write``, DESIGN.md §14)
raising ``OSError`` — the realistic failure — so the chaos suite drives the
exact degradation paths a flaky filesystem would.
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
from pathlib import Path

from repro.ft import inject


def read_json_object(path: Path, label: str = "artifact") -> dict:
    """The JSON object at ``path``, or {} (with a warning) on any defect."""
    try:
        inject.check("artifacts.read", OSError)
        text = path.read_text()
    except FileNotFoundError:
        return {}
    except OSError as e:
        warnings.warn(f"{label} {path}: unreadable ({e}); treating as empty",
                      stacklevel=3)
        return {}
    try:
        data = json.loads(text)
    except json.JSONDecodeError as e:
        warnings.warn(f"{label} {path}: corrupt JSON ({e}); treating as "
                      f"empty", stacklevel=3)
        return {}
    if not isinstance(data, dict):
        warnings.warn(f"{label} {path}: expected an object, got "
                      f"{type(data).__name__}; treating as empty",
                      stacklevel=3)
        return {}
    return data


def read_bytes_safe(path: Path, label: str = "artifact") -> bytes | None:
    """The bytes at ``path``, or ``None`` (missing silently, I/O errors
    with a warning) — the binary sibling of :func:`read_json_object`."""
    try:
        inject.check("artifacts.read", OSError)
        return path.read_bytes()
    except FileNotFoundError:
        return None
    except OSError as e:
        warnings.warn(f"{label} {path}: unreadable ({e}); treating as "
                      f"missing", stacklevel=3)
        return None


def atomic_write_bytes(path: Path, payload: bytes) -> None:
    """Write ``payload`` via tmp file + rename (same-directory, so the
    rename is atomic on POSIX).  Raises ``OSError`` on failure — callers
    that must survive a flaky disk catch it (checkpointing warns and keeps
    the previous checkpoint; a torn write can never be observed)."""
    inject.check("artifacts.write", OSError)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name,
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path: Path, payload: dict) -> None:
    """Atomic JSON write (tmp file + rename) through the same injected-
    fault path as :func:`atomic_write_bytes`."""
    text = json.dumps(payload, indent=2, sort_keys=True) + "\n"
    atomic_write_bytes(path, text.encode("utf-8"))
