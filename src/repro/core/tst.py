"""Tensor syntax trees (TSTs) — HASCO's unified HW/SW IR (paper §IV-B).

A TST abstracts the loop and tensor structure of a tensor computation's
right-hand side.  Internal nodes are operations (``sum``, ``mul``, ``add``,
``access``/``[]``, ``affine``/``+`` inside one access dimension); leaves are
loop-index occurrences.  The tree for ``C[k,x,y] = sum A[c,x+r,y+s]*B[k,c,r,s]``
has nine leaves (c,x,r,y,s under the A access and k,c,r,s under the B access).

Two TSTs exist per tensorize decision: the *compute* tree (the workload) and
the *intrinsic* tree (what the accelerator's hardware intrinsic implements).
``repro.core.matching`` performs the paper's two-step matching over them.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Iterator

# ---------------------------------------------------------------------------
# Node kinds
# ---------------------------------------------------------------------------

SUM = "sum"        # reduction over one or more indices
MUL = "mul"        # n-ary product
ADD = "add"        # n-ary sum of sub-expressions
ACCESS = "access"  # tensor indexing node ``[]``
AFFINE = "affine"  # ``+`` of loops inside a single access dimension
LOOP = "loop"      # leaf: one occurrence of a loop index


@dataclass(frozen=True)
class Node:
    """One TST node.  ``children`` is a tuple of Nodes; leaves have none.

    ``label`` carries the loop index for LOOP leaves and the tensor name for
    ACCESS nodes; it is empty for pure operator nodes.
    """

    kind: str
    children: tuple["Node", ...] = ()
    label: str = ""

    def __post_init__(self) -> None:
        if self.kind == LOOP and self.children:
            raise ValueError("loop leaves cannot have children")
        if self.kind not in (SUM, MUL, ADD, ACCESS, AFFINE, LOOP):
            raise ValueError(f"unknown node kind {self.kind!r}")

    # -- convenience constructors ------------------------------------------
    @staticmethod
    def loop(index: str) -> "Node":
        return Node(LOOP, (), index)

    @staticmethod
    def access(tensor: str, dims: tuple[tuple[str, ...], ...]) -> "Node":
        """``dims`` is one tuple of loop indices per tensor dimension; a
        dimension with >1 index becomes an AFFINE node (e.g. ``x+r``)."""
        children = []
        for dim in dims:
            if len(dim) == 1:
                children.append(Node.loop(dim[0]))
            else:
                children.append(Node(AFFINE, tuple(Node.loop(i) for i in dim)))
        return Node(ACCESS, tuple(children), tensor)

    def __repr__(self) -> str:  # compact, deterministic
        if self.kind == LOOP:
            return self.label
        if self.kind == ACCESS:
            return f"{self.label}[{','.join(map(repr, self.children))}]"
        sep = {MUL: "*", ADD: " + ", AFFINE: "+"}.get(self.kind)
        if sep is not None:
            return "(" + sep.join(map(repr, self.children)) + ")"
        return f"sum({self.children[0]!r})"


@dataclass(frozen=True)
class Leaf:
    """A leaf occurrence: which index, where in the tree, inside which tensor."""

    index: str
    path: tuple[int, ...]  # child positions from the root
    tensor: str            # enclosing ACCESS label ('' if none)
    dim: int               # dimension position within the access (-1 if none)


@dataclass
class TensorExpr:
    """A full tensor computation ``out[out_indices] = sum_{reduced} body``.

    ``extents`` maps every loop index to its trip count.  ``reduced`` is the
    set of indices not appearing in the output (inferred by the parser).
    """

    name: str
    output: str
    out_indices: tuple[str, ...]
    body: Node
    extents: dict[str, int]
    reduced: frozenset[str] = field(default_factory=frozenset)

    def __post_init__(self) -> None:
        indices = {leaf.index for leaf in leaves(self.body)}
        missing = indices - set(self.extents)
        if missing:
            raise ValueError(f"{self.name}: extents missing for {sorted(missing)}")
        if not self.reduced:
            self.reduced = frozenset(indices - set(self.out_indices))

    # FLOP count for the computation (2 flops per multiply-accumulate, and
    # each extra product factor adds one multiply per point).
    def flops(self) -> int:
        n_factors = len(self.body.children) if self.body.kind == MUL else 1
        pts = 1
        for e in self.extents.values():
            pts *= e
        return pts * max(2, 2 * (n_factors - 1))

    def all_indices(self) -> tuple[str, ...]:
        seen: list[str] = []
        for leaf in leaves(self.body):
            if leaf.index not in seen:
                seen.append(leaf.index)
        return tuple(seen)

    def tensors(self) -> dict[str, tuple[tuple[str, ...], ...]]:
        """tensor name -> per-dimension index tuples (input operands only)."""
        out: dict[str, tuple[tuple[str, ...], ...]] = {}
        for node, _ in walk(self.body):
            if node.kind == ACCESS:
                dims = []
                for ch in node.children:
                    if ch.kind == LOOP:
                        dims.append((ch.label,))
                    else:
                        dims.append(tuple(g.label for g in ch.children))
                out[node.label] = tuple(dims)
        return out

    def tensor_shape(self, tensor: str) -> tuple[int, ...]:
        dims = self.tensors()[tensor]
        # affine dims (x+r) size ~ sum of extents - (#terms - 1)
        return tuple(sum(self.extents[i] for i in d) - (len(d) - 1) for d in dims)


# ---------------------------------------------------------------------------
# Traversal helpers
# ---------------------------------------------------------------------------

def walk(root: Node) -> Iterator[tuple[Node, tuple[int, ...]]]:
    stack: list[tuple[Node, tuple[int, ...]]] = [(root, ())]
    while stack:
        node, path = stack.pop()
        yield node, path
        for i, ch in enumerate(node.children):
            stack.append((ch, path + (i,)))


def leaves(root: Node) -> list[Leaf]:
    out: list[Leaf] = []

    def rec(node: Node, path: tuple[int, ...], tensor: str, dim: int) -> None:
        if node.kind == LOOP:
            out.append(Leaf(node.label, path, tensor, dim))
            return
        for i, ch in enumerate(node.children):
            if node.kind == ACCESS:
                rec(ch, path + (i,), node.label, i)
            else:
                rec(ch, path + (i,), tensor, dim)

    rec(root, (), "", -1)
    out.sort(key=lambda l: l.path)
    return out


def node_at(root: Node, path: tuple[int, ...]) -> Node:
    node = root
    for i in path:
        node = node.children[i]
    return node


def lca_kind(root: Node, a: tuple[int, ...], b: tuple[int, ...]) -> str:
    """Operation kind of the lowest common ancestor of two leaf paths."""
    k = 0
    while k < min(len(a), len(b)) and a[k] == b[k]:
        k += 1
    return node_at(root, a[:k]).kind


def count_nodes(root: Node) -> int:
    return sum(1 for _ in walk(root))


# ---------------------------------------------------------------------------
# Parser:  "C[k,x,y] = A[c,x+r,y+s] * B[k,c,r,s]"   (reduction inferred)
# ---------------------------------------------------------------------------

_ACCESS_RE = re.compile(r"([A-Za-z_]\w*)\s*\[([^\]]*)\]")


def _parse_access(text: str) -> Node:
    m = _ACCESS_RE.fullmatch(text.strip())
    if not m:
        raise ValueError(f"cannot parse tensor access {text!r}")
    tensor, idx = m.group(1), m.group(2)
    dims = tuple(tuple(p.strip() for p in d.split("+")) for d in idx.split(","))
    return Node.access(tensor, dims)


def parse(notation: str, extents: dict[str, int], name: str = "") -> TensorExpr:
    """Parse ``Out[i,j] = A[i,k] * B[k,j]`` (products of accesses, affine
    dims allowed).  Reduction indices are those absent from the output."""
    lhs, rhs = notation.split("=", 1)
    out = _ACCESS_RE.fullmatch(lhs.strip())
    if not out:
        raise ValueError(f"cannot parse output {lhs!r}")
    output, out_idx = out.group(1), tuple(i.strip() for i in out.group(2).split(","))
    factors = [f for f in rhs.split("*") if f.strip()]
    accesses = tuple(_parse_access(f) for f in factors)
    body = accesses[0] if len(accesses) == 1 else Node(MUL, accesses)
    indices = {l.index for l in leaves(body)}
    reduced = frozenset(indices - set(out_idx))
    if reduced:
        body = Node(SUM, (body,))
    return TensorExpr(name or output, output, out_idx, body, dict(extents), reduced)
