"""HASCO core: TST IR, two-step tensorize matching, HW/SW design spaces,
cost model, MOBO / NSGA-II / random hardware DSE, heuristic + Q-learning
software DSE, and the co-design driver (paper Fig. 3)."""

from .codesign import Constraints, Solution, codesign, separate_design
from .cost_model import (CostReport, EvalCache, evaluate, evaluate_batch,
                         evaluate_batch_reports)
from .hw_primitives import HWBuilder, HWConfig
from .hw_space import HWSpace
from .intrinsics import ALL_INTRINSICS
from .matching import TensorizeChoice, match, partition_space
from .mobo import mobo
from .nsga2 import nsga2
from .random_search import random_search
from .sw_dse import SearchSpec, SWResult, run_searches
from .sw_primitives import Schedule
from .tst import TensorExpr, parse

__all__ = [
    "ALL_INTRINSICS", "Constraints", "CostReport", "EvalCache", "HWBuilder",
    "HWConfig", "HWSpace", "SWResult", "Schedule", "SearchSpec", "Solution",
    "TensorExpr", "TensorizeChoice", "codesign", "evaluate",
    "evaluate_batch", "evaluate_batch_reports", "match", "mobo", "nsga2",
    "parse", "partition_space", "random_search", "run_searches",
    "separate_design",
]
