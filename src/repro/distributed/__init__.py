"""Distribution substrate: sharding helpers, pipeline parallelism, and
collective utilities over the (pod, data, model) production mesh."""

from .sharding import (batch_specs, cache_shardings, named, param_shardings,
                       prune_specs)

__all__ = ["batch_specs", "cache_shardings", "named", "param_shardings",
           "prune_specs"]
