"""Pipeline parallelism (optional axis, DESIGN.md §6).

GPipe-style microbatched pipeline over a 'stage' mesh axis using shard_map +
collective_permute: stage s holds its own layer slice; microbatches stream
stage-to-stage; the bubble is the classic (S−1)/(M+S−1).  The production
dry-run mesh spends its axes on (pod, data, model); this module exists so the
framework *supports* PP — exercised by tests on a small stage mesh and usable
via a 'stage' axis on real hardware.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(layer_fn, params_stacked, x, *, mesh: Mesh,
                   axis: str = "stage", n_microbatches: int | None = None):
    """Run ``y = layer_fn(stage_params, x)`` through S pipeline stages.

    params_stacked: pytree with leading dim S (one slice per stage), sharded
    over ``axis``; x: (B, ...) batch, split into M microbatches (default S).
    Returns the pipelined output, replicated across stages.
    """
    s = mesh.shape[axis]
    m = n_microbatches or s
    b = x.shape[0]
    assert b % m == 0, (b, m)
    x_mb = x.reshape(m, b // m, *x.shape[1:])

    def stage_body(params, xs):
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        idx = jax.lax.axis_index(axis)
        n_ticks = m + s - 1

        def tick(carry, t):
            inp, outputs = carry
            # stage 0 ingests fresh microbatch t; later stages take the wire
            fresh = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, m - 1), axis=0, keepdims=False)
            cur = jnp.where(idx == 0, fresh, inp)
            y = layer_fn(params, cur)
            # last stage emits microbatch t-(s-1) at tick t
            mb_out = t - (s - 1)
            upd = jax.lax.dynamic_update_index_in_dim(
                outputs, y, jnp.clip(mb_out, 0, m - 1), axis=0)
            emit = (idx == s - 1) & (mb_out >= 0)
            outputs = jnp.where(emit, upd, outputs)
            # stream s -> s+1
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s) for i in range(s)])
            return (nxt, outputs), None

        carry0 = (jnp.zeros_like(xs[0]), jnp.zeros_like(xs))
        (_, outputs), _ = jax.lax.scan(tick, carry0, jnp.arange(n_ticks))
        # only the last stage holds real outputs; replicate via psum
        outputs = jnp.where(idx == s - 1, outputs, jnp.zeros_like(outputs))
        return jax.lax.psum(outputs, axis)

    fn = shard_map(stage_body, mesh=mesh,
                   in_specs=(P(axis), P()), out_specs=P(),
                   check_rep=False)
    out = fn(params_stacked, x_mb)
    return out.reshape(b, *x.shape[1:])
