"""Activation-sharding context (sequence parallelism, DESIGN.md §6).

The residual stream between blocks is what scan saves for the backward pass;
left unconstrained it is replicated over the 'model' axis and dominates HBM
(dry-run probe: deepseek-67b ≈ 100 GB/device).  Constraining it to
P((pod, data), 'model', None) — sequence-sharded over TP — makes GSPMD insert
the classic SP all-gather/reduce-scatter pairs and cuts saved activations by
the TP degree.

Model code calls ``constrain_activations(x)``; launchers opt in via
``set_activation_spec``.  Smoke tests (1-device mesh) leave it unset.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_SPEC: P | None = None
_AXES: tuple[str, ...] | None = None


def set_activation_spec(spec: P | None, mesh=None) -> None:
    """Install the residual-stream constraint; with ``mesh`` given, axes the
    mesh does not have are pruned (single-pod meshes lack 'pod')."""
    global _SPEC, _AXES
    if mesh is not None:
        _AXES = tuple(mesh.axis_names)
    if spec is None:
        _AXES = None
    elif mesh is not None:
        from .sharding import prune_specs
        spec = prune_specs(spec, mesh)
    _SPEC = spec


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """Generic pruned sharding constraint for internal activations (MoE
    dispatch buffers etc.).  No-op unless a launcher enabled sharding."""
    if _AXES is None:
        return x
    from .sharding import prune_specs
    return jax.lax.with_sharding_constraint(x, prune_specs(spec, _mesh_like()))


class _mesh_like:
    """Duck-typed mesh stand-in carrying only axis_names for prune_specs."""

    @property
    def axis_names(self):
        return _AXES


def get_activation_spec() -> P | None:
    return _SPEC


def constrain_activations(x: jax.Array) -> jax.Array:
    """Apply the context spec to a (B, S, D) residual-stream activation.
    No-op when unset or when the sequence dim cannot shard (decode, S=1)."""
    if _SPEC is None or x.ndim != 3 or x.shape[1] == 1:
        return x
    return jax.lax.with_sharding_constraint(x, _SPEC)


DEFAULT_TRAIN_SPEC = P(("pod", "data"), "model", None)
