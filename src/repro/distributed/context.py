"""Activation-sharding context (sequence parallelism, DESIGN.md §6).

The residual stream between blocks is what scan saves for the backward pass;
left unconstrained it is replicated over the 'model' axis and dominates HBM
(dry-run probe: deepseek-67b ≈ 100 GB/device).  Constraining it to
P((pod, data), 'model', None) — sequence-sharded over TP — makes GSPMD insert
the classic SP all-gather/reduce-scatter pairs and cuts saved activations by
the TP degree.

Model code calls ``constrain_activations(x)``; launchers opt in via
``set_activation_spec`` or the scoped :func:`activation_spec` context
manager.  Smoke tests (1-device mesh) leave it unset, and the test suite's
autouse fixture calls :func:`reset` after every test so one engine enabling
sharding can never leak into the next.

Specs are stored RAW and pruned lazily at apply time against the axes the
active mesh actually has (recorded at install when a ``mesh`` is given,
otherwise discovered from the ambient mesh context).  Pruning only at
install time was a bug: ``set_activation_spec(DEFAULT_TRAIN_SPEC)`` without
a mesh stored a spec naming 'pod', which then crashed on any single-pod
mesh.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P


class _ActivationState:
    """The installed constraint: the raw (unpruned) spec plus the axis names
    of the mesh it was installed with (None = discover lazily)."""

    __slots__ = ("spec", "axes")

    def __init__(self) -> None:
        self.spec: P | None = None
        self.axes: tuple[str, ...] | None = None


_STATE = _ActivationState()


def reset() -> None:
    """Clear the installed spec and axes (test isolation hook)."""
    _STATE.spec = None
    _STATE.axes = None


def set_activation_spec(spec: P | None, mesh=None) -> None:
    """Install the residual-stream constraint.  The spec is stored raw;
    pruning to the mesh's axes happens at apply time (``mesh`` here only
    records which axes exist, sparing the lazy discovery)."""
    _STATE.spec = spec
    _STATE.axes = tuple(mesh.axis_names) if (mesh is not None
                                             and spec is not None) else None


@contextlib.contextmanager
def activation_spec(spec: P | None, mesh=None):
    """Scoped :func:`set_activation_spec`: installs ``spec`` for the body
    and restores whatever was installed before on exit — engines and tests
    use this so enabling sharding cannot pollute the rest of the process."""
    prev = (_STATE.spec, _STATE.axes)
    set_activation_spec(spec, mesh)
    try:
        yield
    finally:
        _STATE.spec, _STATE.axes = prev


def _ambient_axes() -> tuple[str, ...] | None:
    """Axis names of the mesh active right now: the recorded install-time
    axes, else the ambient ``with mesh:`` context (how the launchers trace
    their jitted steps)."""
    if _STATE.axes is not None:
        return _STATE.axes
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        if not mesh.empty:
            return tuple(mesh.axis_names)
    except Exception:
        pass
    return None


def _pruned(spec: P, axes: tuple[str, ...]) -> P:
    from .sharding import _filter_axes
    return P(*(_filter_axes(e, axes) for e in spec))


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """Generic pruned sharding constraint for internal activations (MoE
    dispatch buffers etc.).  No-op unless a launcher enabled sharding with a
    mesh (``set_activation_spec(spec, mesh)``)."""
    if _STATE.axes is None:
        return x
    return jax.lax.with_sharding_constraint(x, _pruned(spec, _STATE.axes))


def get_activation_spec() -> P | None:
    """The spec as it would apply right now (pruned to the known axes)."""
    if _STATE.spec is None:
        return None
    axes = _ambient_axes()
    return _pruned(_STATE.spec, axes) if axes is not None else _STATE.spec


def constrain_activations(x: jax.Array) -> jax.Array:
    """Apply the context spec to a (B, S, D) residual-stream activation.
    No-op when unset or when the sequence dim cannot shard (decode, S=1).
    The spec is pruned here, against the axes of the mesh actually active,
    so a spec installed without a mesh cannot crash a mesh lacking 'pod'."""
    if _STATE.spec is None or x.ndim != 3 or x.shape[1] == 1:
        return x
    spec = _STATE.spec
    axes = _ambient_axes()
    if axes is not None:
        spec = _pruned(spec, axes)
    return jax.lax.with_sharding_constraint(x, spec)


DEFAULT_TRAIN_SPEC = P(("pod", "data"), "model", None)
