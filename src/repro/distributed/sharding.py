"""Sharding rules for the production mesh (DESIGN.md §6).

Model code annotates params/caches with PartitionSpecs over logical axes
('pod', 'data', 'model'); these helpers adapt the specs to whatever mesh the
job actually brings up (e.g. a single-pod mesh has no 'pod' axis; smoke tests
run on a 1-device mesh) and wrap them into NamedShardings.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _filter_axes(entry, axis_names: tuple[str, ...]):
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in axis_names else None
    kept = tuple(a for a in entry if a in axis_names)
    return kept if len(kept) > 1 else (kept[0] if kept else None)


def prune_specs(tree, mesh: Mesh):
    """Drop mesh axes the current mesh does not have from every spec."""
    names = tuple(mesh.axis_names)

    def prune(spec: P) -> P:
        return P(*(_filter_axes(e, names) for e in spec))

    return jax.tree_util.tree_map(prune, tree,
                                  is_leaf=lambda x: isinstance(x, P))


def named(tree, mesh: Mesh):
    """PartitionSpec tree -> NamedSharding tree (pruned to the mesh)."""
    pruned = prune_specs(tree, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pruned,
                                  is_leaf=lambda x: isinstance(x, P))


def param_shardings(model_module, cfg, mesh: Mesh):
    return named(model_module.specs(cfg), mesh)


def zero1_specs(tree):
    """ZeRO-1 parameter specs: drop the 'data' (FSDP) axis from parameters —
    weights become TP-only (replicated over data), while optimizer moments
    keep the original fully-sharded specs.  Trades per-layer weight
    all-gathers for one gradient all-reduce + one post-update param
    all-gather (EXPERIMENTS.md §Perf, qwen3 train hillclimb)."""
    def strip(entry):
        if entry == "data":
            return None
        if isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != "data")
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return entry

    def one(spec: P) -> P:
        return P(*(strip(e) for e in spec))

    return jax.tree_util.tree_map(one, tree,
                                  is_leaf=lambda x: isinstance(x, P))


def cache_shardings(model_module, cfg, mesh: Mesh):
    return named(model_module.cache_specs(cfg), mesh)


def batch_specs(cfg) -> dict[str, P]:
    """Input specs: batch dim over (pod, data)."""
    b = ("pod", "data")
    if cfg.embed_inputs:
        return {"frames": P(b, None, None), "labels": P(b, None)}
    if cfg.vis_tokens:
        return {"tokens": P(b, None), "patches": P(b, None, None),
                "labels": P(b, None)}
    return {"tokens": P(b, None), "labels": P(b, None)}


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
