"""Post-SPMD HLO text analysis for the roofline (DESIGN.md §7).

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (empirically
verified: flops are layer-count-invariant under scan), so totals for scanned
models must be reconstructed.  This parser walks the partitioned HLO text:

  * splits it into computations,
  * counts dot FLOPs (2 · prod(output) · prod(contracting dims)) and
    collective bytes per computation,
  * rolls totals up through ``fusion``/``call``/``while`` edges, multiplying
    while bodies by their ``known_trip_count`` backend config,

yielding per-device HLO_FLOPs (dot-dominated; elementwise ops excluded, noted
in EXPERIMENTS.md) and per-device collective bytes split by op kind.
No jax import — pure text processing, unit-testable on saved HLO.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(.*)$")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    """Total bytes of every shape literal in ``text`` (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


_DEF_RE = re.compile(r"^\s+(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)")


def _dims_of(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    return [int(x) for x in m.group(2).split(",") if x]


def _dot_flops(line: str, symbols: dict[str, str]) -> int:
    """2 · prod(output dims) · prod(lhs contracting dims).  Operand shapes
    are resolved through the computation's symbol table (this HLO print mode
    shows operand *names* only)."""
    head, _, tail = line.partition(" dot(")
    out_n = 1
    for d in _dims_of(head.split("=", 1)[-1]):
        out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", tail)
    lhs_name = tail.split(",")[0].strip().rstrip(")")
    lhs_dims = _dims_of(symbols.get(lhs_name, ""))
    contract = 1
    if m and lhs_dims:
        for idx in (int(x) for x in m.group(1).split(",") if x):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2 * out_n * contract


@dataclass
class Computation:
    name: str
    dot_flops: int = 0
    conv_flops: int = 0
    collective_bytes: dict[str, float] = field(default_factory=dict)
    # (callee, multiplier) edges: fusions/calls x1, whiles x trip_count
    edges: list[tuple[str, int]] = field(default_factory=list)


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    symbols: dict[str, str] = {}
    for line in hlo.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                symbols = {}
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(line)
        if d:
            symbols[d.group(1)] = d.group(2)
        op = _OP_RE.match(line)
        if not op:
            continue
        body = op.group(1)
        if " dot(" in body:
            cur.dot_flops += _dot_flops(line, symbols)
        elif " convolution(" in body:
            # flops ~ 2 * prod(out) * (in_ch * window) — rare in our models;
            # approximate with 2*prod(out shape) * contraction from operands
            cur.conv_flops += 2 * _shape_bytes(body.split(" convolution(")[0])
        elif " while(" in body:
            callee = _CALLS_RE.search(body)
            trip = _TRIP_RE.search(body)
            if callee:
                cur.edges.append((callee.group(1),
                                  int(trip.group(1)) if trip else 1))
        else:
            for kind in COLLECTIVES:
                if f" {kind}(" in body or f" {kind}-start(" in body:
                    out_bytes = _shape_bytes(body.split(f" {kind}")[0])
                    g = _GROUPS_RE.search(body)
                    group = int(g.group(2)) if g else 0
                    key = kind
                    cur.collective_bytes[key] = cur.collective_bytes.get(
                        key, 0.0) + out_bytes
                    cur.collective_bytes[key + ":group"] = max(
                        cur.collective_bytes.get(key + ":group", 0), group)
                    break
            else:
                if " fusion(" in body or " call(" in body:
                    callee = _CALLS_RE.search(body)
                    if callee:
                        cur.edges.append((callee.group(1), 1))
    return comps


@dataclass
class HLOReport:
    dot_flops: float
    collective_bytes: dict[str, float]      # per kind, raw output bytes
    group_sizes: dict[str, int]
    n_collectives: dict[str, int]

    def wire_bytes(self) -> float:
        """ICI wire traffic per device: ring-model multipliers —
        all-reduce 2·(g−1)/g · size; all-gather/reduce-scatter (g−1)/g of
        full buffer (output/input resp., both = parsed size here for AG;
        RS parsed size is the small output → ×(g−1)); others 1×."""
        total = 0.0
        for kind, size in self.collective_bytes.items():
            if kind.endswith(":group"):
                continue
            g = max(2, self.group_sizes.get(kind, 2))
            if kind == "all-reduce":
                total += 2.0 * size * (g - 1) / g
            elif kind == "all-gather":
                total += size * (g - 1) / g
            elif kind == "reduce-scatter":
                total += size * (g - 1)
            else:
                total += size
        return total


def entry_name(comps: dict[str, Computation], hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fall back: a computation never referenced by others
    referenced = {c for comp in comps.values() for c, _ in comp.edges}
    for name in comps:
        if name not in referenced and "main" in name:
            return name
    return max(comps, key=lambda n: len(comps[n].edges))


def analyze(hlo: str) -> HLOReport:
    comps = parse_computations(hlo)
    root = entry_name(comps, hlo)

    memo: dict[str, tuple[float, dict[str, float], dict[str, int]]] = {}

    def roll(name: str, stack=()) -> tuple[float, dict[str, float], dict[str, int]]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, {}, {})
        c = comps[name]
        flops = float(c.dot_flops)
        coll: dict[str, float] = {}
        counts: dict[str, int] = {}
        for k, v in c.collective_bytes.items():
            if k.endswith(":group"):
                continue
            coll[k] = coll.get(k, 0.0) + v
            counts[k] = counts.get(k, 0) + 1
        for callee, mult in c.edges:
            f2, c2, n2 = roll(callee, stack + (name,))
            flops += mult * f2
            for k, v in c2.items():
                coll[k] = coll.get(k, 0.0) + mult * v
            for k, v in n2.items():
                counts[k] = counts.get(k, 0) + mult * v
        memo[name] = (flops, coll, counts)
        return memo[name]

    flops, coll, counts = roll(root)
    groups = {}
    for c in comps.values():
        for k, v in c.collective_bytes.items():
            if k.endswith(":group"):
                groups[k[:-6]] = max(groups.get(k[:-6], 0), int(v))
    return HLOReport(flops, coll, groups, counts)
