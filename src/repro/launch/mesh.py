"""Production meshes (DESIGN.md §6).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state.  The dry-run launches with
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` (set in dryrun.py
*before any jax import*); everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers, as a ('data','model') mesh — used by smoke
    tests and the CPU example drivers."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def describe(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
