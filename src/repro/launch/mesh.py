"""Production meshes (DESIGN.md §6).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state.  The dry-run launches with
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` (set in dryrun.py
*before any jax import*); everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, tp: int = 1):
    """Whatever this host offers, as a ('data','model') mesh — used by smoke
    tests and the CPU example drivers.  ``tp`` sets the model-axis size
    (tensor parallelism); it must divide the host device count, the rest
    becomes the data axis."""
    n = len(jax.devices())
    if tp < 1 or n % tp:
        raise ValueError(f"tp={tp} must be >= 1 and divide the host device "
                         f"count ({n})")
    return jax.make_mesh((n // tp, tp), ("data", "model"))


def describe(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
