"""Roofline analysis (deliverable (g), DESIGN.md §7): reads dry-run artifacts
and derives the three roofline terms per (arch × shape × mesh).

  compute term    = HLO_dot_FLOPs/dev ÷ peak_FLOP/s          (197 TF bf16)
  memory term     = HBM bytes/dev     ÷ HBM bw               (819 GB/s)
  collective term = ICI wire bytes/dev ÷ 2·link_bw           (50 GB/s/link,
                    bidirectional ring on the sharded axis)

Sources: HLO_dot_FLOPs and wire bytes come from the while-trip-aware HLO
parse (hlo_analysis.py) — XLA's cost_analysis counts scan bodies once and is
reported only as a cross-check.  HBM bytes are analytic (params + optimizer
+ saved activations + KV/state cache traffic per step) because no compiled
source survives scan-once counting; the formula per cell kind is printed with
the table.  MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N_active·B
(decode, + KV attention reads).

Run:  python -m repro.launch.roofline [--emit artifacts/roofline.md]
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link
ICI_BW = 2 * LINK_BW         # bidirectional ring on the sharded axis

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def model_flops(cfg, shape) -> float:
    """Global mathematically-useful FLOPs for one step (MODEL_FLOPS)."""
    n_active = cfg.params_active()
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.head_dim
    if shape.kind == "train":
        tokens = b * s
        attn = 12 * cfg.n_layers * b * s * s * cfg.n_heads * hd \
            if cfg.n_heads else 0
        if cfg.family == "zamba2":
            attn = attn // max(1, cfg.attn_every)
        return 6.0 * n_active * tokens + attn
    if shape.kind == "prefill":
        tokens = b * s
        attn = 4 * cfg.n_layers * b * s * s * cfg.n_heads * hd \
            if cfg.n_heads else 0
        if cfg.family == "zamba2":
            attn = attn // max(1, cfg.attn_every)
        return 2.0 * n_active * tokens + attn
    # decode: one token per sequence
    attn_layers = cfg.n_layers if cfg.family not in ("rwkv6", "zamba2") else \
        (cfg.n_layers // max(1, cfg.attn_every) if cfg.family == "zamba2" else 0)
    kv_flops = 4.0 * b * s * attn_layers * cfg.n_kv_heads * hd \
        if cfg.n_kv_heads else 0
    return 2.0 * n_active * b + kv_flops


def hbm_bytes_per_dev(cfg, shape, n_dev: int, record: dict) -> float:
    """Analytic per-device HBM traffic for one step (formula in module doc)."""
    p_bytes = cfg.params_dense() * 2 / n_dev          # bf16, sharded
    arg = record["memory"]["argument_bytes"]          # params(+opt+cache)/dev
    b, s = shape.global_batch, shape.seq_len
    act = b * s * cfg.d_model * 2 / n_dev             # one residual stream
    if shape.kind == "train":
        # fwd read + bwd read + grad write + opt m/v read+write (in arg)
        return 3 * p_bytes + 2 * (arg - p_bytes) + 2 * cfg.n_layers * act
    if shape.kind == "prefill":
        return p_bytes + 2 * cfg.n_layers * act
    # decode: stream all (active) weights once + read the KV/state cache
    active = p_bytes * cfg.params_active() / max(1, cfg.params_dense())
    return active + (arg - p_bytes)


@dataclass
class Row:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_dev: float
    hlo_flops_dev: float
    temp_gib: float
    arg_gib: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_dev / self.hlo_flops_dev \
            if self.hlo_flops_dev else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute term / max(all terms): 1.0 = compute-bound at peak."""
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return self.compute_s / bound if bound else 0.0


def build_row(record: dict) -> Row:
    from repro.configs import get_config
    from repro.models.config import SHAPES

    cfg = get_config(record["arch"])
    shape = SHAPES[record["shape"]]
    n_dev = record["n_devices"]
    hlo_flops = record["hlo"]["dot_flops"]
    wire = record["hlo"]["wire_bytes"]
    mem_bytes = hbm_bytes_per_dev(cfg, shape, n_dev, record)
    return Row(
        arch=record["arch"], shape=record["shape"], mesh=record["mesh"],
        compute_s=hlo_flops / PEAK_FLOPS,
        memory_s=mem_bytes / HBM_BW,
        collective_s=wire / ICI_BW,
        model_flops_dev=model_flops(cfg, shape) / n_dev,
        hlo_flops_dev=hlo_flops,
        temp_gib=record["memory"]["temp_bytes"] / 2**30,
        arg_gib=record["memory"]["argument_bytes"] / 2**30,
    )


def suggestion(row: Row) -> str:
    if row.dominant == "collective":
        return ("reduce wire bytes: coarser EP/TP collectives, bf16 reduce, "
                "or re-shard the hot einsum")
    if row.dominant == "memory":
        if row.shape.startswith("decode") or row.shape.startswith("long"):
            return ("decode is weight/cache streaming-bound: quantize KV, "
                    "raise per-step batch, or multi-token decode")
        return "cut re-fetch: fuse, larger per-step compute, better remat"
    if row.useful_ratio < 0.5:
        return ("compute-bound but <50% useful: shrink remat recompute / "
                "head padding waste")
    return "compute-bound: push MXU utilization (block shapes, bf16 paths)"


def load_rows(variant: str = "baseline") -> list[Row]:
    rows = []
    for path in sorted(ART_DIR.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("variant", "baseline") != variant:
            continue
        rows.append(build_row(rec))
    return rows


def markdown(rows: list[Row], single_pod_only: bool = True) -> str:
    from repro.configs import all_cells, cell_skip_reason

    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops | roofline frac | temp GiB |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    seen = set()
    for r in sorted(rows, key=lambda r: (r.arch, r.shape, r.mesh)):
        if single_pod_only and "pod" in r.mesh:
            continue
        seen.add((r.arch, r.shape))
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | **{r.dominant}** | "
            f"{r.useful_ratio:.2f} | {r.roofline_fraction:.2f} | "
            f"{r.temp_gib:.1f} |")
    out.append("")
    out.append("Skipped cells (DESIGN.md §5):")
    for a, s in all_cells():
        reason = cell_skip_reason(a, s)
        if reason:
            out.append(f"- {a} × {s}: {reason}")
        elif (a, s) not in seen:
            out.append(f"- {a} × {s}: (no dry-run artifact found)")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit", default=str(ART_DIR.parent / "roofline.md"))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--all-meshes", action="store_true")
    args = ap.parse_args()
    rows = load_rows(args.variant)
    md = markdown(rows, single_pod_only=not args.all_meshes)
    Path(args.emit).parent.mkdir(parents=True, exist_ok=True)
    Path(args.emit).write_text(md)
    print(md)
    print()
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        if "pod" not in r.mesh:
            print(f"{r.arch} x {r.shape}: {r.dominant}-bound -> "
                  f"{suggestion(r)}")


if __name__ == "__main__":
    main()
