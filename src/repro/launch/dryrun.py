import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (the (e) deliverable, DESIGN.md §6/§7).

For every (architecture × input-shape) cell, ``lower().compile()`` the
appropriate step function on the single-pod 16×16 mesh AND the 2×16×16
multi-pod mesh, proving the distribution config is coherent: shardings
resolve, collectives lower, and the per-device memory fits.  Records per
cell: memory_analysis, cost_analysis aggregates, and HLO-derived dot-FLOPs /
collective bytes (repro.launch.hlo_analysis — while-trip-aware, since XLA's
own cost analysis counts scan bodies once).

The two env lines above MUST stay first — jax locks the device count on
first init.  Nothing outside this launcher sees 512 devices.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all          # every runnable cell, both meshes
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, cell_skip_reason, get_config,
                           runnable_cells)
from repro.distributed.context import DEFAULT_TRAIN_SPEC, set_activation_spec
from repro.distributed.sharding import batch_specs, named, prune_specs
from repro.launch import hlo_analysis
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models import family_module
from repro.models.config import SHAPES, ModelConfig, ShapeCell
from repro.optim import AdamW

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def input_structs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    if cfg.embed_inputs:   # hubert: precomputed frame embeddings (stub)
        d = {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)}
    elif cfg.vis_tokens:   # internvl2: patch-embedding prefix (stub)
        st = s - cfg.vis_tokens
        d = {"tokens": jax.ShapeDtypeStruct((b, st), jnp.int32),
             "patches": jax.ShapeDtypeStruct((b, cfg.vis_tokens, cfg.d_model),
                                             f32)}
    else:
        d = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "train":
        lbl = s - cfg.vis_tokens if cfg.vis_tokens else s
        d["labels"] = jax.ShapeDtypeStruct((b, lbl), jnp.int32)
    return d


def _batch_axes_for(batch: int, mesh) -> tuple[str, ...]:
    """Shard the batch over mesh axes whose product divides it."""
    axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes)


def _puredp_specs(tree):
    """Map TP specs to pure-FSDP: 'model' joins the FSDP ('data') axis on the
    weight dim; nothing is tensor-parallel."""
    from jax.sharding import PartitionSpec as P

    def entry(e):
        if e == "model":
            return None
        if e == "data":
            return ("data", "model")
        if isinstance(e, tuple):
            out = []
            for a in e:
                if a == "model":
                    continue
                out.append(a)
            if "data" in out:
                out.append("model")
            return tuple(out) if len(out) > 1 else (out[0] if out else None)
        return e

    def one(spec: P) -> P:
        return P(*(entry(e) for e in spec))

    return jax.tree_util.tree_map(one, tree,
                                  is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ModelConfig, shape: ShapeCell, mesh, impl: str = "xla",
               mode: str = "fsdp"):
    """Returns (fn, example_args, in_shardings, out_shardings, donate).
    mode: 'fsdp'   — weights sharded over data+model, TP over model (baseline)
          'zero1'  — weights TP-only, optimizer moments data-sharded
          'puredp' — no TP at all: tp=1 (exact configs, no head padding),
                     weights/moments FSDP over data×model, batch over the
                     whole mesh.  The qwen3 hillclimb winner for mid-size
                     models (EXPERIMENTS.md §Perf)."""
    from jax.sharding import PartitionSpec as P

    tp = 1 if mode == "puredp" else mesh.shape["model"]
    mod = family_module(cfg)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(functools.partial(mod.init, cfg, tp=tp), key)
    pspecs = mod.specs(cfg)
    if mode == "zero1":
        from repro.distributed.sharding import zero1_specs
        p_sh = named(zero1_specs(pspecs), mesh)
    elif mode == "puredp":
        pspecs = _puredp_specs(pspecs)
        p_sh = named(pspecs, mesh)
    else:
        p_sh = named(pspecs, mesh)

    baxes = _batch_axes_for(shape.global_batch, mesh)
    if mode == "puredp":
        if shape.global_batch % mesh.size == 0:
            baxes = tuple(mesh.axis_names)
        else:
            baxes = baxes  # fall back: divisibility decides
    bspecs = {k: P(baxes, *list(v)[1:]) for k, v in batch_specs(cfg).items()}
    batch = input_structs(cfg, shape)
    b_sh = named({k: bspecs[k] for k in batch}, mesh)

    if shape.kind == "train":
        opt = AdamW()
        opt_state = jax.eval_shape(opt.init, params)
        o_sh = named(opt.init_specs(pspecs), mesh)  # moments stay sharded
        fn = make_train_step(cfg, opt, tp=tp, impl=impl)
        return (fn, (params, opt_state, batch), (p_sh, o_sh, b_sh),
                (p_sh, o_sh, None), (0, 1))

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, tp=tp, impl=impl)
        return fn, (params, batch), (p_sh, b_sh), None, ()

    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(functools.partial(
        mod.init_cache, cfg, shape.global_batch, shape.seq_len, tp))
    c_specs = prune_specs(mod.cache_specs(cfg), mesh)
    # respect the batch divisibility rule on cache batch dims too
    c_specs = jax.tree_util.tree_map(
        lambda sp: P(*[(baxes if e in (("pod", "data"), "data") else e)
                       for e in sp]), c_specs,
        is_leaf=lambda x: isinstance(x, P))
    c_sh = named(c_specs, mesh)
    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_sh = named(P(baxes, None), mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    fn = make_decode_step(cfg, tp=tp, impl=impl)
    return (fn, (params, cache, toks, pos),
            (p_sh, c_sh, t_sh, None), (None, c_sh), (1,))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               layers: int | None = None, save_hlo: bool = False,
               impl: str = "xla", variant: str = "",
               mode: str = "fsdp") -> dict:
    cfg = get_config(arch)
    if layers:
        cfg = dataclasses.replace(cfg, n_layers=layers)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    if mode == "puredp":
        from jax.sharding import PartitionSpec as P
        set_activation_spec(P(("pod", "data", "model"), None, None), mesh)
    else:
        set_activation_spec(DEFAULT_TRAIN_SPEC, mesh)
    t0 = time.time()
    fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh, impl, mode)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    set_activation_spec(None)

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax < 0.5 returns [dict] per device
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    rep = hlo_analysis.analyze(hlo)
    n_dev = mesh.size

    record = {
        "arch": arch, "shape": shape_name, "mesh": describe(mesh),
        "n_devices": n_dev, "kind": shape.kind,
        "n_layers": cfg.n_layers, "variant": variant or "baseline",
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "cost_analysis": {
            "flops_scan_once": ca.get("flops", 0.0),
            "bytes_accessed_scan_once": ca.get("bytes accessed", 0.0),
        },
        "hlo": {
            "dot_flops": rep.dot_flops,
            "collective_bytes": rep.collective_bytes,
            "collective_counts": rep.n_collectives,
            "group_sizes": rep.group_sizes,
            "wire_bytes": rep.wire_bytes(),
            "text_bytes": len(hlo),
        },
    }
    if save_hlo:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}_{shape_name}_{describe(mesh)}{variant}"
        (ART_DIR / f"{tag}.hlo").write_text(hlo)
    return record


def save_record(record: dict) -> Path:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    tag = (f"{record['arch']}_{record['shape']}_{record['mesh']}"
           + ("" if record["variant"] == "baseline"
              else f"_{record['variant']}"))
    path = ART_DIR / f"{tag}.json"
    path.write_text(json.dumps(record, indent=1))
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every runnable cell on both meshes")
    ap.add_argument("--layers", type=int, default=None,
                    help="override n_layers (roofline extrapolation probes)")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="", help="tag for perf experiments")
    ap.add_argument("--mode", default="fsdp", choices=("fsdp", "zero1", "puredp"),
                    help="train-cell weight sharding strategy")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s, m) for a, s in runnable_cells()
                 for m in (False, True)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        reason = cell_skip_reason(args.arch, args.shape)
        if reason:
            print(f"SKIP {args.arch} x {args.shape}: {reason}")
            return
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, multi in cells:
        tag = f"{arch} x {shape} x {'2x16x16' if multi else '16x16'}"
        try:
            rec = lower_cell(arch, shape, multi_pod=multi,
                             layers=args.layers, save_hlo=args.save_hlo,
                             variant=args.variant, mode=args.mode)
            path = save_record(rec)
            m = rec["memory"]
            print(f"OK   {tag}: compile {rec['compile_s']}s  "
                  f"arg {m['argument_bytes']/2**30:.2f}GiB  "
                  f"temp {m['temp_bytes']/2**30:.2f}GiB  "
                  f"dotF {rec['hlo']['dot_flops']:.3e}  "
                  f"wire {rec['hlo']['wire_bytes']:.3e}B -> {path.name}")
        except Exception as e:  # a failure here is a bug in our system
            failures += 1
            print(f"FAIL {tag}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=3)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
