"""Continuous-batching serving engine (deliverable (b); DESIGN.md §11).

Requests arrive with prompts of different lengths; an FCFS scheduler packs
them into a fixed number of decode *slots*.  Admission runs one batched
prefill over the whole prompt — a single causal forward whose K/V (or
recurrent state) is scattered into that slot alone — and every decode step
advances all active slots at once, each at its own absolute position.

Invariant (the per-slot position contract): slot ``s`` holds a request whose
next token will be written at ``pos[s]``; its cache rows ``< pos[s]`` (or
its recurrent state) describe exactly its own prompt + generated prefix and
nothing else.  Admission re-establishes the invariant by *replacing* the
whole slot slice (prefill scatter == KV/state reset), so a retired tenant's
leftovers can never leak into the next request.

The paged engine (:class:`PagedServeEngine`, DESIGN.md §12) keeps the same
per-slot position contract but virtualizes the KV rows themselves: full-length
attention KV lives in one physical pool of fixed-size pages, a per-slot page
table (``row_map``) supplies the slot → row indirection, admission is gated on
free *pages* rather than free slots, prefill is chunked and interleaved with
decode, and low-priority requests are preempted (swapped out to host memory,
bit-exactly) under page pressure.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 6 --max-new 8 [--paged --page-size 8 --pages 24]
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import named, param_shardings, tp_size
from repro.ft import ProgressWatchdog, inject
from repro.ft.inject import InjectedFault
from repro.launch.mesh import describe, make_host_mesh
from repro.launch.paging import PageAllocator, PriorityScheduler
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import family_module, reduced

#: Terminal request statuses (DESIGN.md §14).  Every submitted request ends
#: in exactly one of these; ``PENDING`` is the only non-terminal state.
TERMINAL_STATUSES = ("OK", "CANCELLED", "EXPIRED", "REJECTED", "FAILED")


class EngineStalledError(RuntimeError):
    """``run()`` made no progress for ``stall_limit`` consecutive engine
    steps — fail-stop with a diagnosable snapshot instead of an infinite
    loop (``.diagnostics`` holds queue/slot/page state at the stall)."""

    def __init__(self, msg: str, diagnostics: dict | None = None):
        super().__init__(msg)
        self.diagnostics = diagnostics or {}


@dataclasses.dataclass
class Request:
    """One generation request.  ``next_token`` is a real field (not a
    dynamically attached attribute): −1 until prefill seeds it, then always
    the token the next decode step consumes.  ``priority`` is a small
    non-negative int, 0 = most urgent (paged engine only; the FCFS engine
    ignores it).  ``deadline_s`` is an optional relative deadline (seconds
    from engine submit); the engine stamps ``deadline_at`` and enforces it
    at admission and per step.  ``status`` is ``PENDING`` until the request
    reaches exactly one terminal status (:data:`TERMINAL_STATUSES`)."""

    rid: int
    prompt: np.ndarray
    max_new: int
    max_seq: int | None = None     # per-request context budget (rows of KV)
    priority: int = 0
    deadline_s: float | None = None    # relative deadline, stamped at submit
    next_token: int = -1
    out: list[int] = dataclasses.field(default_factory=list)
    submit_seq: int = -1           # stamped by the scheduler at submit
    preemptions: int = 0
    status: str = "PENDING"
    deadline_at: float | None = None   # absolute, on the engine's clock
    submit_time: float | None = None
    admit_time: float | None = None    # first slot placement (queue exit)
    first_token_time: float | None = None
    finish_time: float | None = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(
                f"request {self.rid}: prompt must be a non-empty 1-D token "
                f"array (zero-length prompts have no logits to seed decode)")
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new must be >= 1")
        if not isinstance(self.priority, (int, np.integer)) \
                or isinstance(self.priority, bool) or self.priority < 0:
            raise ValueError(f"request {self.rid}: priority must be a "
                             f"non-negative int, got {self.priority!r}")
        self.priority = int(self.priority)

    @property
    def queue_latency(self) -> float | None:
        """Wall-clock submit → first token, None until the first token."""
        if self.submit_time is None or self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time


# -- request-lifecycle telemetry (DESIGN.md §13) ----------------------------
# Each helper is a single obs.state() read when tracing is disabled: args
# dicts and metric lookups only happen behind the `st is not None` guard
# (the decode hot path's zero-allocation contract, gated by bench_obs).


def _obs_submit(req: Request) -> None:
    if req.submit_time is None:
        req.submit_time = time.time()
    st = obs.state()
    if st is not None:
        st.tracer.instant("req.submit", {"rid": req.rid,
                                         "prompt": len(req.prompt),
                                         "priority": req.priority})
        st.metrics.counter("serve.submitted").inc()


def _obs_admit(req: Request, slot: int, resumed: bool = False) -> None:
    first = req.admit_time is None
    if first:
        req.admit_time = time.time()
    st = obs.state()
    if st is not None:
        st.tracer.instant("req.resume" if resumed else "req.admit",
                          {"rid": req.rid, "slot": slot})
        if resumed:
            st.metrics.counter("serve.resumes").inc()
        if first and req.submit_time is not None:
            st.metrics.histogram("serve.queue_wait_s").observe(
                req.admit_time - req.submit_time)


def _obs_first_token(req: Request) -> None:
    if req.first_token_time is not None:
        return
    req.first_token_time = time.time()
    st = obs.state()
    if st is not None:
        st.tracer.instant("req.first_token", {"rid": req.rid})
        if req.submit_time is not None:
            st.metrics.histogram("serve.ttft_s").observe(
                req.first_token_time - req.submit_time)


def _obs_finish(req: Request) -> None:
    if req.status != "PENDING":   # terminal transition is exactly-once
        return
    req.status = "OK"
    req.finish_time = time.time()
    st = obs.state()
    if st is not None:
        st.tracer.instant("req.retire", {"rid": req.rid,
                                         "tokens": len(req.out)})
        st.metrics.counter("serve.retired").inc()
        if req.submit_time is not None:
            st.metrics.histogram("serve.e2e_s").observe(
                req.finish_time - req.submit_time)


def _obs_degrade(req: Request, status: str, detail: str = "") -> bool:
    """Exactly-once degraded terminal transition (CANCELLED / EXPIRED /
    REJECTED / FAILED); False (and no telemetry) if ``req`` is already
    terminal — the guarantee the chaos suite asserts per request."""
    if req.status != "PENDING":
        return False
    assert status in TERMINAL_STATUSES and status != "OK", status
    req.status = status
    req.finish_time = time.time()
    st = obs.state()
    if st is not None:
        args = {"rid": req.rid, "status": status}
        if detail:
            args["detail"] = detail
        st.tracer.instant("req.degrade", args)
        st.metrics.counter(f"serve.requests_{status.lower()}").inc()
    return True


class FCFSScheduler:
    """First-come-first-served slot scheduler — pure bookkeeping, no model.

    Owns the waiting queue and the slot occupancy table.  The engine asks
    :meth:`admit` which requests enter which slots (lowest free slot first,
    queue order preserved) and calls :meth:`retire` when a request finishes;
    ``max_concurrency`` caps simultaneously active requests (1 == the
    sequential one-request-at-a-time baseline).
    """

    def __init__(self, n_slots: int, max_concurrency: int | None = None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_concurrency = min(max_concurrency or n_slots, n_slots)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * n_slots

    @property
    def active(self) -> dict[int, Request]:
        return {s: r for s, r in enumerate(self.slots) if r is not None}

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def waiting(self) -> list[Request]:
        return list(self.queue)

    def remove(self, req: Request) -> bool:
        """Pull a waiting request out of the queue (cancellation / deadline
        expiry); False if it was not waiting."""
        if req in self.queue:
            self.queue.remove(req)
            return True
        return False

    def admit(self) -> list[tuple[int, Request]]:
        """Assign queued requests to free slots, FCFS, up to the
        concurrency cap.  Returns the new (slot, request) pairs."""
        placed = []
        for slot in range(self.n_slots):
            if not self.queue or self.n_active >= self.max_concurrency:
                break
            if self.slots[slot] is None:
                req = self.queue.popleft()
                self.slots[slot] = req
                placed.append((slot, req))
        return placed

    def retire(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not occupied")
        self.slots[slot] = None
        return req


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg, tp: int, impl: str, max_seq: int):
    """One set of jitted step functions per (config, tp, impl, max_seq) —
    shared by every engine instance (a fresh ``jax.jit`` wrapper per engine
    would carry a fresh compilation cache, recompiling identical programs)."""
    mod = family_module(cfg)
    decode = jax.jit(make_decode_step(cfg, tp=tp, impl=impl))
    prefill = jax.jit(
        make_prefill_step(cfg, tp=tp, impl=impl, cache_len=max_seq))
    axes = mod.cache_slot_axes(cfg)

    def write_slot(cache, slot_cache, slot):
        return jax.tree_util.tree_map(
            lambda c, pc, ax: jax.lax.dynamic_update_index_in_dim(
                c, jax.lax.index_in_dim(pc, 0, ax, keepdims=False),
                slot, ax),
            cache, slot_cache, axes)

    return decode, prefill, jax.jit(write_slot)


def _resolve_mesh_tp(mesh, tp: int) -> int:
    """TP degree of a mesh-hosted engine: the mesh's 'model' axis.  An
    explicit non-default ``tp`` must agree — params were padded with it."""
    mtp = tp_size(mesh)
    if tp not in (1, mtp):
        raise ValueError(f"tp={tp} conflicts with the mesh's model axis "
                         f"({mtp}); the mesh decides the TP degree")
    return mtp


@functools.lru_cache(maxsize=None)
def _mesh_jitted_steps(cfg, tp: int, impl: str, max_seq: int, mesh):
    """Mesh-aware :func:`_jitted_steps`: identical programs, but decode and
    write_slot pin the cache's output sharding so it never silently
    de-shards across steps.  Prefill stays unconstrained — its batch-1
    cache is private and GSPMD lays it out from the sharded params.
    ``mesh`` is hashable, so this shares the same per-key jit caching."""
    decode, prefill, _ = _jitted_steps(cfg, tp, impl, max_seq)
    mod = family_module(cfg)
    c_sh = named(mod.cache_specs(cfg), mesh)
    axes = mod.cache_slot_axes(cfg)

    def write_slot(cache, slot_cache, slot):
        return jax.tree_util.tree_map(
            lambda c, pc, ax: jax.lax.dynamic_update_index_in_dim(
                c, jax.lax.index_in_dim(pc, 0, ax, keepdims=False),
                slot, ax),
            cache, slot_cache, axes)

    mesh_decode = jax.jit(make_decode_step(cfg, tp=tp, impl=impl),
                          out_shardings=(None, c_sh))
    return mesh_decode, prefill, jax.jit(write_slot, out_shardings=c_sh)


class ServeEngine:
    """Per-slot continuous batching around one model + one shared cache.

    Lifecycle per request: ``submit`` → (scheduler) → admission prefill
    (one forward over the prompt; the packed slot cache *replaces* the slot
    slice, resetting any stale KV/state; ``pos[slot]`` := prompt length;
    the prompt's last logits seed ``out[0]``) → batched decode steps (each
    active slot consumes its ``next_token`` at its own ``pos``, emits one
    token, ``pos[slot] += 1``) → retirement when ``len(out) == max_new`` or
    the per-request context budget is exhausted.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_seq: int = 64,
                 tp: int = 1, impl: str = "xla",
                 max_concurrency: int | None = None, mesh=None,
                 clock=time.monotonic, stall_limit: int = 256):
        if cfg.embed_inputs:
            raise ValueError(f"{cfg.name} is encoder-only: no decode loop "
                             f"(DESIGN.md §5)")
        self.cfg, self.params = cfg, params
        self.mod = family_module(cfg)
        self.mesh = mesh
        self.n_slots, self.max_seq = slots, max_seq
        self.scheduler = FCFSScheduler(slots, max_concurrency)
        if mesh is not None:
            tp = _resolve_mesh_tp(mesh, tp)
            self.params = jax.device_put(
                params, param_shardings(self.mod, cfg, mesh))
            self._decode, self._prefill, self._write_slot = \
                _mesh_jitted_steps(cfg, tp, impl, max_seq, mesh)
            self.cache = jax.device_put(
                self.mod.init_cache(cfg, slots, max_seq, tp),
                named(self.mod.cache_specs(cfg), mesh))
        else:
            self._decode, self._prefill, self._write_slot = _jitted_steps(
                cfg, tp, impl, max_seq)
            self.cache = self.mod.init_cache(cfg, slots, max_seq, tp)
        self.pos = np.zeros(slots, np.int64)   # per-slot next write position
        self.clock = clock
        self.stall_limit = stall_limit
        self.terminal: list[Request] = []   # degraded terminals, undrained
        self.decode_steps = 0
        self.prefill_tokens = 0
        self.generated = 0

    # -- request intake ----------------------------------------------------

    def _budget(self, req: Request) -> int:
        return min(self.max_seq, req.max_seq or self.max_seq)

    def submit(self, req: Request) -> bool:
        """Queue ``req``; False when it can never be served (status becomes
        REJECTED and it is reported through ``run()`` like any terminal)."""
        _obs_submit(req)
        if len(req.prompt) >= self._budget(req):
            self._finish_terminal(req, "REJECTED", f"prompt "
                                  f"({len(req.prompt)} tokens) must leave "
                                  f"room under its context budget "
                                  f"{self._budget(req)}")
            return False
        if req.deadline_s is not None:
            req.deadline_at = self.clock() + req.deadline_s
        self.scheduler.submit(req)
        return True

    # -- graceful degradation (DESIGN.md §14) ------------------------------

    def _finish_terminal(self, req: Request, status: str,
                         detail: str = "") -> None:
        _obs_degrade(req, status, detail)
        self.terminal.append(req)

    def cancel(self, rid: int) -> bool:
        """Cancel a waiting or active request; False if ``rid`` is unknown
        or already terminal.  The slot (if any) frees immediately."""
        for req in self.scheduler.waiting():
            if req.rid == rid:
                self.scheduler.remove(req)
                self._finish_terminal(req, "CANCELLED")
                return True
        for slot, req in list(self.scheduler.active.items()):
            if req.rid == rid:
                self.scheduler.retire(slot)
                self._finish_terminal(req, "CANCELLED")
                return True
        return False

    def _purge_expired(self) -> None:
        """Drop every request past its deadline — waiting or active — at
        the top of each step (admission control + per-step enforcement)."""
        now = self.clock()
        for req in self.scheduler.waiting():
            if req.deadline_at is not None and now >= req.deadline_at:
                self.scheduler.remove(req)
                self._finish_terminal(req, "EXPIRED")
        for slot, req in list(self.scheduler.active.items()):
            if req.deadline_at is not None and now >= req.deadline_at:
                self.scheduler.retire(slot)
                self._finish_terminal(req, "EXPIRED")

    # -- the serving loop --------------------------------------------------

    def _admit(self) -> list[Request]:
        """Prefill newly admitted requests into their slots; returns any
        that finish immediately (max_new == 1).

        Known scaling limit: the prefill jit is shape-keyed on the prompt
        length, so each distinct length compiles once per process.  Fine at
        smoke scale; arbitrary production traffic wants length bucketing,
        which needs per-family masking of the pad tail (right-padding feeds
        junk into recurrent state and can wrap ring rows) — not done here.
        """
        finished = []
        for slot, req in self.scheduler.admit():
            _obs_admit(req, slot)
            prompt = jnp.asarray(req.prompt[None, :])
            with obs.span("serve.prefill"):
                logits, slot_cache = self._prefill(self.params, prompt)
                self.cache = self._write_slot(self.cache, slot_cache,
                                              jnp.int32(slot))
            self.pos[slot] = len(req.prompt)
            tok = int(jnp.argmax(logits[0, -1]))
            req.next_token = tok
            req.out.append(tok)
            _obs_first_token(req)
            self.prefill_tokens += len(req.prompt)
            self.generated += 1
            if len(req.out) >= req.max_new:
                _obs_finish(req)
                finished.append(self.scheduler.retire(slot))
        return finished

    def step(self) -> list[Request]:
        """Admit what fits, then run one batched decode step over every
        active slot.  Returns the requests that finished this step."""
        self._purge_expired()
        finished = self._admit()
        active = self.scheduler.active
        if not active:
            return finished
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot, req in active.items():
            toks[slot, 0] = req.next_token
        with obs.span("serve.decode_step"):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(self.pos, jnp.int32))
        self.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for slot, req in active.items():
            tok = int(nxt[slot])
            req.out.append(tok)
            req.next_token = tok
            self.pos[slot] += 1
            self.generated += 1
            if len(req.out) >= req.max_new \
                    or self.pos[slot] >= self._budget(req):
                _obs_finish(req)
                finished.append(self.scheduler.retire(slot))
        st = obs.state()
        if st is not None:
            st.metrics.histogram(
                "serve.decode_batch",
                obs.DEFAULT_COUNT_EDGES).observe(len(active))
        return finished

    def run(self) -> list[Request]:
        """Serve until queue and slots drain.  Returns every submitted
        request in rid order — finished (status OK) and degraded terminals
        alike.  A no-progress stall raises :class:`EngineStalledError`
        instead of looping forever."""
        done: list[Request] = []
        dog = ProgressWatchdog(self.stall_limit)
        while self.scheduler.has_work():
            done.extend(self.step())
            dog.beat((self.generated, self.prefill_tokens,
                      len(done) + len(self.terminal)))
            if dog.stalled:
                raise EngineStalledError(
                    f"no progress in {self.stall_limit} engine steps",
                    diagnostics={
                        "stall_limit": self.stall_limit,
                        "waiting": [r.rid for r in self.scheduler.waiting()],
                        "active": {s: r.rid for s, r in
                                   self.scheduler.active.items()},
                        "generated": self.generated,
                    })
        done.extend(self.terminal)
        self.terminal = []
        return sorted(done, key=lambda r: r.rid)


@functools.lru_cache(maxsize=None)
def _paged_jitted_steps(cfg, tp: int, impl: str):
    """Jitted paged-engine programs per (config, tp, impl), shared across
    engine instances like :func:`_jitted_steps`.  jax.jit additionally keys
    the decode program on the page-table width and the write program on the
    packed prompt length."""
    mod = family_module(cfg)
    decode = jax.jit(make_decode_step(cfg, tp=tp, impl=impl))
    axes = mod.paged_slot_axes(cfg)

    def write_slot(cache, packed, slot, prows):
        """Scatter one finished batch-1 prefill: pool leaves land at the
        slot's page-table rows ``prows``, per-slot leaves replace the slot
        slice wholesale (the KV/state reset of DESIGN.md §11)."""
        def wr(c, pc, ax):
            if ax == "pool":
                rows = jax.lax.index_in_dim(pc, 0, 1, keepdims=False)
                return c.at[:, prows].set(rows.astype(c.dtype), mode="drop")
            return jax.lax.dynamic_update_index_in_dim(
                c, jax.lax.index_in_dim(pc, 0, ax, keepdims=False), slot, ax)
        return jax.tree_util.tree_map(wr, cache, packed, axes)

    return decode, jax.jit(write_slot), axes


@functools.lru_cache(maxsize=None)
def _mesh_paged_jitted_steps(cfg, tp: int, impl: str, mesh):
    """Mesh-aware :func:`_paged_jitted_steps` for the batched-decode and
    commit programs only: both pin the paged cache's output sharding (pool
    kv-heads over 'model', physical rows replicated) so decode steps can
    never de-shard it.  Chunked prefill keeps using the plain decode jit —
    its private batch-1 dense cache is a different pytree, laid out by
    GSPMD from the sharded params."""
    mod = family_module(cfg)
    axes = mod.paged_slot_axes(cfg)
    c_sh = named(mod.paged_cache_specs(cfg), mesh)

    def write_slot(cache, packed, slot, prows):
        def wr(c, pc, ax):
            if ax == "pool":
                rows = jax.lax.index_in_dim(pc, 0, 1, keepdims=False)
                return c.at[:, prows].set(rows.astype(c.dtype), mode="drop")
            return jax.lax.dynamic_update_index_in_dim(
                c, jax.lax.index_in_dim(pc, 0, ax, keepdims=False), slot, ax)
        return jax.tree_util.tree_map(wr, cache, packed, axes)

    decode = jax.jit(make_decode_step(cfg, tp=tp, impl=impl),
                     out_shardings=(None, c_sh))
    return decode, jax.jit(write_slot, out_shardings=c_sh)


@dataclasses.dataclass
class _Prefill:
    """An in-flight chunked prefill: a private batch-1 full-length dense
    cache advanced ``prefill_chunk`` tokens per engine step through the same
    decode program (row_map=None -> dense path).  The cache covers the whole
    prompt so every chunk's queries see their exact causal (and sliding-
    window) context; KV only moves into the shared pool at commit."""
    req: Request
    cache: object
    done: int = 0


class PagedServeEngine:
    """Paged continuous batching (DESIGN.md §12).

    KV virtualization: full-length attention KV lives in one physical pool
    of ``n_pages`` pages of ``page_size`` rows; ``row_map[slot, i]`` maps a
    slot's logical row ``i`` to its physical pool row (−1 = unmapped).
    Sliding-window rings and recurrent state stay per-slot (already O(1) in
    request length).  Admission is gated on free pages, prefill is chunked
    and interleaved with decode, and page pressure preempts the least
    deserving active request: its pool rows and per-slot state are swapped
    out to host memory and restored bit-exactly on resume — no recompute, so
    preemption can never change a request's output.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_seq: int = 64,
                 page_size: int = 8, n_pages: int | None = None,
                 prefill_chunk: int = 16, tp: int = 1, impl: str = "xla",
                 max_concurrency: int | None = None, mesh=None,
                 age_steps: int = 32,
                 clock=time.monotonic, stall_limit: int = 256,
                 sanitize: bool = False):
        if cfg.embed_inputs:
            raise ValueError(f"{cfg.name} is encoder-only: no decode loop "
                             f"(DESIGN.md §5)")
        self.cfg, self.params = cfg, params
        self.mod = family_module(cfg)
        self.mesh = mesh
        if mesh is not None:
            tp = _resolve_mesh_tp(mesh, tp)
            self.params = jax.device_put(
                params, param_shardings(self.mod, cfg, mesh))
        self.n_slots, self.max_seq = slots, max_seq
        self.prefill_chunk = max(1, prefill_chunk)
        self._tp = tp
        if n_pages is None:   # default: same KV capacity as the dense engine
            n_pages = -(-max_seq // page_size) * slots
        self.alloc = PageAllocator(n_pages, page_size)
        self.scheduler = PriorityScheduler(slots, max_concurrency, age_steps)
        # chunked prefill always runs the plain decode jit on its private
        # dense cache; batched decode + commit swap in mesh-aware programs
        # (pinned cache shardings) when a mesh hosts the engine
        self._decode, self._write_slot, self._axes = _paged_jitted_steps(
            cfg, tp, impl)
        self._decode_batch = self._decode
        self._has_pool = "pool" in jax.tree_util.tree_leaves(self._axes)
        self.cache = self.mod.init_paged_cache(
            cfg, slots, n_pages * page_size, max_seq, tp)
        if mesh is not None:
            self._decode_batch, self._write_slot = _mesh_paged_jitted_steps(
                cfg, tp, impl, mesh)
            self.cache = jax.device_put(
                self.cache, named(self.mod.paged_cache_specs(cfg), mesh))
        self.row_map = np.full((slots, max_seq), -1, np.int32)
        # pos sentinel max_seq: an idle/prefilling slot's decode-batch lane
        # writes out of range, which the paged scatter drops (DESIGN.md §12)
        self.pos = np.full(slots, max_seq, np.int64)
        self._pages: list[list[int]] = [[] for _ in range(slots)]
        self._prefills: dict[int, _Prefill] = {}
        self._suspended: dict[int, tuple[int, object]] = {}   # rid -> swap
        self.clock = clock
        self.stall_limit = stall_limit
        # debug mode: re-check the page-table/allocator invariants after
        # every tick (repro.analysis.kv_sanitizer; raises PagedStateError)
        self.sanitize = sanitize
        self.terminal: list[Request] = []   # degraded terminals, undrained
        self.decode_steps = 0
        self.prefill_tokens = 0
        self.generated = 0
        self.preemptions = 0

    # -- request intake ----------------------------------------------------

    def _budget(self, req: Request) -> int:
        return min(self.max_seq, req.max_seq or self.max_seq)

    def submit(self, req: Request) -> bool:
        """Queue ``req``; False when it can never be served (status becomes
        REJECTED and it is reported through ``run()`` like any terminal)."""
        _obs_submit(req)
        if len(req.prompt) >= self._budget(req):
            self._finish_terminal(req, "REJECTED", f"prompt "
                                  f"({len(req.prompt)} tokens) must leave "
                                  f"room under its context budget "
                                  f"{self._budget(req)}")
            return False
        if self._has_pool:
            # a request admitted alone must always fit: its peak row count
            # is bounded by both its budget and prompt + max_new - 1
            peak = min(len(req.prompt) + req.max_new - 1, self._budget(req))
            if self.alloc.pages_for(peak) > self.alloc.n_pages:
                self._finish_terminal(
                    req, "REJECTED",
                    f"needs {self.alloc.pages_for(peak)} pages at peak, "
                    f"pool only has {self.alloc.n_pages}")
                return False
        if req.deadline_s is not None:
            req.deadline_at = self.clock() + req.deadline_s
        self.scheduler.submit(req)
        return True

    # -- graceful degradation (DESIGN.md §14) ------------------------------

    def _finish_terminal(self, req: Request, status: str,
                         detail: str = "") -> None:
        _obs_degrade(req, status, detail)
        self.terminal.append(req)

    def _drop_slot(self, slot: int) -> Request:
        """Tear down an active slot without completing its request: the
        in-flight prefill (if any) is discarded and every page returns to
        the pool — the leak-free guarantee the chaos suite asserts."""
        req = self.scheduler.retire(slot)
        self._prefills.pop(slot, None)
        self._release(slot)
        return req

    def cancel(self, rid: int) -> bool:
        """Cancel a waiting, suspended, or active request; False if ``rid``
        is unknown or already terminal.  Pages free immediately."""
        for req in self.scheduler.waiting():
            if req.rid == rid:
                self.scheduler.remove(req)
                self._suspended.pop(rid, None)   # swapped-out snapshot
                self._finish_terminal(req, "CANCELLED")
                return True
        for slot, req in list(self.scheduler.active.items()):
            if req.rid == rid:
                self._drop_slot(slot)
                self._finish_terminal(req, "CANCELLED")
                return True
        return False

    def _purge_expired(self) -> None:
        """Drop every request past its deadline — waiting, suspended, or
        active — at the top of each step (admission control + per-step
        enforcement)."""
        now = self.clock()
        for req in self.scheduler.waiting():
            if req.deadline_at is not None and now >= req.deadline_at:
                self.scheduler.remove(req)
                self._suspended.pop(req.rid, None)
                self._finish_terminal(req, "EXPIRED")
        for slot, req in list(self.scheduler.active.items()):
            if req.deadline_at is not None and now >= req.deadline_at:
                self._drop_slot(slot)
                self._finish_terminal(req, "EXPIRED")

    # -- paging ------------------------------------------------------------

    def _need_pages(self, req: Request) -> int:
        """Free pages required to (re)start ``req`` and take one decode
        step: prompt rows + 1 fresh, suspended rows + 1 on resume."""
        if not self._has_pool:
            return 0
        rows = self._suspended[req.rid][0] if req.rid in self._suspended \
            else len(req.prompt)
        return self.alloc.pages_for(min(rows + 1, self._budget(req)))

    def _release(self, slot: int) -> None:
        if self._pages[slot]:
            self.alloc.free(self._pages[slot])
        self._pages[slot] = []
        self.row_map[slot, :] = -1
        self.pos[slot] = self.max_seq

    def _map_pages(self, slot: int, pages: list[int]) -> None:
        """Append ``pages`` to the slot's table, mapping their rows."""
        ps = self.alloc.page_size
        start = len(self._pages[slot]) * ps
        self._pages[slot].extend(pages)
        stop = min(len(self._pages[slot]) * ps, self.max_seq)
        self.row_map[slot, start:stop] = np.asarray(
            self.alloc.rows(self._pages[slot], stop)[start:], np.int32)

    def _reclaim(self, need: int, challenger: int) -> bool:
        """Preempt strictly less deserving page-holding slots until ``need``
        pages are free; False if no such victim remains."""
        while self.alloc.n_free < need:
            key = self.scheduler.admit_key(challenger)
            cands = [(self.scheduler.admit_key(s), s)
                     for s in list(self.scheduler.active)
                     if s != challenger and self._pages[s]]
            if not cands:
                return False
            vkey, victim = max(cands)
            if vkey <= key:
                return False
            self._preempt(victim)
        return True

    def _grow(self, slot: int) -> bool:
        """Ensure the slot's next write row is mapped, allocating (and under
        pressure reclaiming) pages; False = the slot itself was preempted."""
        if not self._has_pool:
            return True
        ps = self.alloc.page_size
        while len(self._pages[slot]) * ps < self.pos[slot] + 1:
            if self.alloc.n_free < 1 and not self._reclaim(1, slot):
                self._preempt(slot)
                return False
            try:
                pages = self.alloc.alloc(1)
            except MemoryError:
                # injected (or genuine) allocation failure degrades exactly
                # like page pressure: swap out bit-exactly, retry later
                self._preempt(slot)
                return False
            self._map_pages(slot, pages)
        return True

    # -- preemption: swap-out / swap-in (bit-exact, no recompute) ----------

    def _preempt(self, slot: int) -> None:
        req = self.scheduler.slots[slot]
        if slot in self._prefills:
            del self._prefills[slot]     # partial prefill restarts on resume
        else:
            self._swap_out(slot, req)
        self._release(slot)
        self.scheduler.preempt(slot)
        self.preemptions += 1
        st = obs.state()
        if st is not None:
            st.tracer.instant("req.preempt", {"rid": req.rid, "slot": slot})
            st.metrics.counter("serve.preemptions").inc()

    def _swap_out(self, slot: int, req: Request) -> None:
        rows = int(self.pos[slot])
        prows = jnp.asarray(self.row_map[slot, :rows])

        def grab(c, ax):
            if ax == "pool":
                return np.asarray(c[:, prows])
            return np.asarray(
                jax.lax.index_in_dim(c, slot, ax, keepdims=False))

        self._suspended[req.rid] = (
            rows, jax.tree_util.tree_map(grab, self.cache, self._axes))

    def _swap_in(self, slot: int, req: Request) -> None:
        rows, snap = self._suspended[req.rid]
        prows = jnp.zeros((0,), jnp.int32)
        if self._has_pool:
            # allocate BEFORE dropping the host snapshot: an (injected)
            # MemoryError here leaves the suspension intact, so the caller
            # can requeue the request without losing its state
            self._map_pages(slot, self.alloc.alloc(
                self.alloc.pages_for(rows)))
            prows = jnp.asarray(self.row_map[slot, :rows])
        del self._suspended[req.rid]

        def put(c, s, ax):
            if ax == "pool":
                return c.at[:, prows].set(jnp.asarray(s), mode="drop")
            return jax.lax.dynamic_update_index_in_dim(
                c, jnp.asarray(s).astype(c.dtype), slot, ax)

        self.cache = jax.tree_util.tree_map(put, self.cache, snap,
                                            self._axes)
        self.pos[slot] = rows

    # -- the serving loop --------------------------------------------------

    def _start(self, slot: int, req: Request) -> None:
        # preemptions > 0 without a swap snapshot means the request was
        # preempted mid-prefill: the restart is still a resume of its
        # lifecycle, not a fresh admission
        _obs_admit(req, slot,
                   resumed=req.rid in self._suspended or req.preemptions > 0)
        if req.rid in self._suspended:
            self._swap_in(slot, req)
            return
        self.pos[slot] = self.max_seq
        self._prefills[slot] = _Prefill(req, self.mod.init_prefill_cache(
            self.cfg, 1, len(req.prompt), self._tp))

    def _admit_new(self) -> None:
        """Admit waiting requests in (effective priority, submit) order,
        gated on a free slot AND enough free pages; a strictly lower
        effective-priority active request is preempted to make room."""
        while True:
            req = self.scheduler.peek()
            if req is None:
                return
            if self.scheduler.free_slot() is not None \
                    and self.alloc.n_free >= self._need_pages(req):
                slot = self.scheduler.place(req)
                try:
                    self._start(slot, req)
                except MemoryError:
                    # injected page fault while re-admitting: undo the
                    # placement and yield to the next step — retrying
                    # inside this tick could livelock on a rate-based
                    # fault schedule
                    self._release(slot)
                    self._prefills.pop(slot, None)
                    self.scheduler.preempt(slot)
                    self.preemptions += 1
                    return
                continue
            victim = self.scheduler.least_deserving()
            if victim is None or self.scheduler.admit_key(victim)[0] <= \
                    self.scheduler.effective_priority(req):
                return
            self._preempt(victim)

    def _prefill_tick(self, finished: list[Request]) -> None:
        """Advance every in-flight prefill by one chunk; commit finished
        ones into pool pages + slot state."""
        for slot in sorted(self._prefills):
            pf = self._prefills[slot]
            req = pf.req
            try:
                inject.check("serve.prefill")
            except InjectedFault as e:
                # fail-stop for this request alone: the private prefill
                # cache is discarded and the slot torn down, so by the
                # per-slot position contract survivors are bit-identical
                self._drop_slot(slot)
                self._finish_terminal(req, "FAILED", str(e))
                continue
            chunk = min(self.prefill_chunk, len(req.prompt) - pf.done)
            toks = jnp.asarray(req.prompt[None, pf.done:pf.done + chunk])
            with obs.span("serve.prefill_chunk"):
                logits, pf.cache = self._decode(
                    self.params, pf.cache, toks, jnp.asarray([pf.done],
                                                             jnp.int32))
            pf.done += chunk
            self.prefill_tokens += chunk
            st = obs.state()
            if st is not None:
                st.tracer.instant("req.prefill_chunk",
                                  {"rid": req.rid, "done": pf.done,
                                   "of": len(req.prompt)})
                st.metrics.counter("serve.prefill_chunks").inc()
            if pf.done < len(req.prompt):
                continue
            del self._prefills[slot]
            self._commit(slot, req, pf.cache, logits, finished)

    def _commit(self, slot: int, req: Request, pcache, logits,
                finished: list[Request]) -> None:
        """Prefill done: seed the first token, then move the prompt's KV
        into freshly allocated pool pages + the slot's per-slot leaves.

        Pages are secured BEFORE the first token is emitted: nothing is
        committed yet, so a page-pressure failure here must requeue the
        request as a plain prefill restart.  Routing it through
        ``_preempt``/``_swap_out`` instead would snapshot the slot's idle
        ``pos`` sentinel (``max_seq`` rows — more pages than the whole pool
        for small pools, i.e. permanently unadmittable) and the
        already-appended first token would be emitted a second time when
        the prefill reruns.
        """
        n = len(req.prompt)
        # max_new == 1 finishes at commit and never touches the pool
        need = (self.alloc.pages_for(n)
                if self._has_pool and req.max_new > 1 else 0)
        pages: list[int] = []
        if need:
            ok = self.alloc.n_free >= need or self._reclaim(need, slot)
            if ok:
                try:
                    pages = self.alloc.alloc(need)
                except MemoryError:   # injected: degrade like pressure
                    ok = False
            if not ok:
                self._release(slot)
                self.scheduler.preempt(slot)
                self.preemptions += 1
                st = obs.state()
                if st is not None:
                    st.tracer.instant("req.preempt", {"rid": req.rid,
                                                      "slot": slot})
                    st.metrics.counter("serve.preemptions").inc()
                return
        tok = int(jnp.argmax(logits[0, -1]))
        req.next_token = tok
        req.out.append(tok)
        self.generated += 1
        _obs_first_token(req)
        if len(req.out) >= req.max_new:
            _obs_finish(req)
            finished.append(self.scheduler.retire(slot))
            self.pos[slot] = self.max_seq
            return
        if pages:
            self._map_pages(slot, pages)
        prows = jnp.asarray(self.row_map[slot, :n].clip(min=0)
                            if self._has_pool else np.zeros(0, np.int32))
        packed = self.mod.pack_paged_slot(self.cfg, pcache, self.max_seq, n)
        self.cache = self._write_slot(self.cache, packed, jnp.int32(slot),
                                      prows)
        self.pos[slot] = n

    def _decode_tick(self, finished: list[Request]) -> None:
        """One batched decode step over every committed slot, after mapping
        (or reclaiming) the pages under each slot's next write row."""
        # injection site FIRST — nothing is mutated yet, so step() can drop
        # the whole tick as a transient and retry next step
        inject.check("serve.decode")
        order = sorted((s for s in self.scheduler.active
                        if s not in self._prefills),
                       key=self.scheduler.admit_key)
        # _grow may preempt later slots as reclaim victims — skip them
        decoding = [s for s in order
                    if self.scheduler.slots[s] is not None and self._grow(s)]
        if not decoding:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        pos = np.full(self.n_slots, self.max_seq, np.int64)
        for s in decoding:
            toks[s, 0] = self.scheduler.slots[s].next_token
            pos[s] = self.pos[s]
        with obs.span("serve.decode_step"):
            logits, self.cache = self._decode_batch(
                self.params, self.cache, jnp.asarray(toks),
                jnp.asarray(pos, jnp.int32), jnp.asarray(self.row_map))
        self.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in decoding:
            req = self.scheduler.slots[s]
            tok = int(nxt[s])
            req.out.append(tok)
            req.next_token = tok
            self.pos[s] += 1
            self.generated += 1
            if len(req.out) >= req.max_new \
                    or self.pos[s] >= self._budget(req):
                _obs_finish(req)
                finished.append(self.scheduler.retire(s))
                self._release(s)
        st = obs.state()
        if st is not None:
            st.metrics.histogram(
                "serve.decode_batch",
                obs.DEFAULT_COUNT_EDGES).observe(len(decoding))

    def step(self) -> list[Request]:
        """One engine tick: admissions, one prefill chunk per prefilling
        slot, one batched decode step.  Returns requests finished now."""
        self.scheduler.tick()
        finished: list[Request] = []
        with obs.span("serve.step"):
            self._purge_expired()
            with obs.span("serve.admit"):
                self._admit_new()
            with obs.span("serve.prefill_tick"):
                self._prefill_tick(finished)
            with obs.span("serve.decode_tick"):
                try:
                    self._decode_tick(finished)
                except InjectedFault:
                    # transient tick fault: the injection site is the
                    # tick's first statement, so nothing was mutated —
                    # drop the tick; a persistent schedule turns into a
                    # stall, which run()'s watchdog converts to fail-stop
                    st = obs.state()
                    if st is not None:
                        st.metrics.counter("serve.tick_faults").inc()
        st = obs.state()
        if st is not None:
            m = st.metrics
            m.gauge("serve.pages_free").set(self.alloc.n_free)
            m.gauge("serve.slots_active").set(self.scheduler.n_active)
            m.gauge("serve.waiting").set(self.scheduler.n_waiting)
        if self.sanitize:
            from repro.analysis.kv_sanitizer import assert_engine
            assert_engine(self, site=f"tick{self.decode_steps}")
        return finished

    def run(self) -> list[Request]:
        """Serve until queue and slots drain.  Returns every submitted
        request in rid order — finished (status OK) and degraded terminals
        alike.  A no-progress stall (e.g. a persistent fault schedule, or
        the preemption livelock §12 guards against) raises
        :class:`EngineStalledError` instead of looping forever."""
        done: list[Request] = []
        dog = ProgressWatchdog(self.stall_limit)
        while self.scheduler.has_work():
            done.extend(self.step())
            # progress = tokens moved or a request reaching a terminal
            # status; preemption counts are deliberately excluded (they
            # keep incrementing during a livelock)
            dog.beat((self.generated, self.prefill_tokens,
                      len(done) + len(self.terminal)))
            if dog.stalled:
                raise EngineStalledError(
                    f"no progress in {self.stall_limit} engine steps",
                    diagnostics={
                        "stall_limit": self.stall_limit,
                        "waiting": [r.rid for r in self.scheduler.waiting()],
                        "active": {s: r.rid for s, r in
                                   self.scheduler.active.items()},
                        "prefills": sorted(self._prefills),
                        "suspended": sorted(self._suspended),
                        "pages_free": self.alloc.n_free,
                        "preemptions": self.preemptions,
                    })
        done.extend(self.terminal)
        self.terminal = []
        return sorted(done, key=lambda r: r.rid)


def _latency_summary(done: list[Request]) -> dict:
    """Per-run latency summaries through the fixed-bucket histogram
    machinery (DESIGN.md §13), replacing the old ad-hoc per-request
    percentile scans:

      * ``ttft_s``       — submit → first token (the quantity the old
        ``queue_latency`` property reports, kept for compatibility);
      * ``queue_wait_s`` — submit → first slot placement (pure queueing,
        excludes prefill).

    Buckets span the observed range at 1/512 resolution, so the quantile
    interpolation error is negligible against the serving gates."""
    from repro.obs.metrics import Histogram, linear_edges

    def summarize(vals: list[float | None]) -> dict:
        vals = [v for v in vals if v is not None]
        if not vals:
            return {"count": 0, "mean": None,
                    "p50": None, "p95": None, "p99": None}
        lo, hi = min(vals), max(vals)
        if hi <= lo:   # degenerate range: every quantile is the value
            return {"count": len(vals), "mean": lo,
                    "p50": lo, "p95": lo, "p99": lo}
        h = Histogram(linear_edges(lo, hi, 512))
        for v in vals:
            h.observe(v)
        return {"count": h.count, "mean": h.mean, "p50": h.quantile(0.5),
                "p95": h.quantile(0.95), "p99": h.quantile(0.99)}

    return {
        "ttft_s": summarize([r.queue_latency for r in done]),
        "queue_wait_s": summarize(
            [r.admit_time - r.submit_time
             if r.admit_time is not None and r.submit_time is not None
             else None for r in done]),
    }


def serve_requests(cfg, params, requests, *, slots: int = 4,
                   max_seq: int = 64, tp: int = 1, impl: str = "xla",
                   max_concurrency: int | None = None, paged: bool = False,
                   page_size: int = 8, n_pages: int | None = None,
                   prefill_chunk: int = 16, age_steps: int = 32,
                   stall_limit: int = 256, mesh=None, sanitize: bool = False
                   ) -> tuple[list[Request], dict]:
    """Convenience wrapper: submit ``requests``, drain the engine, return
    ``(requests, stats)`` — every submitted request comes back with a
    terminal ``status`` (OK / CANCELLED / EXPIRED / REJECTED / FAILED),
    counted exactly once in ``stats["status_counts"]``.
    ``max_concurrency=1`` is the sequential one-request-at-a-time baseline
    (identical math and shapes, no batching across requests); ``paged=True``
    runs the page-table engine of DESIGN.md §12 instead of the slot-pinned
    one."""
    if paged:
        eng = PagedServeEngine(
            cfg, params, slots=slots, max_seq=max_seq, tp=tp, impl=impl,
            max_concurrency=max_concurrency, page_size=page_size,
            n_pages=n_pages, prefill_chunk=prefill_chunk,
            age_steps=age_steps, stall_limit=stall_limit, mesh=mesh,
            sanitize=sanitize)
    else:
        eng = ServeEngine(cfg, params, slots=slots, max_seq=max_seq, tp=tp,
                          impl=impl, max_concurrency=max_concurrency,
                          stall_limit=stall_limit, mesh=mesh)
    for req in requests:
        eng.submit(req)
    done = eng.run()
    status_counts = collections.Counter(r.status for r in done)
    return done, {"decode_steps": eng.decode_steps,
                  "prefill_tokens": eng.prefill_tokens,
                  "generated": eng.generated,
                  "preemptions": getattr(eng, "preemptions", 0),
                  "status_counts": dict(sorted(status_counts.items())),
                  **_latency_summary(done)}


def make_requests(cfg, n: int, max_new: int, seed: int = 0,
                  lengths: tuple[int, int] = (3, 12), long_every: int = 0,
                  long_lengths: tuple[int, int] = (24, 33),
                  priorities: tuple[int, ...] = (0,),
                  max_new_spread: int = 0) -> list[Request]:
    """Synthetic traffic.  The defaults reproduce the original homogeneous
    stream bit-for-bit; the knobs generate the heterogeneous mixes paging
    and preemption need: ``long_every=k`` makes every k-th request a long
    prompt drawn from ``long_lengths`` (``long_every=11`` is the ROADMAP
    10:1 short/long scenario), ``priorities`` cycles per request, and
    ``max_new_spread=s`` draws max_new from ``[max_new-s, max_new+s]``."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        is_long = long_every and (i % long_every) == long_every - 1
        size = int(rng.integers(*(long_lengths if is_long else lengths)))
        mn = max_new if not max_new_spread else int(rng.integers(
            max(1, max_new - max_new_spread), max_new + max_new_spread + 1))
        reqs.append(Request(i, rng.integers(0, cfg.vocab, size=size)
                            .astype(np.int32), mn,
                            priority=priorities[i % len(priorities)]))
    return reqs


def parse_mesh_flag(spec: str):
    """``--mesh data=1,model=8`` -> a host ('data','model') mesh.  Both axes
    must be named, their product must equal the host device count (widen
    CPU hosts with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    before any jax import)."""
    shape: dict[str, int] = {}
    for part in spec.split(","):
        k, sep, v = part.partition("=")
        if not sep or not v.strip().isdigit():
            raise ValueError(f"--mesh expects axis=size pairs, got {part!r}")
        shape[k.strip()] = int(v)
    if sorted(shape) != ["data", "model"]:
        raise ValueError(f"--mesh must name exactly data= and model=, "
                         f"got {sorted(shape)}")
    n = len(jax.devices())
    if shape["data"] * shape["model"] != n:
        raise ValueError(f"mesh {spec} wants {shape['data'] * shape['model']}"
                         f" devices, host has {n}")
    return make_host_mesh(tp=shape["model"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sequential", action="store_true",
                    help="one-request-at-a-time baseline (max_concurrency=1)")
    ap.add_argument("--paged", action="store_true",
                    help="page-table KV engine (DESIGN.md §12)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="KV rows per page (paged engine)")
    ap.add_argument("--pages", type=int, default=None,
                    help="physical pool size in pages (default: dense-"
                         "equivalent capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens prefetched per engine step (paged)")
    ap.add_argument("--sanitize", action="store_true",
                    help="debug mode: assert the paged page-table/allocator "
                         "invariants after every engine tick "
                         "(repro.analysis.kv_sanitizer)")
    ap.add_argument("--mesh", default=None, metavar="data=D,model=T",
                    help="serve tensor-parallel over a device mesh, e.g. "
                         "data=1,model=8 (product must equal the host "
                         "device count)")
    ap.add_argument("--long-every", type=int, default=0,
                    help="every k-th request gets a long prompt (mixed "
                         "traffic; 0 = homogeneous)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request relative deadline in seconds; "
                         "overdue requests expire gracefully")
    ap.add_argument("--stall-limit", type=int, default=256,
                    help="engine steps without progress before fail-stop")
    ap.add_argument("--inject", action="append", default=[],
                    metavar="SITE=RATE",
                    help="arm a fault-injection site at a seeded failure "
                         "rate, e.g. page.alloc=0.05 (repeatable; "
                         "DESIGN.md §14)")
    ap.add_argument("--inject-at", action="append", default=[],
                    metavar="SITE=I,J",
                    help="inject at exact call indices of a site, e.g. "
                         "serve.decode=3,7 (repeatable)")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for the fault-injection schedules")
    ap.add_argument("--tuning-db", default=None,
                    help="tuning database (tuner/db.py); defaults to "
                         "artifacts/tuning_db.json")
    ap.add_argument("--tuned-app", default=None,
                    help="co-design app whose tuned kernel blocks to "
                         "install (default: the arch name)")
    ap.add_argument("--trace", action="store_true",
                    help="enable the observability layer (DESIGN.md §13) "
                         "and export telemetry + a Perfetto trace")
    ap.add_argument("--telemetry-out", default=None,
                    help="telemetry artifact path (default: "
                         "artifacts/telemetry.json)")
    ap.add_argument("--trace-out", default=None,
                    help="Chrome trace path (default: artifacts/trace.json)")
    args = ap.parse_args()

    if args.trace:
        obs.enable()
    if args.inject or args.inject_at:
        rates = {}
        for spec in args.inject:
            site, _, rate = spec.partition("=")
            rates[site] = float(rate) if rate else 1.0
        at = {}
        for spec in args.inject_at:
            site, _, idxs = spec.partition("=")
            at[site] = [int(x) for x in idxs.split(",") if x]
        inject.arm(seed=args.inject_seed, rates=rates, at=at)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    # measured-autotuning pickup (DESIGN.md §8.4): install the app's tuned
    # block shapes as dispatch defaults; shape-exact DB records still win
    from repro.kernels import ops
    tuned = ops.configure(app=args.tuned_app or args.arch,
                          db_path=args.tuning_db)
    if tuned:
        print(f"tuned kernel blocks installed: gemm={tuned['gemm']}")
    if cfg.embed_inputs:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode loop "
                         f"(DESIGN.md §5) — use launch.train instead")
    if args.mesh:
        try:
            mesh = parse_mesh_flag(args.mesh)
        except ValueError as e:
            raise SystemExit(str(e))
        print(f"mesh: {describe(mesh)}")
    else:
        mesh = None
        make_host_mesh()
    tp = tp_size(mesh) if mesh is not None else 1
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(args.seed), tp=tp)
    requests = make_requests(cfg, args.requests, args.max_new, args.seed,
                             long_every=args.long_every)
    if args.deadline_s is not None:
        for req in requests:
            req.deadline_s = args.deadline_s

    t0 = time.time()
    done, stats = serve_requests(
        cfg, params, requests, slots=args.slots, max_seq=args.max_seq,
        tp=tp, mesh=mesh,
        max_concurrency=1 if args.sequential else None, paged=args.paged,
        page_size=args.page_size, n_pages=args.pages,
        prefill_chunk=args.prefill_chunk, stall_limit=args.stall_limit,
        sanitize=args.sanitize and args.paged)
    dt = time.time() - t0
    for req in done:
        tail = "" if req.status == "OK" else f"  [{req.status}]"
        print(f"req {req.rid}: prompt[{len(req.prompt)}] -> "
              f"{req.out}{tail}")
    print(f"{len(done)} requests, {stats['generated']} tokens in "
          f"{stats['decode_steps']} decode steps "
          f"({stats['preemptions']} preemptions), "
          f"{stats['generated'] / dt:.1f} tok/s")
    print("status: " + ", ".join(f"{k}={v}" for k, v in
                                 stats["status_counts"].items()))
    plan = inject.plan()
    if plan is not None:
        print(f"fault injection: {plan.summary()}")
    ttft = stats["ttft_s"]
    if ttft["count"]:
        print(f"ttft p50={ttft['p50']:.4f}s p95={ttft['p95']:.4f}s "
              f"p99={ttft['p99']:.4f}s")
    if args.trace:
        tpath = obs.export_telemetry(args.telemetry_out)
        cpath = obs.export_chrome_trace(args.trace_out)
        st = obs.state()
        print(f"telemetry: {len(st.tracer)} events "
              f"({st.tracer.dropped} dropped), {len(st.metrics)} metrics "
              f"-> {tpath} + {cpath} (open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
