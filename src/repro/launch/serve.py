"""Batched serving driver (deliverable (b): the serve-kind example).

A minimal continuous-batching server: requests arrive with prompts of
different lengths, a scheduler packs them into a fixed-slot decode batch,
prefill fills each slot's KV cache, and the decode loop emits one token per
slot per step, retiring finished requests and admitting queued ones.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step
from repro.models import family_module, reduced


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = dataclasses.field(default_factory=list)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tuning-db", default=None,
                    help="tuning database (tuner/db.py); defaults to "
                         "artifacts/tuning_db.json")
    ap.add_argument("--tuned-app", default=None,
                    help="co-design app whose tuned kernel blocks to "
                         "install (default: the arch name)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    # measured-autotuning pickup (DESIGN.md §8.4): install the app's tuned
    # block shapes as dispatch defaults; shape-exact DB records still win
    from repro.kernels import ops
    tuned = ops.configure(app=args.tuned_app or args.arch,
                          db_path=args.tuning_db)
    if tuned:
        print(f"tuned kernel blocks installed: gemm={tuned['gemm']}")
    if cfg.embed_inputs:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode loop "
                         f"(DESIGN.md §5) — use launch.train instead")
    mesh = make_host_mesh()
    tp = 1
    mod = family_module(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = mod.init(cfg, key, tp=tp)
    decode = jax.jit(make_decode_step(cfg, tp=tp))

    rng = np.random.default_rng(args.seed)
    queue = [Request(i, rng.integers(0, cfg.vocab,
                                     size=rng.integers(3, 12)).astype(np.int32),
                     args.max_new) for i in range(args.requests)]
    active: dict[int, Request] = {}
    cache = mod.init_cache(cfg, args.slots, args.max_seq, tp)
    pos = 0
    done = []

    t0 = time.time()
    steps = 0
    while queue or active:
        # admit requests into free slots: prefill by stepping prompt tokens
        while queue and len(active) < args.slots:
            req = queue.pop(0)
            slot = next(s for s in range(args.slots) if s not in active)
            active[slot] = req
            # slot-wise prefill via the decode path (teacher-forced steps)
            for t, tok in enumerate(req.prompt):
                toks = np.zeros((args.slots, 1), np.int32)
                toks[slot, 0] = tok
                logits, cache = decode(params, cache, jnp.asarray(toks),
                                       jnp.int32(pos + t))
                steps += 1
            req._next = int(jnp.argmax(logits[slot, -1]))
        pos += max((len(r.prompt) for r in active.values()), default=0)

        # one batched decode step for every active slot
        toks = np.zeros((args.slots, 1), np.int32)
        for slot, req in active.items():
            toks[slot, 0] = getattr(req, "_next", 0)
        logits, cache = decode(params, cache, jnp.asarray(toks),
                               jnp.int32(min(pos, args.max_seq - 1)))
        steps += 1
        pos += 1
        for slot in list(active):
            req = active[slot]
            tok = int(jnp.argmax(logits[slot, -1]))
            req.out.append(tok)
            req._next = tok
            if len(req.out) >= req.max_new or pos >= args.max_seq - 1:
                done.append(req)
                del active[slot]

    dt = time.time() - t0
    for req in sorted(done, key=lambda r: r.rid):
        print(f"req {req.rid}: prompt[{len(req.prompt)}] -> {req.out}")
    print(f"{len(done)} requests, {steps} decode steps, "
          f"{steps / dt:.1f} steps/s")


if __name__ == "__main__":
    main()
