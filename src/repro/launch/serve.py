"""Continuous-batching serving engine (deliverable (b); DESIGN.md §11).

Requests arrive with prompts of different lengths; an FCFS scheduler packs
them into a fixed number of decode *slots*.  Admission runs one batched
prefill over the whole prompt — a single causal forward whose K/V (or
recurrent state) is scattered into that slot alone — and every decode step
advances all active slots at once, each at its own absolute position.

Invariant (the per-slot position contract): slot ``s`` holds a request whose
next token will be written at ``pos[s]``; its cache rows ``< pos[s]`` (or
its recurrent state) describe exactly its own prompt + generated prefix and
nothing else.  Admission re-establishes the invariant by *replacing* the
whole slot slice (prefill scatter == KV/state reset), so a retired tenant's
leftovers can never leak into the next request.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 6 --max-new 8
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import family_module, reduced


@dataclasses.dataclass
class Request:
    """One generation request.  ``next_token`` is a real field (not a
    dynamically attached attribute): −1 until prefill seeds it, then always
    the token the next decode step consumes."""

    rid: int
    prompt: np.ndarray
    max_new: int
    max_seq: int | None = None     # per-request context budget (rows of KV)
    next_token: int = -1
    out: list[int] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(
                f"request {self.rid}: prompt must be a non-empty 1-D token "
                f"array (zero-length prompts have no logits to seed decode)")
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new must be >= 1")


class FCFSScheduler:
    """First-come-first-served slot scheduler — pure bookkeeping, no model.

    Owns the waiting queue and the slot occupancy table.  The engine asks
    :meth:`admit` which requests enter which slots (lowest free slot first,
    queue order preserved) and calls :meth:`retire` when a request finishes;
    ``max_concurrency`` caps simultaneously active requests (1 == the
    sequential one-request-at-a-time baseline).
    """

    def __init__(self, n_slots: int, max_concurrency: int | None = None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_concurrency = min(max_concurrency or n_slots, n_slots)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * n_slots

    @property
    def active(self) -> dict[int, Request]:
        return {s: r for s, r in enumerate(self.slots) if r is not None}

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        """Assign queued requests to free slots, FCFS, up to the
        concurrency cap.  Returns the new (slot, request) pairs."""
        placed = []
        for slot in range(self.n_slots):
            if not self.queue or self.n_active >= self.max_concurrency:
                break
            if self.slots[slot] is None:
                req = self.queue.popleft()
                self.slots[slot] = req
                placed.append((slot, req))
        return placed

    def retire(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not occupied")
        self.slots[slot] = None
        return req


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg, tp: int, impl: str, max_seq: int):
    """One set of jitted step functions per (config, tp, impl, max_seq) —
    shared by every engine instance (a fresh ``jax.jit`` wrapper per engine
    would carry a fresh compilation cache, recompiling identical programs)."""
    mod = family_module(cfg)
    decode = jax.jit(make_decode_step(cfg, tp=tp, impl=impl))
    prefill = jax.jit(
        make_prefill_step(cfg, tp=tp, impl=impl, cache_len=max_seq))
    axes = mod.cache_slot_axes(cfg)

    def write_slot(cache, slot_cache, slot):
        return jax.tree_util.tree_map(
            lambda c, pc, ax: jax.lax.dynamic_update_index_in_dim(
                c, jax.lax.index_in_dim(pc, 0, ax, keepdims=False),
                slot, ax),
            cache, slot_cache, axes)

    return decode, prefill, jax.jit(write_slot)


class ServeEngine:
    """Per-slot continuous batching around one model + one shared cache.

    Lifecycle per request: ``submit`` → (scheduler) → admission prefill
    (one forward over the prompt; the packed slot cache *replaces* the slot
    slice, resetting any stale KV/state; ``pos[slot]`` := prompt length;
    the prompt's last logits seed ``out[0]``) → batched decode steps (each
    active slot consumes its ``next_token`` at its own ``pos``, emits one
    token, ``pos[slot] += 1``) → retirement when ``len(out) == max_new`` or
    the per-request context budget is exhausted.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_seq: int = 64,
                 tp: int = 1, impl: str = "xla",
                 max_concurrency: int | None = None):
        if cfg.embed_inputs:
            raise ValueError(f"{cfg.name} is encoder-only: no decode loop "
                             f"(DESIGN.md §5)")
        self.cfg, self.params = cfg, params
        self.mod = family_module(cfg)
        self.n_slots, self.max_seq = slots, max_seq
        self.scheduler = FCFSScheduler(slots, max_concurrency)
        self._decode, self._prefill, self._write_slot = _jitted_steps(
            cfg, tp, impl, max_seq)
        self.cache = self.mod.init_cache(cfg, slots, max_seq, tp)
        self.pos = np.zeros(slots, np.int64)   # per-slot next write position
        self.decode_steps = 0
        self.prefill_tokens = 0
        self.generated = 0

    # -- request intake ----------------------------------------------------

    def _budget(self, req: Request) -> int:
        return min(self.max_seq, req.max_seq or self.max_seq)

    def submit(self, req: Request) -> None:
        if len(req.prompt) >= self._budget(req):
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)} tokens) must "
                f"leave room under its context budget {self._budget(req)}")
        self.scheduler.submit(req)

    # -- the serving loop --------------------------------------------------

    def _admit(self) -> list[Request]:
        """Prefill newly admitted requests into their slots; returns any
        that finish immediately (max_new == 1).

        Known scaling limit: the prefill jit is shape-keyed on the prompt
        length, so each distinct length compiles once per process.  Fine at
        smoke scale; arbitrary production traffic wants length bucketing,
        which needs per-family masking of the pad tail (right-padding feeds
        junk into recurrent state and can wrap ring rows) — not done here.
        """
        finished = []
        for slot, req in self.scheduler.admit():
            prompt = jnp.asarray(req.prompt[None, :])
            logits, slot_cache = self._prefill(self.params, prompt)
            self.cache = self._write_slot(self.cache, slot_cache,
                                          jnp.int32(slot))
            self.pos[slot] = len(req.prompt)
            tok = int(jnp.argmax(logits[0, -1]))
            req.next_token = tok
            req.out.append(tok)
            self.prefill_tokens += len(req.prompt)
            self.generated += 1
            if len(req.out) >= req.max_new:
                finished.append(self.scheduler.retire(slot))
        return finished

    def step(self) -> list[Request]:
        """Admit what fits, then run one batched decode step over every
        active slot.  Returns the requests that finished this step."""
        finished = self._admit()
        active = self.scheduler.active
        if not active:
            return finished
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot, req in active.items():
            toks[slot, 0] = req.next_token
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(self.pos, jnp.int32))
        self.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for slot, req in active.items():
            tok = int(nxt[slot])
            req.out.append(tok)
            req.next_token = tok
            self.pos[slot] += 1
            self.generated += 1
            if len(req.out) >= req.max_new \
                    or self.pos[slot] >= self._budget(req):
                finished.append(self.scheduler.retire(slot))
        return finished

    def run(self) -> list[Request]:
        """Serve until queue and slots drain; requests in rid order."""
        done: list[Request] = []
        while self.scheduler.has_work():
            done.extend(self.step())
        return sorted(done, key=lambda r: r.rid)


def serve_requests(cfg, params, requests, *, slots: int = 4,
                   max_seq: int = 64, tp: int = 1, impl: str = "xla",
                   max_concurrency: int | None = None
                   ) -> tuple[list[Request], dict]:
    """Convenience wrapper: submit ``requests``, drain the engine, return
    ``(finished_requests, stats)``.  ``max_concurrency=1`` is the sequential
    one-request-at-a-time baseline (identical math and shapes, no batching
    across requests)."""
    eng = ServeEngine(cfg, params, slots=slots, max_seq=max_seq, tp=tp,
                      impl=impl, max_concurrency=max_concurrency)
    for req in requests:
        eng.submit(req)
    done = eng.run()
    return done, {"decode_steps": eng.decode_steps,
                  "prefill_tokens": eng.prefill_tokens,
                  "generated": eng.generated}


def make_requests(cfg, n: int, max_new: int, seed: int = 0,
                  lengths: tuple[int, int] = (3, 12)) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(*lengths)))
                    .astype(np.int32), max_new)
            for i in range(n)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sequential", action="store_true",
                    help="one-request-at-a-time baseline (max_concurrency=1)")
    ap.add_argument("--tuning-db", default=None,
                    help="tuning database (tuner/db.py); defaults to "
                         "artifacts/tuning_db.json")
    ap.add_argument("--tuned-app", default=None,
                    help="co-design app whose tuned kernel blocks to "
                         "install (default: the arch name)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    # measured-autotuning pickup (DESIGN.md §8.4): install the app's tuned
    # block shapes as dispatch defaults; shape-exact DB records still win
    from repro.kernels import ops
    tuned = ops.configure(app=args.tuned_app or args.arch,
                          db_path=args.tuning_db)
    if tuned:
        print(f"tuned kernel blocks installed: gemm={tuned['gemm']}")
    if cfg.embed_inputs:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode loop "
                         f"(DESIGN.md §5) — use launch.train instead")
    make_host_mesh()
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(args.seed), tp=1)
    requests = make_requests(cfg, args.requests, args.max_new, args.seed)

    t0 = time.time()
    done, stats = serve_requests(
        cfg, params, requests, slots=args.slots, max_seq=args.max_seq,
        max_concurrency=1 if args.sequential else None)
    dt = time.time() - t0
    for req in done:
        print(f"req {req.rid}: prompt[{len(req.prompt)}] -> {req.out}")
    print(f"{len(done)} requests, {stats['generated']} tokens in "
          f"{stats['decode_steps']} decode steps, "
          f"{stats['generated'] / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
