"""Step functions: train_step / prefill_step / decode (serve) step builders,
shared by the trainers, the servers, and the multi-pod dry-run.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import family_module
from repro.models.config import ModelConfig
from repro.optim import AdamW

Params = Any


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE in f32; padded-vocab rows arrive already masked
    to -1e30 by unembed, so logsumexp ignores them."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def model_inputs(batch: dict, cfg: ModelConfig) -> dict:
    return {k: v for k, v in batch.items() if k != "labels"}


def make_loss_fn(cfg: ModelConfig, *, tp: int, impl: str = "xla"):
    mod = family_module(cfg)

    def loss_fn(params, batch):
        logits = mod.forward(params, cfg, model_inputs(batch, cfg),
                             tp=tp, impl=impl)
        labels = batch["labels"]
        if cfg.vis_tokens:           # loss on the text tail only
            logits = logits[:, cfg.vis_tokens:]
        return cross_entropy(logits, labels)

    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer: AdamW, *, tp: int,
                    impl: str = "xla"):
    loss_fn = make_loss_fn(cfg, tp=tp, impl=impl)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig, *, tp: int, impl: str = "xla",
                      cache_len: int | None = None):
    """Prefill step builder.

    Without ``cache_len`` (training / dry-run use): a plain full-sequence
    forward returning logits.

    With ``cache_len`` (serving use, DESIGN.md §11): one causal forward over
    ``tokens (1, S)`` through the decode path against a fresh batch-1 cache,
    returning ``(logits, slot_cache)`` where ``slot_cache`` is packed into
    the serving layout (length ``cache_len``, ring folds applied).  The last
    position's logits are exact; the cache equals what S sequential decode
    steps would have produced — without ever touching a neighbor slot.
    """
    mod = family_module(cfg)

    if cache_len is None:
        def prefill_step(params, batch):
            return mod.forward(params, cfg, batch, tp=tp, impl=impl)

        return prefill_step

    def slot_prefill_step(params, tokens):
        s = tokens.shape[1]
        pcache = mod.init_prefill_cache(cfg, tokens.shape[0], s, tp)
        logits, pcache = mod.decode_step(
            params, cfg, pcache, tokens,
            jnp.zeros((tokens.shape[0],), jnp.int32), tp=tp, impl=impl)
        return logits, mod.pack_slot_cache(cfg, pcache, cache_len, s)

    return slot_prefill_step


def make_decode_step(cfg: ModelConfig, *, tp: int, impl: str = "xla"):
    mod = family_module(cfg)

    def decode_step(params, cache, tokens, pos, row_map=None):
        """tokens (B, S); pos (B,) per-slot absolute positions (scalar
        broadcasts); ``row_map`` (B, L) page table for paged caches
        (DESIGN.md §12), None for dense."""
        return mod.decode_step(params, cfg, cache, tokens, pos,
                               tp=tp, impl=impl, row_map=row_map)

    return decode_step
