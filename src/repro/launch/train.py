"""End-to-end training driver (deliverable (b): the train-kind example).

Runs real steps on whatever devices exist (reduced configs on this CPU
container; the same code path scales to the production mesh — the dry-run
proves those shardings compile).  Features exercised here:

  * deterministic sharded data pipeline with background prefetch,
  * AdamW + clipping + cosine schedule, optional EF-int8 grad compression,
  * atomic/async checkpointing with auto-resume,
  * heartbeat watchdog with straggler accounting,
  * simulated failure injection (--inject-failure-at) to demonstrate the
    checkpoint/restart path.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 30 --inject-failure-at 12
"""
from __future__ import annotations

import argparse
import functools
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.context import DEFAULT_TRAIN_SPEC, set_activation_spec
from repro.distributed.sharding import batch_specs, named
from repro.ft import CheckpointManager, Watchdog
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import family_module, reduced
from repro.optim import AdamW, cosine_schedule
from repro.optim.compression import ef_compress, ef_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a crash at this step (tests restart)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tuning-db", default=None,
                    help="tuning database (tuner/db.py); defaults to "
                         "artifacts/tuning_db.json")
    ap.add_argument("--tuned-app", default=None,
                    help="co-design app whose tuned kernel blocks to "
                         "install (default: the arch name)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    # measured-autotuning pickup (DESIGN.md §8.4): install the app's tuned
    # block shapes as dispatch defaults; shape-exact DB records still win
    from repro.kernels import ops as _ops
    tuned = _ops.configure(app=args.tuned_app or args.arch,
                           db_path=args.tuning_db)
    if tuned:
        print(f"tuned kernel blocks installed: gemm={tuned['gemm']}")
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    tp = mesh.shape.get("model", 1)
    set_activation_spec(DEFAULT_TRAIN_SPEC if tp > 1 else None, mesh)
    mod = family_module(cfg)

    key = jax.random.PRNGKey(args.seed)
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=5, total=args.steps))
    step_fn = make_train_step(cfg, opt, tp=tp)

    p_sh = named(mod.specs(cfg), mesh)
    o_sh = named(opt.init_specs(mod.specs(cfg)), mesh)
    b_sh = named({k: v for k, v in batch_specs(cfg).items()
                  if k in ("tokens", "labels")}, mesh)
    jitted = jax.jit(step_fn, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))

    ckpt = CheckpointManager(Path(args.checkpoint_dir) / cfg.name)
    start = 0
    latest = ckpt.latest_step()
    state = None
    if latest is not None:
        state = ckpt.restore(latest, like=_eval_state(mod, cfg, opt, key, tp),
                             mesh=mesh, specs=(mod.specs(cfg),
                                               opt.init_specs(mod.specs(cfg))))
    if state is not None:
        params, opt_state = state
        start = latest + 1
        print(f"resumed from checkpoint step {latest}")
    else:
        with mesh:
            params = mod.init(cfg, key, tp=tp)
            opt_state = opt.init(params)

    data = SyntheticLM(cfg.vocab, args.seq_len, args.global_batch, args.seed)
    ef_state = ef_init(params) if args.grad_compression else None
    watchdog = Watchdog(n_workers=jax.process_count())

    fetch = Prefetcher(lambda s: data.batch(s), start_step=start)
    for step in range(start, args.steps):
        got_step, host_batch = fetch.get()
        assert got_step == step
        if cfg.vis_tokens or cfg.embed_inputs:
            host_batch = _adapt_batch(cfg, host_batch)
        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        t0 = time.time()
        if step == args.inject_failure_at:
            # drain the async writer first: the injection simulates a crash
            # *after* the last checkpoint landed, so the rerun demonstrably
            # resumes from it (a writer killed mid-write is already safe —
            # it only ever loses the in-flight step, never corrupts)
            ckpt.wait()
            raise SystemExit(
                f"[injected failure at step {step}] — rerun the same "
                f"command; training auto-resumes from the last checkpoint")
        params, opt_state, metrics = jitted(params, opt_state, batch)
        dt = time.time() - t0
        watchdog.beat(jax.process_index(), step, step_time_s=dt)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  {dt*1e3:.0f}ms  "
                  f"health {watchdog.check()}")
        if step and step % args.checkpoint_every == 0:
            ckpt.save(step, (params, opt_state), blocking=False)
    ckpt.wait()
    ckpt.save(args.steps - 1, (params, opt_state))
    print(f"done; checkpoints in {ckpt.dir}")


def _eval_state(mod, cfg, opt, key, tp):
    params = jax.eval_shape(functools.partial(mod.init, cfg, tp=tp), key)
    return params, jax.eval_shape(opt.init, params)


def _adapt_batch(cfg, batch):
    import numpy as np
    toks, labels = batch["tokens"], batch["labels"]
    if cfg.embed_inputs:   # hubert: frames stand in for the CNN frontend
        rng = np.random.default_rng(int(toks[0, 0]) + 1)
        frames = rng.standard_normal(
            (toks.shape[0], toks.shape[1], cfg.d_model)).astype("float32")
        return {"frames": frames, "labels": labels % cfg.vocab}
    if cfg.vis_tokens:     # internvl2: patch prefix
        rng = np.random.default_rng(int(toks[0, 0]) + 1)
        patches = rng.standard_normal(
            (toks.shape[0], cfg.vis_tokens, cfg.d_model)).astype("float32")
        return {"tokens": toks, "patches": patches, "labels": labels}
    return batch


if __name__ == "__main__":
    main()
