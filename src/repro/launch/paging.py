"""Host-side KV paging + scheduling policy (DESIGN.md §12).

Two model-free pieces the paged serving engine composes:

  * :class:`PageAllocator` — owns the physical page pool's free list.  Pages
    are fixed-size groups of KV rows; the engine maps a slot's *logical*
    rows onto its pages through a per-slot page table (``row_map``), so long
    and short requests share one pool instead of each pinning a full
    ``max_seq`` slice.
  * :class:`PriorityScheduler` — priority-class admission (lower value =
    more urgent), FIFO within a class, aging so sustained high-priority load
    cannot starve low priority, and preemption bookkeeping: a preempted
    request re-enters its class queue at its original submit position.

Both are pure bookkeeping (no jax, no model) and unit-testable in
isolation; ``tests/test_paged_kv.py`` holds the property tests.
"""
from __future__ import annotations

import collections
import itertools
from typing import TYPE_CHECKING

from repro import obs
from repro.ft import inject

if TYPE_CHECKING:  # pragma: no cover - type-only import
    from repro.launch.serve import Request


class PageAllocator:
    """Free-list allocator over ``n_pages`` fixed-size KV pages.

    Lowest-numbered free pages are handed out first, so allocation order is
    deterministic (same request stream -> same physical layout -> the
    bit-exactness gates stay meaningful).  Double-allocation and foreign /
    double frees raise rather than corrupt the pool.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError("need n_pages >= 1 and page_size >= 1")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: list[int] = list(range(n_pages))   # ascending
        self._held: set[int] = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def free_pages(self) -> tuple[int, ...]:
        return tuple(self._free)

    def pages_for(self, rows: int) -> int:
        """Pages needed to hold ``rows`` KV rows."""
        return -(-max(0, rows) // self.page_size)

    def alloc(self, n: int) -> list[int]:
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        # fault-injection site (DESIGN.md §14): fires BEFORE any free-list
        # mutation, so an injected MemoryError is indistinguishable from a
        # genuine exhaustion and leaves the pool consistent
        inject.check("page.alloc", MemoryError)
        if n > len(self._free):
            raise MemoryError(
                f"allocation of {n} pages exceeds {len(self._free)} free")
        pages, self._free = self._free[:n], self._free[n:]
        self._held.update(pages)
        st = obs.state()
        if st is not None:
            st.metrics.counter("pages.alloc").inc(n)
            st.metrics.gauge("pages.free").set(len(self._free))
        return pages

    def free(self, pages: list[int]) -> None:
        for p in pages:
            if p not in self._held:
                raise ValueError(f"page {p} is not currently allocated")
            self._held.discard(p)
        self._free = sorted(self._free + list(pages))
        st = obs.state()
        if st is not None:
            st.metrics.counter("pages.freed").inc(len(pages))
            st.metrics.gauge("pages.free").set(len(self._free))

    def rows(self, pages: list[int], n_rows: int) -> list[int]:
        """Physical row index for each of the first ``n_rows`` logical rows
        stored on ``pages`` (page-major, ``page * page_size + offset``)."""
        ps = self.page_size
        out = [p * ps + i for p in pages for i in range(ps)]
        if n_rows > len(out):
            raise ValueError(f"{n_rows} rows exceed {len(pages)} pages")
        return out[:n_rows]


class PriorityScheduler:
    """Priority-class slot scheduler with aging and preemption requeue.

    ``priority`` is a small non-negative int, 0 = most urgent.  Admission
    order is (effective priority, submit order): FIFO within a class, and a
    waiting request's effective priority improves by one class every
    ``age_steps`` scheduler ticks — ties break on submit order, so an aged
    low-priority request eventually outranks freshly submitted high-priority
    traffic (the no-starvation guarantee).

    The scheduler only does bookkeeping; *page* admission control and victim
    selection policy live in the engine, which asks :meth:`least_deserving`
    for the preemption candidate.
    """

    def __init__(self, n_slots: int, max_concurrency: int | None = None,
                 age_steps: int = 32):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        self.n_slots = n_slots
        self.max_concurrency = min(max_concurrency or n_slots, n_slots)
        self.age_steps = age_steps
        self.now = 0
        self.queues: dict[int, collections.deque[Request]] = {}
        self.slots: list[Request | None] = [None] * n_slots
        self._seq = itertools.count()
        self._admit_seq = itertools.count()
        self._enqueued_at: dict[int, int] = {}       # rid -> tick
        self._admitted: dict[int, int] = {}          # slot -> admit seq

    # -- state ------------------------------------------------------------

    @property
    def active(self) -> dict[int, "Request"]:
        return {s: r for s, r in enumerate(self.slots) if r is not None}

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    @property
    def n_waiting(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def has_work(self) -> bool:
        return self.n_waiting > 0 or self.n_active > 0

    def tick(self) -> None:
        self.now += 1

    def effective_priority(self, req: "Request") -> int:
        """Class after aging: one class better per ``age_steps`` ticks
        waited (0 disables aging)."""
        if not self.age_steps:
            return req.priority
        waited = self.now - self._enqueued_at.get(req.rid, self.now)
        return max(0, req.priority - waited // self.age_steps)

    # -- queue ------------------------------------------------------------

    def submit(self, req: "Request") -> None:
        req.submit_seq = next(self._seq)
        self._enqueue(req)

    def _enqueue(self, req: "Request") -> None:
        self._enqueued_at.setdefault(req.rid, self.now)
        q = self.queues.setdefault(req.priority, collections.deque())
        # keep each class queue sorted by submit order; a preempted request
        # (older seq than anything still waiting) lands back at the front
        i = len(q)
        while i > 0 and q[i - 1].submit_seq > req.submit_seq:
            i -= 1
        q.insert(i, req)

    def peek(self) -> "Request | None":
        """Best waiting request: lowest (effective priority, submit order)."""
        heads = [q[0] for q in self.queues.values() if q]
        if not heads:
            return None
        return min(heads, key=lambda r: (self.effective_priority(r),
                                         r.submit_seq))

    def waiting(self) -> list["Request"]:
        """Every waiting request, across all class queues (queue order
        within a class; no cross-class ordering implied)."""
        return [r for q in self.queues.values() for r in q]

    def remove(self, req: "Request") -> bool:
        """Pull a waiting request out of its class queue (cancellation /
        deadline expiry); False if it was not waiting."""
        q = self.queues.get(req.priority)
        if q is not None and req in q:
            q.remove(req)
            return True
        return False

    # -- slots ------------------------------------------------------------

    def free_slot(self) -> int | None:
        if self.n_active >= self.max_concurrency:
            return None
        for slot, r in enumerate(self.slots):
            if r is None:
                return slot
        return None

    def place(self, req: "Request") -> int:
        """Move ``req`` from its queue into the lowest free slot."""
        slot = self.free_slot()
        if slot is None:
            raise ValueError("no free slot")
        q = self.queues.get(req.priority)
        if not q or req not in q:
            raise ValueError(f"request {req.rid} is not waiting")
        st = obs.state()
        if st is not None and self.effective_priority(req) < req.priority:
            # the no-starvation mechanism actually fired: this placement
            # was earned through aging, not nominal class
            st.metrics.counter("sched.aged_admits").inc()
        q.remove(req)
        # _enqueued_at is deliberately KEPT: the aging clock runs from first
        # submission across preemptions, so an aged-in low-priority request
        # keeps its earned effective priority and cannot be re-starved by a
        # preempt/requeue cycle.
        self.slots[slot] = req
        self._admitted[slot] = next(self._admit_seq)
        return slot

    def retire(self, slot: int) -> "Request":
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not occupied")
        self.slots[slot] = None
        self._admitted.pop(slot, None)
        return req

    def preempt(self, slot: int) -> "Request":
        """Evict the request in ``slot`` back into its class queue (at its
        original submit position, so intra-class FIFO order is preserved)."""
        req = self.retire(slot)
        req.preemptions += 1
        self._enqueue(req)
        return req

    def least_deserving(self, than: tuple[int, int] | None = None
                        ) -> int | None:
        """Slot of the least-deserving active request — highest *effective*
        priority value, most recently admitted on ties.  With ``than`` =
        (priority, admit_seq), only a strictly less deserving victim is
        returned."""
        cands = [(self.effective_priority(r), self._admitted[s], s)
                 for s, r in self.active.items()]
        if not cands:
            return None
        prio, seq, slot = max(cands)
        if than is not None and (prio, seq) <= than:
            return None
        return slot

    def admit_key(self, slot: int) -> tuple[int, int]:
        """(effective priority, admit order) deservingness key for the slot
        holder — effective, not nominal, so an aged-in low-priority request
        is as preemption-proof as the class it aged into."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is not occupied")
        return (self.effective_priority(req), self._admitted[slot])
