"""Gradient compression with error feedback.

Two modes (DESIGN.md §6 "distributed-optimization tricks"):

* ``ef_compress`` — int8 block-quantization with an f32 error-feedback
  accumulator.  Quantize-dequantize happens *before* the data-parallel
  reduction; the residual is carried to the next step, so the scheme is
  unbiased in the long run (classic EF-SGD).  On real pods this halves/
  quarters DP all-reduce bytes when paired with a low-precision reduction;
  here it also serves the convergence-vs-compression benchmark.

* the bf16-reduction path is free: params/grads are bf16 end-to-end and the
  pjit-inserted reduce-scatter already moves 2-byte words (visible in the
  dry-run's collective bytes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress(grads, ef_state):
    """Error-feedback int8 compression.

    grads/ef_state: matching pytrees (ef_state f32, zeros at step 0).
    Returns (compressed_grads, new_ef_state).
    """
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(tree, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(tree, [o[1] for o in out]))


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
