"""Optimizer substrate: AdamW with global-norm clipping, schedules, and
error-feedback gradient compression."""

from .adamw import AdamW, OptState, cosine_schedule
from .compression import ef_compress

__all__ = ["AdamW", "OptState", "cosine_schedule", "ef_compress"]
