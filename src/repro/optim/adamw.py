"""AdamW in pure JAX with f32 master moments over (possibly bf16) params,
global-norm clipping and warmup+cosine schedule.  State specs mirror param
specs so ZeRO-style sharding falls out of GSPMD (DESIGN.md §6).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclass
class OptState:
    step: jax.Array
    m: Params
    v: Params


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / max(1, warmup))
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


class AdamW:
    def __init__(self, lr: float | Callable = 3e-4, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
        self.lr = lr if callable(lr) else (lambda _: jnp.float32(lr))
        self.b1, self.b2, self.eps = b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm

    def init(self, params: Params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree_util.tree_map(zeros, params),
                        jax.tree_util.tree_map(zeros, params))

    def init_specs(self, param_specs: Params) -> OptState:
        from jax.sharding import PartitionSpec as P
        return OptState(P(), param_specs, param_specs)

    def update(self, grads: Params, state: OptState,
               params: Params) -> tuple[Params, OptState, jax.Array]:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9)) \
            if self.clip_norm > 0 else jnp.float32(1.0)
        step = state.step + 1
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            update = (m2 / c1) / (jnp.sqrt(v2 / c2) + self.eps)
            update = update + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m2, v2

        flat_p, tree = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_m = jax.tree_util.tree_leaves(state.m)
        flat_v = jax.tree_util.tree_leaves(state.v)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
        return new_p, OptState(step, new_m, new_v), gnorm


jax.tree_util.register_pytree_node(
    OptState,
    lambda s: ((s.step, s.m, s.v), None),
    lambda _, c: OptState(*c),
)
