"""Model zoo: one module per family, uniform functional API
(init / specs / forward / decode_step / init_cache / cache_specs)."""
from __future__ import annotations

from types import ModuleType

from . import rwkv6, transformer, zamba2
from .config import SHAPES, ModelConfig, ShapeCell, reduced

__all__ = ["SHAPES", "ModelConfig", "ShapeCell", "family_module", "reduced",
           "rwkv6", "transformer", "zamba2"]


def family_module(cfg: ModelConfig) -> ModuleType:
    if cfg.family == "rwkv6":
        return rwkv6
    if cfg.family == "zamba2":
        return zamba2
    return transformer  # dense / moe / vlm-backbone / encoder
