"""RWKV-6 "Finch" LM (rwkv6-3b): attention-free, data-dependent decay.

Block = time-mix (WKV recurrence via the chunked Pallas kernel) + channel-mix.
Faithful elements: token-shift interpolation, data-dependent per-channel decay
through a low-rank (LoRA) projection, per-head bonus ``u``, per-head group
norm, receptance gating in channel-mix.  Simplification (DESIGN.md
§Arch-applicability): the token-shift mixing coefficients are static
(per-channel ``mu``) rather than data-dependent ddlerp — the recurrence
itself keeps the paper-relevant data-dependent decay.

Head count 40 (2560/64) pads to 48 under tp=16 with zero o-proj rows (exact).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.context import constrain_activations
from repro.kernels import ops
from . import layers as L
from .config import ModelConfig

Params = dict[str, Any]
LORA_RANK = 64


def _heads(cfg: ModelConfig, tp: int) -> int:
    return cfg.padded(tp).rwkv_heads or cfg.d_model // cfg.rwkv_head_dim


def _block_init(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    d, dh = cfg.d_model, cfg.rwkv_head_dim
    h = _heads(cfg, tp)
    hd = h * dh
    ks = jax.random.split(key, 12)
    sc = d ** -0.5
    p = {
        "ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
        # time-mix
        "mu": L._normal(ks[0], (5, d), 0.02, dtype) + 0.5,  # r,k,v,g,w
        "wr": L._normal(ks[1], (d, hd), sc, dtype),
        "wk": L._normal(ks[2], (d, hd), sc, dtype),
        "wv": L._normal(ks[3], (d, hd), sc, dtype),
        "wg": L._normal(ks[4], (d, hd), sc, dtype),
        "wo": L._normal(ks[5], (hd, d), hd ** -0.5, dtype),
        "w0": jnp.full((hd,), -1.0, dtype),
        "w_lora_a": L._normal(ks[6], (d, LORA_RANK), sc, dtype),
        "w_lora_b": L._normal(ks[7], (LORA_RANK, hd), LORA_RANK ** -0.5, dtype),
        "u": L._normal(ks[8], (h, dh), 0.5, dtype),
        "ln_x": jnp.ones((h, dh), dtype),
        # channel-mix
        "mu_c": L._normal(ks[9], (2, d), 0.02, dtype) + 0.5,  # k, r
        "wck": L._normal(ks[10], (d, cfg.d_ff), sc, dtype),
        "wcv": L._normal(ks[11], (cfg.d_ff, d), cfg.d_ff ** -0.5, dtype),
        "wcr": L._normal(ks[0], (d, d), sc, dtype),
    }
    logical = cfg.d_model // cfg.rwkv_head_dim
    if h > logical:  # exact padding: zero output rows for the extra heads
        mask = (jnp.arange(h) < logical).repeat(dh)[:, None]
        p["wo"] = (p["wo"] * mask).astype(dtype)
    return p


def _block_specs(cfg: ModelConfig) -> Params:
    return {
        "ln1": P(None), "ln2": P(None),
        "mu": P(None, None),
        "wr": P(L.FSDP, L.TP), "wk": P(L.FSDP, L.TP), "wv": P(L.FSDP, L.TP),
        "wg": P(L.FSDP, L.TP), "wo": P(L.TP, L.FSDP),
        "w0": P(L.TP), "w_lora_a": P(L.FSDP, None), "w_lora_b": P(None, L.TP),
        "u": P(L.TP, None), "ln_x": P(L.TP, None),
        "mu_c": P(None, None),
        "wck": P(L.FSDP, L.TP), "wcv": P(L.TP, L.FSDP), "wcr": P(L.FSDP, L.TP),
    }


def _shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Token shift: x_{t-1}, with ``prev`` as the carry for decode."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :]
    pad = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _time_mix(p: Params, cfg: ModelConfig, x, tp: int, impl: str,
              wkv_state=None, shift_prev=None):
    b, s, d = x.shape
    dh = cfg.rwkv_head_dim
    h = _heads(cfg, tp)
    xp = _shift(x, shift_prev)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + (xp - x) * mu[i] for i in range(5))

    r = (xr @ p["wr"]).reshape(b, s, h, dh)
    k = (xk @ p["wk"]).reshape(b, s, h, dh)
    v = (xv @ p["wv"]).reshape(b, s, h, dh)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay (Finch): log-decay = -exp(w0 + lora(x_w)) <= 0
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    logw = -jnp.exp(jnp.clip((p["w0"] + lora).astype(jnp.float32), -8.0, 6.0))
    logw = logw.reshape(b, s, h, dh)

    if s == 1:
        # decode fast path: one recurrence step, no kernel launch
        st = wkv_state if wkv_state is not None else jnp.zeros(
            (b, h, dh, dh), jnp.float32)
        r1, k1, v1 = (z[:, 0].astype(jnp.float32) for z in (r, k, v))
        w1 = logw[:, 0]
        kv = k1[..., :, None] * v1[..., None, :]
        u_f = p["u"].astype(jnp.float32)
        o1 = jnp.einsum("bhk,bhkv->bhv", r1,
                        st + u_f[None, :, :, None] * kv)
        new_state = jnp.exp(w1)[..., None] * st + kv
        out = o1[:, None].astype(x.dtype)
    else:
        out, new_state = ops.rwkv6(r, k, v, logw.astype(x.dtype), p["u"],
                                   wkv_state, implementation=impl)
    # per-head group norm, then gate and project
    out = L.rms_norm(out, p["ln_x"])
    out = out.reshape(b, s, h * dh) * g
    return out @ p["wo"], new_state, x[:, -1]


def _channel_mix(p: Params, x, shift_prev=None):
    xp = _shift(x, shift_prev)
    mu = p["mu_c"].astype(x.dtype)
    xk = x + (xp - x) * mu[0]
    xr = x + (xp - x) * mu[1]
    hidden = jnp.square(jax.nn.relu(xk @ p["wck"]))
    return jax.nn.sigmoid(xr @ p["wcr"]) * (hidden @ p["wcv"]), x[:, -1]


def _block(p: Params, cfg: ModelConfig, x, tp, impl, state=None):
    st = state or {}
    att, wkv, sh_t = _time_mix(p, cfg, L.rms_norm(x, p["ln1"]), tp, impl,
                               st.get("wkv"), st.get("shift_t"))
    x = x + att
    cm, sh_c = _channel_mix(p, L.rms_norm(x, p["ln2"]), st.get("shift_c"))
    x = constrain_activations(x + cm)
    new_state = {"wkv": wkv, "shift_t": sh_t, "shift_c": sh_c}
    return x, new_state


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key, tp: int = 1) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = [_block_init(keys[i], cfg, tp, dtype)
              for i in range(cfg.n_layers)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return {
        "embed": L.embed_init(keys[-2], cfg, tp, dtype),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "head": {"table": L._normal(keys[-1], (cfg.padded(tp).vocab,
                                               cfg.d_model), 0.02, dtype)},
    }


def specs(cfg: ModelConfig) -> Params:
    blk = jax.tree_util.tree_map(lambda s: P(None, *s), _block_specs(cfg),
                                 is_leaf=lambda x: isinstance(x, P))
    return {"embed": L.embed_specs(), "layers": blk, "final_norm": P(None),
            "head": L.embed_specs()}


def forward(params, cfg: ModelConfig, inputs, *, tp: int = 1,
            impl: str = "xla") -> jax.Array:
    x = L.embed(params["embed"], inputs["tokens"])

    def body(x, lp):
        x, _ = _block(lp, cfg, x, tp, impl)
        return x, None
    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"])
    return L.unembed(params["head"], x, cfg.vocab)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, tp: int = 1,
               dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    h, dh = _heads(cfg, tp), cfg.rwkv_head_dim
    ll = cfg.n_layers
    return {
        "wkv": jnp.zeros((ll, batch, h, dh, dh), jnp.float32),
        "shift_t": jnp.zeros((ll, batch, cfg.d_model), dtype),
        "shift_c": jnp.zeros((ll, batch, cfg.d_model), dtype),
    }


def cache_specs(cfg: ModelConfig) -> Params:
    return {"wkv": P(None, L.BATCH_AXES, L.TP, None, None),
            "shift_t": P(None, L.BATCH_AXES, None),
            "shift_c": P(None, L.BATCH_AXES, None)}


def init_prefill_cache(cfg: ModelConfig, batch: int, seq: int, tp: int = 1,
                       dtype=None) -> Params:
    """Batch-1 prefill state (DESIGN.md §11): the recurrence is O(1) in
    sequence length, so the prefill cache IS the slot state."""
    return init_cache(cfg, batch, seq, tp, dtype)


def pack_slot_cache(cfg: ModelConfig, pcache: Params, max_seq: int,
                    seq_len: int) -> Params:
    """Identity: recurrent state has no sequence axis.  A fresh admission
    scatters this state over the slot wholesale, which is exactly the
    per-slot state *reset* this family needs instead of position zeroing."""
    if seq_len > max_seq:
        raise ValueError(f"prompt length {seq_len} exceeds max_seq {max_seq}")
    return pcache


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Batch(=slot)-axis index of every cache leaf (serving scatter map)."""
    return {"wkv": 1, "shift_t": 1, "shift_c": 1}


def init_paged_cache(cfg: ModelConfig, slots: int, rows: int, max_seq: int,
                     tp: int = 1, dtype=None) -> Params:
    """Paged-API alias (DESIGN.md §12): recurrent state is O(1) per slot, so
    there is nothing to page — the family joins the paged engine with zero
    pool rows and the same per-slot state as the dense engine."""
    return init_cache(cfg, slots, max_seq, tp, dtype)


def paged_cache_specs(cfg: ModelConfig) -> Params:
    """Same layout as the dense cache (the paged cache IS the dense cache),
    so the same shardings: heads shard over TP, slots stay replicated."""
    return cache_specs(cfg)


def paged_slot_axes(cfg: ModelConfig) -> Params:
    """No pooled leaves: every leaf is per-slot, exactly as in
    :func:`cache_slot_axes`."""
    return cache_slot_axes(cfg)


def pack_paged_slot(cfg: ModelConfig, pcache: Params, max_seq: int,
                    seq_len: int) -> Params:
    """Identity, same as :func:`pack_slot_cache` (no sequence axis)."""
    return pack_slot_cache(cfg, pcache, max_seq, seq_len)


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *,
                tp: int = 1, impl: str = "xla", row_map=None):
    """State-carried step (O(1) in context length — the reason long_500k
    runs for this family).  ``tokens`` may be (B, 1) (decode) or (B, S)
    (slot prefill); ``pos`` is accepted for API uniformity but unused — the
    recurrent state, not a position index, carries the history.
    ``row_map`` is likewise accepted and ignored: no leaf is paged."""
    x = L.embed(params["embed"], tokens)

    def body(x, xs):
        lp, st = xs
        x, ns = _block(lp, cfg, x, tp, impl, state=st)
        return x, ns

    x, new_state = jax.lax.scan(
        body, x, (params["layers"],
                  {"wkv": cache["wkv"], "shift_t": cache["shift_t"],
                   "shift_c": cache["shift_c"]}))
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(params["head"], x, cfg.vocab)
    return logits, new_state
