"""Unified transformer family: dense GQA LMs (deepseek-coder-33b,
deepseek-67b, qwen3-8b, internvl2-76b backbone), gemma2 (alternating
local/global + softcaps + sandwich norms), MoE LMs (granite, moonshot), and
the hubert encoder — selected purely by ModelConfig flags.

Layers are scanned (jax.lax.scan) with optional remat so that 95-layer
configs stay compile-light; gemma2's local/global alternation scans pairs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.context import constrain_activations
from . import layers as L
from .config import ModelConfig

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# One transformer block
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {
        "ln_attn": jnp.zeros((cfg.d_model,), dtype) if cfg.sandwich_norm
        else jnp.ones((cfg.d_model,), dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), dtype) if cfg.sandwich_norm
        else jnp.ones((cfg.d_model,), dtype),
        "attn": L.attn_init(ks[0], cfg, tp, dtype),
    }
    if cfg.sandwich_norm:
        p["ln_attn_post"] = jnp.zeros((cfg.d_model,), dtype)
        p["ln_mlp_post"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.n_experts:
        p["moe"] = L.moe_init(ks[1], cfg, tp, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[2], cfg, dtype)
    return p


def _block_specs(cfg: ModelConfig) -> Params:
    p: Params = {"ln_attn": P(None), "ln_mlp": P(None),
                 "attn": L.attn_specs(cfg)}
    if cfg.sandwich_norm:
        p["ln_attn_post"] = P(None)
        p["ln_mlp_post"] = P(None)
    if cfg.n_experts:
        p["moe"] = L.moe_specs()
    else:
        p["mlp"] = L.mlp_specs()
    return p


def _block(p: Params, cfg: ModelConfig, x, *, positions, tp, impl, window,
           cache=None, cache_pos=None, row_map=None):
    plus_one = cfg.sandwich_norm  # gemma-style (1+w) norms
    h = L.rms_norm(x, p["ln_attn"], plus_one=plus_one)
    attn_out, new_cache = L.attention(
        p["attn"], cfg, h, positions=positions, tp=tp, impl=impl,
        window=window, cache=cache, cache_pos=cache_pos, row_map=row_map)
    if cfg.sandwich_norm:
        attn_out = L.rms_norm(attn_out, p["ln_attn_post"], plus_one=True)
    x = x + attn_out
    h = L.rms_norm(x, p["ln_mlp"], plus_one=plus_one)
    if cfg.n_experts:
        mlp_out = L.moe(p["moe"], cfg, h, tp)
    else:
        mlp_out = L.mlp(p["mlp"], h, gelu=cfg.gelu_mlp)
    if cfg.sandwich_norm:
        mlp_out = L.rms_norm(mlp_out, p["ln_mlp_post"], plus_one=True)
    return constrain_activations(x + mlp_out), new_cache


# ---------------------------------------------------------------------------
# Whole model
# ---------------------------------------------------------------------------

def _stack(trees: list[Params]) -> Params:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _n_groups(cfg: ModelConfig) -> int:
    return cfg.n_layers // 2 if cfg.alt_local_global else cfg.n_layers


def init(cfg: ModelConfig, key, tp: int = 1) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = [_block_init(keys[i], cfg, tp, dtype)
              for i in range(cfg.n_layers)]
    if cfg.alt_local_global:
        layers = {"local": _stack(blocks[0::2]), "global": _stack(blocks[1::2])}
    else:
        layers = {"all": _stack(blocks)}
    p: Params = {
        "embed": L.embed_init(keys[-3], cfg, tp, dtype),
        "layers": layers,
        "final_norm": (jnp.zeros if cfg.sandwich_norm else jnp.ones)(
            (cfg.d_model,), dtype),
    }
    if not cfg.name.startswith("gemma"):   # gemma ties head to the embedding
        p["head"] = {"table": L._normal(keys[-2], (cfg.padded(tp).vocab,
                                                   cfg.d_model), 0.02, dtype)}
    return p


def specs(cfg: ModelConfig) -> Params:
    blk = _block_specs(cfg)

    def stacked(tree):
        return jax.tree_util.tree_map(
            lambda s: P(None, *s), tree,
            is_leaf=lambda x: isinstance(x, P))

    if cfg.alt_local_global:
        layers = {"local": stacked(blk), "global": stacked(blk)}
    else:
        layers = {"all": stacked(blk)}
    p: Params = {"embed": L.embed_specs(), "layers": layers,
                 "final_norm": P(None)}
    if not cfg.name.startswith("gemma"):
        p["head"] = L.embed_specs()
    return p


def _run_layers(params, cfg: ModelConfig, x, *, positions, tp, impl,
                caches=None, cache_pos=None, row_map=None):
    """Scan the block stack; returns (x, new_caches).  ``row_map`` is the
    per-slot page table, shared by every paged layer (closure, not scanned)."""
    decode = caches is not None

    def make_body(window):
        def body(carry, xs):
            x = carry
            if decode:
                lp, cache = xs
                x, nc = _block(lp, cfg, x, positions=positions, tp=tp,
                               impl=impl, window=window, cache=cache,
                               cache_pos=cache_pos, row_map=row_map)
                return x, nc
            x, _ = _block(xs, cfg, x, positions=positions, tp=tp,
                          impl=impl, window=window)
            return x, None
        if cfg.remat and not decode:
            return jax.checkpoint(body)
        return body

    if cfg.alt_local_global:
        loc, glo = params["layers"]["local"], params["layers"]["global"]
        body_l = make_body(cfg.local_window)
        body_g = make_body(0)

        def pair(x, xs):
            if decode:
                (lpl, cl), (lpg, cg) = xs
                x, ncl = body_l(x, (lpl, cl))
                x, ncg = body_g(x, (lpg, cg))
                return x, (ncl, ncg)
            lpl, lpg = xs
            x, _ = body_l(x, lpl)
            x, _ = body_g(x, lpg)
            return x, None
        if decode:
            xs = ((loc, caches["local"]), (glo, caches["global"]))
        else:
            xs = (loc, glo)
        x, ys = jax.lax.scan(pair, x, xs)
        new_caches = ({"local": ys[0], "global": ys[1]} if decode else None)
    else:
        window = cfg.local_window
        body = make_body(window)
        xs = (params["layers"]["all"], caches["all"]) if decode \
            else params["layers"]["all"]
        x, ys = jax.lax.scan(body, x, xs)
        new_caches = {"all": ys} if decode else None
    return x, new_caches


def _embed_inputs(params, cfg: ModelConfig, inputs: Params) -> jax.Array:
    scale = cfg.name.startswith("gemma")
    if cfg.embed_inputs:                       # hubert: precomputed frames
        return inputs["frames"]
    x = L.embed(params["embed"], inputs["tokens"], scale=scale)
    if cfg.vis_tokens:                         # internvl2: patch prefix
        x = jnp.concatenate([inputs["patches"].astype(x.dtype), x], axis=1)
    return x


def forward(params: Params, cfg: ModelConfig, inputs: Params, *,
            tp: int = 1, impl: str = "xla") -> jax.Array:
    """Full-sequence forward -> logits (train / prefill / encoder)."""
    x = _embed_inputs(params, cfg, inputs)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, _ = _run_layers(params, cfg, x, positions=positions, tp=tp, impl=impl)
    x = L.rms_norm(x, params["final_norm"], plus_one=cfg.sandwich_norm)
    head = params.get("head", params["embed"])
    return L.unembed(head, x, cfg.vocab, cap=cfg.final_softcap)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, tp: int = 1,
               dtype=jnp.bfloat16) -> Params:
    def one(n, seq):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((n,) + x.shape, x.dtype),
            L.init_kv_cache(cfg, batch, seq, tp, dtype))
    if cfg.alt_local_global:
        n = cfg.n_layers // 2
        # sliding-window layers carry a ring buffer of `window` slots —
        # 8x smaller cache for gemma2 decode_32k (EXPERIMENTS.md §Perf)
        local_seq = min(max_seq, cfg.local_window or max_seq)
        return {"local": one(n, local_seq), "global": one(n, max_seq)}
    return {"all": one(cfg.n_layers, max_seq)}


def init_prefill_cache(cfg: ModelConfig, batch: int, seq: int, tp: int = 1,
                       dtype=jnp.bfloat16) -> Params:
    """Full-length caches for a one-shot slot prefill (DESIGN.md §11).

    Sliding-window layers get the whole sequence rather than their ring:
    during a single-forward prefill every query position must see its exact
    window, or mid-prompt activations (and through them the final token's
    deeper layers) silently degrade.  :func:`pack_slot_cache` folds the
    result back into the serving ring layout afterwards.
    """
    def one(n):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((n,) + x.shape, x.dtype),
            L.init_kv_cache(cfg, batch, seq, tp, dtype))
    if cfg.alt_local_global:
        n = cfg.n_layers // 2
        return {"local": one(n), "global": one(n)}
    return {"all": one(cfg.n_layers)}


def pack_slot_cache(cfg: ModelConfig, pcache: Params, max_seq: int,
                    seq_len: int) -> Params:
    """Repack a batch-1 prefill cache (:func:`init_prefill_cache`, length
    ``seq_len``) into one slot of the serving cache layout: plain KV is
    right-padded to ``max_seq``; sliding-window groups are folded into their
    ring layout (slot ``p % window`` holds position ``p`` of the last
    ``window`` positions, exactly what sequential decode would have left)."""
    if seq_len > max_seq:
        raise ValueError(f"prompt length {seq_len} exceeds max_seq {max_seq}")

    def one(tree, target, use_ring):
        fn = (lambda x: _fold_ring(x, target, seq_len)) if use_ring else \
            (lambda x: _pad_rows(x, target))
        return jax.tree_util.tree_map(fn, tree)

    if cfg.alt_local_global:
        local_seq = min(max_seq, cfg.local_window or max_seq)
        return {"local": one(pcache["local"], local_seq,
                             local_seq == cfg.local_window),
                "global": one(pcache["global"], max_seq, False)}
    return {"all": one(pcache["all"], max_seq, False)}


def _pad_rows(leaf, target):
    if leaf.shape[2] == target:
        return leaf
    widths = [(0, 0)] * leaf.ndim
    widths[2] = (0, target - leaf.shape[2])
    return jnp.pad(leaf, widths)


def _fold_ring(leaf, window, seq_len):
    last = seq_len - 1
    j = np.arange(window)
    p = last - (last - j) % window              # absolute position per slot
    rows = jnp.take(leaf, jnp.asarray(np.clip(p, 0, seq_len - 1)), axis=2)
    valid = jnp.asarray(p >= 0).reshape(
        (1, 1, window) + (1,) * (leaf.ndim - 3))
    return jnp.where(valid, rows, jnp.zeros_like(rows))


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Batch(=slot)-axis index of every cache leaf — the scatter map the
    serving engine uses to write one slot's prefill into the shared cache."""
    one = jax.tree_util.tree_map(lambda _: 1, L.kv_cache_specs(cfg),
                                 is_leaf=lambda x: isinstance(x, P))
    if cfg.alt_local_global:
        return {"local": one, "global": one}
    return {"all": one}


def cache_specs(cfg: ModelConfig) -> Params:
    base = jax.tree_util.tree_map(
        lambda s: P(None, *s), L.kv_cache_specs(cfg),
        is_leaf=lambda x: isinstance(x, P))
    if cfg.alt_local_global:
        return {"local": base, "global": base}
    return {"all": base}


def init_paged_cache(cfg: ModelConfig, slots: int, rows: int, max_seq: int,
                     tp: int = 1, dtype=jnp.bfloat16) -> Params:
    """Paged serving cache (DESIGN.md §12): full-length attention KV lives
    in one physical pool of ``rows`` page-resident rows shared by every
    slot, indexed through the engine's page table.  Sliding-window ring
    layers keep their fixed per-slot ring — a ring is already O(window) per
    slot regardless of request length, so paging it frees nothing."""
    def pool(n):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((n,) + x.shape, x.dtype),
            L.init_paged_kv_pool(cfg, rows, tp, dtype))

    def dense(n, seq):
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((n,) + x.shape, x.dtype),
            L.init_kv_cache(cfg, slots, seq, tp, dtype))

    if cfg.alt_local_global:
        n = cfg.n_layers // 2
        local_seq = min(max_seq, cfg.local_window or max_seq)
        return {"local": dense(n, local_seq), "global": pool(n)}
    return {"all": pool(cfg.n_layers)}


def paged_cache_specs(cfg: ModelConfig) -> Params:
    """Shardings mirroring :func:`init_paged_cache`: pool leaves gain the
    layer axis over the kv-pool specs; gemma2 local rings reuse the dense
    per-slot specs."""
    def stacked(tree):
        return jax.tree_util.tree_map(
            lambda s: P(None, *s), tree, is_leaf=lambda x: isinstance(x, P))

    pool = stacked(L.paged_kv_pool_specs(cfg))
    if cfg.alt_local_global:
        return {"local": stacked(L.kv_cache_specs(cfg)), "global": pool}
    return {"all": pool}


def paged_slot_axes(cfg: ModelConfig) -> Params:
    """Scatter map for the paged cache: ``"pool"`` marks leaves living in
    the shared physical pool (written through page-table rows); ints are
    the slot-axis index of per-slot dense leaves, as in
    :func:`cache_slot_axes`."""
    one = jax.tree_util.tree_map(lambda _: 1, L.kv_cache_specs(cfg),
                                 is_leaf=lambda x: isinstance(x, P))
    pool = jax.tree_util.tree_map(lambda _: "pool", L.kv_cache_specs(cfg),
                                  is_leaf=lambda x: isinstance(x, P))
    if cfg.alt_local_global:
        return {"local": one, "global": pool}
    return {"all": pool}


def pack_paged_slot(cfg: ModelConfig, pcache: Params, max_seq: int,
                    seq_len: int) -> Params:
    """Repack a batch-1 prefill cache for the paged layout: ring leaves are
    folded exactly as in :func:`pack_slot_cache`; pool leaves keep their raw
    ``seq_len`` rows — the engine scatters them at page-table rows, so no
    right-padding to ``max_seq`` ever exists (that padding is the per-slot
    memory the paged engine reclaims)."""
    if seq_len > max_seq:
        raise ValueError(f"prompt length {seq_len} exceeds max_seq {max_seq}")
    if cfg.alt_local_global:
        local_seq = min(max_seq, cfg.local_window or max_seq)
        if local_seq == cfg.local_window:
            local = jax.tree_util.tree_map(
                lambda x: _fold_ring(x, local_seq, seq_len), pcache["local"])
        else:
            local = jax.tree_util.tree_map(
                lambda x: _pad_rows(x, local_seq), pcache["local"])
        return {"local": local, "global": pcache["global"]}
    return {"all": pcache["all"]}


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jax.Array, pos: jax.Array, *, tp: int = 1,
                impl: str = "xla",
                row_map: jax.Array | None = None) -> tuple[jax.Array, Params]:
    """One autoregressive step: tokens (B, S) at per-slot absolute positions
    ``pos`` — (B,) int32, a scalar broadcasts.  S=1 is the serving decode
    step; S>1 is a slot prefill (one causal forward whose K/V land in the
    cache at ``pos .. pos+S-1``).  ``row_map`` (B, L) routes pooled KV
    leaves through the paged engine's page table (DESIGN.md §12)."""
    scale = cfg.name.startswith("gemma")
    x = L.embed(params["embed"], tokens, scale=scale)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None] + jnp.arange(s)
    x, new_cache = _run_layers(params, cfg, x, positions=positions, tp=tp,
                               impl=impl, caches=cache, cache_pos=pos,
                               row_map=row_map)
    x = L.rms_norm(x, params["final_norm"], plus_one=cfg.sandwich_norm)
    head = params.get("head", params["embed"])
    logits = L.unembed(head, x, cfg.vocab, cap=cfg.final_softcap)
    return logits, new_cache
