"""Zamba2 hybrid LM (zamba2-2.7b): Mamba-2 backbone + a *shared* attention
block applied every ``attn_every`` mamba layers (weights reused across
invocations — the Zamba signature trick that buys attention quality at a
fraction of the parameter cost).

Mamba-2 mixer per layer: in_proj -> [z | x | B | C | dt], short causal
depthwise conv on (x|B|C), SSD recurrence via the chunked Pallas kernel with
per-head scalar decay a = dt·(−exp(A_log)), D skip, silu(z) gating, RMS norm,
out_proj.  Decode carries (conv tail, SSD state) — O(1) per token, so the
long_500k cell runs for this family.

Simplification noted in DESIGN.md: the shared block sees the hidden state
only (upstream Zamba2 concatenates the original embeddings) and LoRA
per-invocation adapters are omitted.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.context import constrain_activations
from repro.kernels import ops
from . import layers as L
from .config import ModelConfig

Params = dict[str, Any]
CONV_K = 4


def _dims(cfg: ModelConfig, tp: int):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = cfg.padded(tp).ssm_heads or d_in // cfg.ssm_head_dim
    return d_in, heads, cfg.ssm_state


def _mamba_init(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    d = cfg.d_model
    d_in, h, n = _dims(cfg, tp)
    hp = h * cfg.ssm_head_dim              # padded inner width
    ks = jax.random.split(key, 4)
    conv_dim = hp + 2 * n
    logical_h = (cfg.ssm_expand * d) // cfg.ssm_head_dim
    p = {
        "ln": jnp.ones((d,), dtype),
        # [z (hp) | x (hp) | B (n) | C (n) | dt (h)]
        "in_proj": L._normal(ks[0], (d, 2 * hp + 2 * n + h), d ** -0.5, dtype),
        "conv_w": L._normal(ks[1], (CONV_K, conv_dim), 0.3, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((hp,), dtype),
        "out_proj": L._normal(ks[2], (hp, d), hp ** -0.5, dtype),
    }
    if h > logical_h:  # exact padding: zero out_proj rows for extra heads
        mask = (jnp.arange(h) < logical_h).repeat(cfg.ssm_head_dim)[:, None]
        p["out_proj"] = (p["out_proj"] * mask).astype(dtype)
    return p


def _mamba_specs() -> Params:
    return {
        "ln": P(None), "in_proj": P(L.FSDP, L.TP),
        "conv_w": P(None, L.TP), "conv_b": P(L.TP),
        "a_log": P(L.TP), "dt_bias": P(L.TP), "d_skip": P(L.TP),
        "norm": P(L.TP), "out_proj": P(L.TP, L.FSDP),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array | None = None):
    """Depthwise causal conv, window CONV_K, via shift-and-add.
    x: (B, S, C); tail: (B, CONV_K-1, C) carry for decode.
    Returns (y, new_tail)."""
    if tail is None:
        tail = jnp.zeros((x.shape[0], CONV_K - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([tail, x], axis=1)          # (B, S+K-1, C)
    s = x.shape[1]
    y = sum(ext[:, i:i + s] * w[i] for i in range(CONV_K)) + b
    return jax.nn.silu(y), ext[:, -(CONV_K - 1):]


def _mamba_block(p: Params, cfg: ModelConfig, x, tp: int, impl: str,
                 state: Params | None = None):
    bsz, s, d = x.shape
    d_in, h, n = _dims(cfg, tp)
    hp = h * cfg.ssm_head_dim
    ph = cfg.ssm_head_dim
    st = state or {}

    hx = L.rms_norm(x, p["ln"])
    zxbcdt = hx @ p["in_proj"]
    z = zxbcdt[..., :hp]
    xbc = zxbcdt[..., hp:hp + hp + 2 * n]
    dt_raw = zxbcdt[..., -h:]

    xbc, new_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 st.get("conv"))
    xs = xbc[..., :hp].reshape(bsz, s, h, ph)
    bmat = xbc[..., hp:hp + n]                        # (B, S, N), one group
    cmat = xbc[..., hp + n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    log_decay = -dt * jnp.exp(p["a_log"])             # (B, S, H) <= 0
    x_scaled = (xs.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    bh = jnp.broadcast_to(bmat[:, :, None, :], (bsz, s, h, n)).astype(x.dtype)
    ch = jnp.broadcast_to(cmat[:, :, None, :], (bsz, s, h, n)).astype(x.dtype)

    if s == 1:
        # decode fast path: one SSD recurrence step
        h0 = st.get("ssd")
        if h0 is None:
            h0 = jnp.zeros((bsz, h, n, ph), jnp.float32)
        xf = x_scaled[:, 0].astype(jnp.float32)
        bf, cf = bh[:, 0].astype(jnp.float32), ch[:, 0].astype(jnp.float32)
        h1 = jnp.exp(log_decay[:, 0])[..., None, None] * h0 \
            + bf[..., :, None] * xf[..., None, :]
        y = jnp.einsum("bhn,bhnp->bhp", cf, h1)[:, None].astype(x.dtype)
        new_ssd = h1
    else:
        y, new_ssd = ops.mamba2(x_scaled, log_decay.astype(x.dtype), bh, ch,
                                st.get("ssd"), implementation=impl)

    y = y + xs * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, hp)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    return constrain_activations(x + out), {"conv": new_tail, "ssd": new_ssd}


# ---------------------------------------------------------------------------
# Model: groups of (attn_every mamba blocks) + one shared attention block
# ---------------------------------------------------------------------------

def _n_groups(cfg: ModelConfig) -> int:
    return max(1, cfg.n_layers // max(1, cfg.attn_every))


def init(cfg: ModelConfig, key, tp: int = 1) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, cfg.n_layers + 4)
    blocks = [_mamba_init(keys[i], cfg, tp, dtype)
              for i in range(cfg.n_layers)]
    g = _n_groups(cfg)
    per = cfg.n_layers // g
    grouped = [jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                      *blocks[i * per:(i + 1) * per])
               for i in range(g)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *grouped)
    ks = jax.random.split(keys[-4], 3)
    shared = {
        "ln_attn": jnp.ones((cfg.d_model,), dtype),
        "ln_mlp": jnp.ones((cfg.d_model,), dtype),
        "attn": L.attn_init(ks[0], cfg, tp, dtype),
        "mlp": L.mlp_init(ks[1], cfg, dtype),
    }
    return {
        "embed": L.embed_init(keys[-3], cfg, tp, dtype),
        "layers": stacked,                       # (G, per, ...)
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "head": {"table": L._normal(keys[-2], (cfg.padded(tp).vocab,
                                               cfg.d_model), 0.02, dtype)},
    }


def specs(cfg: ModelConfig) -> Params:
    blk = jax.tree_util.tree_map(lambda s: P(None, None, *s), _mamba_specs(),
                                 is_leaf=lambda x: isinstance(x, P))
    shared = {"ln_attn": P(None), "ln_mlp": P(None),
              "attn": L.attn_specs(cfg), "mlp": L.mlp_specs()}
    return {"embed": L.embed_specs(), "layers": blk, "shared": shared,
            "final_norm": P(None), "head": L.embed_specs()}


def _shared_attn(shared: Params, cfg: ModelConfig, x, *, positions, tp, impl,
                 cache=None, cache_pos=None, row_map=None):
    h = L.rms_norm(x, shared["ln_attn"])
    att, new_cache = L.attention(shared["attn"], cfg, h, positions=positions,
                                 tp=tp, impl=impl, cache=cache,
                                 cache_pos=cache_pos, row_map=row_map)
    x = x + att
    x = x + L.mlp(shared["mlp"], L.rms_norm(x, shared["ln_mlp"]))
    return x, new_cache


def forward(params, cfg: ModelConfig, inputs, *, tp: int = 1,
            impl: str = "xla") -> jax.Array:
    x = L.embed(params["embed"], inputs["tokens"])
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    shared = params["shared"]

    def inner(x, lp):
        x, _ = _mamba_block(lp, cfg, x, tp, impl)
        return x, None

    if cfg.remat:  # per-block remat: one block's working set at a time
        inner = jax.checkpoint(inner)

    def group(x, gp):
        x, _ = jax.lax.scan(inner, x, gp)
        x, _ = _shared_attn(shared, cfg, x, positions=positions, tp=tp,
                            impl=impl)
        return x, None

    if cfg.remat:
        group = jax.checkpoint(group)
    x, _ = jax.lax.scan(group, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"])
    return L.unembed(params["head"], x, cfg.vocab)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, tp: int = 1,
               dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    d_in, h, n = _dims(cfg, tp)
    hp = h * cfg.ssm_head_dim
    g = _n_groups(cfg)
    per = cfg.n_layers // g
    return {
        "conv": jnp.zeros((g, per, batch, CONV_K - 1, hp + 2 * n), dtype),
        "ssd": jnp.zeros((g, per, batch, h, n, cfg.ssm_head_dim),
                         jnp.float32),
        "attn": jax.tree_util.tree_map(
            lambda x: jnp.zeros((g,) + x.shape, x.dtype),
            L.init_kv_cache(cfg, batch, max_seq, tp, dtype)),
    }


def cache_specs(cfg: ModelConfig) -> Params:
    kv = jax.tree_util.tree_map(lambda s: P(None, *s), L.kv_cache_specs(cfg),
                                is_leaf=lambda x: isinstance(x, P))
    return {"conv": P(None, None, L.BATCH_AXES, None, L.TP),
            "ssd": P(None, None, L.BATCH_AXES, L.TP, None, None),
            "attn": kv}


def init_prefill_cache(cfg: ModelConfig, batch: int, seq: int, tp: int = 1,
                       dtype=None) -> Params:
    """Batch-1 prefill caches (DESIGN.md §11): conv/SSD states are O(1) in
    sequence length, only the shared-attention KV needs the prompt length."""
    return init_cache(cfg, batch, seq, tp, dtype)


def pack_slot_cache(cfg: ModelConfig, pcache: Params, max_seq: int,
                    seq_len: int) -> Params:
    """Repack a batch-1 prefill cache into one serving slot: recurrent
    conv/SSD states carry over as-is, the attention KV pads to ``max_seq``."""
    if seq_len > max_seq:
        raise ValueError(f"prompt length {seq_len} exceeds max_seq {max_seq}")

    def pad(leaf):
        widths = [(0, 0)] * leaf.ndim
        widths[2] = (0, max_seq - leaf.shape[2])
        return jnp.pad(leaf, widths)

    return {"conv": pcache["conv"], "ssd": pcache["ssd"],
            "attn": jax.tree_util.tree_map(pad, pcache["attn"])}


def cache_slot_axes(cfg: ModelConfig) -> Params:
    """Batch(=slot)-axis index of every cache leaf (serving scatter map)."""
    return {"conv": 2, "ssd": 2,
            "attn": jax.tree_util.tree_map(lambda _: 1,
                                           L.kv_cache_specs(cfg),
                                           is_leaf=lambda x: isinstance(x, P))}


def init_paged_cache(cfg: ModelConfig, slots: int, rows: int, max_seq: int,
                     tp: int = 1, dtype=None) -> Params:
    """Paged serving cache (DESIGN.md §12): conv/SSD states stay per-slot
    (O(1) in sequence length — nothing to page), the shared-attention KV
    moves into one physical pool of ``rows`` rows shared across slots."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    d_in, h, n = _dims(cfg, tp)
    hp = h * cfg.ssm_head_dim
    g = _n_groups(cfg)
    per = cfg.n_layers // g
    return {
        "conv": jnp.zeros((g, per, slots, CONV_K - 1, hp + 2 * n), dtype),
        "ssd": jnp.zeros((g, per, slots, h, n, cfg.ssm_head_dim),
                         jnp.float32),
        "attn": jax.tree_util.tree_map(
            lambda x: jnp.zeros((g,) + x.shape, x.dtype),
            L.init_paged_kv_pool(cfg, rows, tp, dtype)),
    }


def paged_cache_specs(cfg: ModelConfig) -> Params:
    """Shardings mirroring :func:`init_paged_cache`: per-slot conv/SSD
    states keep their dense specs (minus the layer axis, plus the group
    axes); the pooled attention KV gains the group axis over the kv-pool
    specs."""
    pool = jax.tree_util.tree_map(
        lambda s: P(None, *s), L.paged_kv_pool_specs(cfg),
        is_leaf=lambda x: isinstance(x, P))
    return {"conv": P(None, None, L.BATCH_AXES, None, L.TP),
            "ssd": P(None, None, L.BATCH_AXES, L.TP, None, None),
            "attn": pool}


def paged_slot_axes(cfg: ModelConfig) -> Params:
    """Scatter map for the paged cache: ``"pool"`` marks pooled KV leaves,
    ints the slot-axis of per-slot recurrent leaves."""
    return {"conv": 2, "ssd": 2,
            "attn": jax.tree_util.tree_map(lambda _: "pool",
                                           L.kv_cache_specs(cfg),
                                           is_leaf=lambda x: isinstance(x, P))}


def pack_paged_slot(cfg: ModelConfig, pcache: Params, max_seq: int,
                    seq_len: int) -> Params:
    """Paged repack: recurrent states carry as-is; attention KV keeps its
    raw ``seq_len`` rows for the engine's page-table scatter (no padding)."""
    if seq_len > max_seq:
        raise ValueError(f"prompt length {seq_len} exceeds max_seq {max_seq}")
    return pcache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *,
                tp: int = 1, impl: str = "xla", row_map=None):
    """Decode ``tokens (B, S)`` at per-slot positions ``pos`` ((B,) int32,
    scalar broadcasts); S>1 is a slot prefill.  ``row_map`` (B, L) routes
    the pooled attention KV through the paged engine's page table."""
    x = L.embed(params["embed"], tokens)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None] + jnp.arange(s)
    shared = params["shared"]

    def inner(x, xs):
        lp, st = xs
        x, ns = _mamba_block(lp, cfg, x, tp, impl, state=st)
        return x, ns

    def group(x, xs):
        gp, gconv, gssd, gattn = xs
        x, ns = jax.lax.scan(inner, x, (gp, {"conv": gconv, "ssd": gssd}))
        x, nattn = _shared_attn(shared, cfg, x, positions=positions, tp=tp,
                                impl=impl, cache=gattn, cache_pos=pos,
                                row_map=row_map)
        return x, (ns["conv"], ns["ssd"], nattn)

    x, (nconv, nssd, nattn) = jax.lax.scan(
        group, x, (params["layers"], cache["conv"], cache["ssd"],
                   cache["attn"]))
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(params["head"], x, cfg.vocab)
    return logits, {"conv": nconv, "ssd": nssd, "attn": nattn}
