"""Model configuration shared by every architecture family.

Configs store the *published* logical dimensions; tensor-parallel padding
(zero q-heads, replicated kv-heads, −inf-routed experts, masked vocab rows)
is computed at model-build time from the mesh's model-axis size so that
smoke tests (tp=1) run the exact published config (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


def pad_to(n: int, multiple: int) -> int:
    return -(-n // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | rwkv6 | zamba2 | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention options
    qk_norm: bool = False                 # qwen3
    attn_softcap: float = 0.0             # gemma2
    final_softcap: float = 0.0            # gemma2
    local_window: int = 0                 # gemma2 alternating local/global
    alt_local_global: bool = False
    causal: bool = True                   # False for encoders
    rope_theta: float = 10_000.0
    sandwich_norm: bool = False           # gemma2 pre+post norms
    gelu_mlp: bool = False                # gemma2 / hubert MLPs

    # MoE options
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_int8_dispatch: bool = False   # quantize dispatch-buffer collectives

    # SSM / RWKV options
    ssm_state: int = 0                    # zamba2 mamba2 state size
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    attn_every: int = 0                   # zamba2: shared attn every N blocks

    # modality stub (vlm / audio): input is precomputed embeddings
    vis_tokens: int = 0                   # internvl2 patch-embedding prefix
    embed_inputs: bool = False            # hubert: frames arrive as embeddings

    # numerics
    dtype: str = "bfloat16"
    remat: bool = True
    kv_int8: bool = False   # int8-quantized KV cache (per-token/head scales)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.n_heads))

    # -- tensor-parallel padding -------------------------------------------------
    def padded(self, tp: int) -> "PaddedDims":
        return PaddedDims(
            n_heads=pad_to(self.n_heads, tp) if self.n_heads else 0,
            n_kv_heads=pad_to(self.n_kv_heads, tp) if self.n_kv_heads else 0,
            vocab=pad_to(self.vocab, tp),
            n_experts=pad_to(self.n_experts, tp) if self.n_experts else 0,
            rwkv_heads=pad_to(self.d_model // self.rwkv_head_dim, tp)
            if self.family == "rwkv6" else 0,
            ssm_heads=pad_to(self.ssm_expand * self.d_model
                             // self.ssm_head_dim, tp)
            if self.family == "zamba2" else 0,
        )

    def params_dense(self) -> int:
        """Approximate dense parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, l = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        if self.family == "rwkv6":
            attn = 6 * d * d // 1  # r,k,v,w(lora),g,o approx
        if self.family == "zamba2":
            din = self.ssm_expand * d
            attn = d * din * 2 + din * d + 2 * din * self.ssm_state
        mlp = 3 * d * self.d_ff if not self.gelu_mlp else 2 * d * self.d_ff
        if self.n_experts:
            mlp = self.n_experts * 3 * d * self.moe_d_ff + d * self.n_experts
        emb = self.vocab * d * 2  # embed + unembed
        return l * (attn + mlp) + emb

    def params_active(self) -> int:
        """Active params per token (= N for dense; routed subset for MoE)."""
        if not self.n_experts:
            return self.params_dense()
        d, l = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        mlp = self.top_k * 3 * d * self.moe_d_ff + d * self.n_experts
        emb = self.vocab * d * 2
        return l * (attn + mlp) + emb


@dataclass(frozen=True)
class PaddedDims:
    n_heads: int
    n_kv_heads: int
    vocab: int
    n_experts: int
    rwkv_heads: int = 0
    ssm_heads: int = 0


@dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""

    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=2, d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128, vocab=256, head_dim=16 if cfg.n_heads else 0,
        vis_tokens=4 if cfg.vis_tokens else 0,
    )
    if cfg.n_experts:
        # generous capacity: smoke tests check prefill/decode equivalence,
        # which token dropping would break
        base.update(n_experts=4, top_k=2, moe_d_ff=32, capacity_factor=4.0)
    if cfg.family == "rwkv6":
        base.update(rwkv_head_dim=16)
    if cfg.family == "zamba2":
        base.update(ssm_state=8, ssm_head_dim=16, attn_every=2,
                    n_heads=4, n_kv_heads=4)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
