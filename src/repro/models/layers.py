"""Shared layer library for all architecture families.

Conventions:
  * params are nested dicts of jnp arrays; every init has a twin ``*_specs``
    returning the same tree with PartitionSpec leaves (tested for structural
    equality) — the dry-run shards straight from these.
  * ``tp`` (model-axis size) drives exactness-preserving padding of heads /
    kv-heads / experts / vocab (DESIGN.md §5).
  * ``impl`` selects the compute path: 'xla' (dry-run/roofline), 'interpret'
    (Pallas correctness on CPU), 'pallas' (real TPU).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from .config import ModelConfig

Params = dict[str, Any]

# logical mesh axes (DESIGN.md §6): batch over (pod, data), tensor over model
BATCH_AXES = ("pod", "data")
FSDP = "data"
TP = "model"


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms / activations / rope
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (xf * scale).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotary on last dim; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    v = cfg.padded(tp).vocab
    return {"table": _normal(key, (v, cfg.d_model), 0.02, dtype)}


def embed_specs() -> Params:
    # vocab over TP, d_model over FSDP: embedding optimizer moments are the
    # single biggest per-device residents otherwise (dry-run probe evidence)
    return {"table": P(TP, FSDP)}


def embed(params: Params, tokens: jax.Array, scale: bool = False) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    if scale:
        out = out * math.sqrt(out.shape[-1])
    return out


def unembed(params: Params, x: jax.Array, vocab: int,
            cap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("bsd,vd->bsv", x, params["table"])
    logits = softcap(logits, cap)
    v_pad = params["table"].shape[0]
    if v_pad > vocab:  # padded vocab rows never win
        mask = jnp.arange(v_pad) < vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# Dense attention (GQA + qk-norm + softcap + sliding window + KV cache)
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    pd = cfg.padded(tp)
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = pd.n_heads, pd.n_kv_heads
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": _normal(ks[0], (d, h * hd), sc, dtype),
        "wk": _normal(ks[1], (d, kv * hd), sc, dtype),
        "wv": _normal(ks[2], (d, kv * hd), sc, dtype),
        "wo": _normal(ks[3], (h * hd, d), (h * hd) ** -0.5, dtype),
    }
    # zero the padded q-heads' output rows -> exact at initialization
    if h > cfg.n_heads:
        mask = (jnp.arange(h) < cfg.n_heads).repeat(hd)[:, None]
        p["wo"] = (p["wo"] * mask).astype(dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attn_specs(cfg: ModelConfig) -> Params:
    p = {"wq": P(FSDP, TP), "wk": P(FSDP, TP), "wv": P(FSDP, TP),
         "wo": P(TP, FSDP)}
    if cfg.qk_norm:
        p["q_norm"] = P(None)
        p["k_norm"] = P(None)
    return p


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def gather_pages(pool: jax.Array, row_map: jax.Array) -> jax.Array:
    """Page-table gather: physical pool ``(R, ...)`` -> per-slot dense view
    ``(B, L, ...)`` where row ``i`` of slot ``b`` is ``pool[row_map[b, i]]``.
    Unmapped rows (``-1``) read as zeros, so the view is bit-identical to
    the dense ``(B, L, ...)`` cache layout the non-paged engine carries."""
    safe = jnp.where(row_map >= 0, row_map, 0)
    rows = pool[safe]
    valid = (row_map >= 0).reshape(row_map.shape + (1,) * (pool.ndim - 1))
    return jnp.where(valid, rows, jnp.zeros((), pool.dtype))


def attention(params: Params, cfg: ModelConfig, x: jax.Array, *,
              positions: jax.Array, tp: int, impl: str,
              window: int = 0, cache: Params | None = None,
              cache_pos: jax.Array | None = None,
              row_map: jax.Array | None = None):
    """Returns (out, new_cache).  cache = {'k','v'}: (B, S_max, KV, hd) —
    or, when ``row_map`` is given and the leaves are 3-D, a paged physical
    pool (R, KV, hd) indexed through the (B, L) page table (DESIGN.md §12)."""
    pd = cfg.padded(tp)
    h, kv, hd = pd.n_heads, pd.n_kv_heads, cfg.head_dim
    rep = max(1, kv // max(1, cfg.n_kv_heads))  # kv replication factor

    q = _split_heads(x @ params["wq"], h, hd)
    k = _split_heads(x @ params["wk"], kv, hd)
    v = _split_heads(x @ params["wv"], kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is not None:
        # decode: insert current k/v, attend over the prefix.  Sliding-window
        # layers may carry a ring buffer of `window` slots (slot = pos % W);
        # absolute slot positions reconstruct the mask (§Perf, gemma2 decode).
        # ``cache_pos`` is per-slot — a (B,) vector of absolute write
        # positions (a scalar broadcasts) — so co-scheduled requests at
        # different depths each write and mask at their own position
        # (DESIGN.md §11).  A 3-D cache leaf is a paged pool: writes and
        # reads route through ``row_map``; ring leaves stay dense (a ring is
        # already O(window) per slot), so one model can mix both.
        paged = row_map is not None and cache["k"].ndim == 3
        cache_len = row_map.shape[1] if paged else cache["k"].shape[1]
        ring = not paged and window > 0 and cache_len == window
        bsz, sq = q.shape[0], q.shape[1]
        cpos = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (bsz,))
        b_idx = jnp.arange(bsz)[:, None]

        def put(name, val):
            s = val.shape[1]
            if ring and s >= window:
                val = val[:, s - window:]   # a full wrap keeps only the tail
            kept = val.shape[1]
            rows = cpos[:, None] + (s - kept) + jnp.arange(kept)
            if paged:
                # logical row -> physical pool row via the slot's page
                # table.  Rows past the table (a parked slot) and unmapped
                # (-1) entries redirect to index R: negative indices WRAP
                # under mode="drop" (only >= size is out of bounds), so -1
                # would silently stomp the last pool row
                pool_rows = cache[name].shape[0]
                safe = jnp.clip(rows, 0, cache_len - 1)
                prow = jnp.take_along_axis(row_map, safe, axis=1)
                prow = jnp.where((rows < cache_len) & (prow >= 0), prow,
                                 pool_rows)
                return cache[name].at[prow].set(
                    val.astype(cache[name].dtype), mode="drop")
            if ring:
                rows = rows % window
            # out-of-range rows (a retired slot parked past its budget) are
            # dropped rather than clamped onto the last row
            return cache[name].at[b_idx, rows].set(
                val.astype(cache[name].dtype), mode="drop")

        def full(name):
            """Dense (B, L, ...) view of the updated cache leaf."""
            if paged:
                return gather_pages(new_cache[name], row_map)
            return new_cache[name]

        if "k_scale" in cache:   # int8 KV: per-(token, head) scales
            def quant(z):
                sc = jnp.max(jnp.abs(z.astype(jnp.float32)), axis=-1,
                             keepdims=True) / 127.0 + 1e-12
                return jnp.round(z.astype(jnp.float32) / sc
                                 ).astype(jnp.int8), sc[..., 0]
            kq, ks = quant(k)
            vq, vs = quant(v)
            new_cache = {"k": put("k", kq), "v": put("v", vq),
                         "k_scale": put("k_scale", ks),
                         "v_scale": put("v_scale", vs)}
            ck = full("k").astype(jnp.float32) * full("k_scale")[..., None]
            cv = full("v").astype(jnp.float32) * full("v_scale")[..., None]
        else:
            new_cache = {"k": put("k", k), "v": put("v", v)}
            ck, cv = full("k"), full("v")

        last = cpos + sq - 1                                 # (B,)
        if ring:
            slots = jnp.arange(cache_len)
            kpos = last[:, None] - jax.lax.rem(
                (last[:, None] - slots) % window + window, jnp.int32(window))
            out = _decode_attention(q, ck, cv, cfg, last, 0, kpos=kpos)
        else:
            out = _decode_attention(q, ck, cv, cfg, last, window)
    else:
        out = ops.attention(q, k, v, causal=cfg.causal,
                            softcap=cfg.attn_softcap, window=window,
                            implementation=impl)
        new_cache = None

    out = out.reshape(x.shape[0], x.shape[1], h * hd)
    return out @ params["wo"], new_cache


def _decode_attention(q, ck, cv, cfg: ModelConfig, last_pos, window: int,
                      kpos: jax.Array | None = None):
    """Single/few-token query against a (partially filled) cache.  Memory
    bound — the XLA einsum path with explicit position masking is the right
    tool; positions beyond ``last_pos`` are masked.  ``last_pos`` is per-slot
    ((B,) — a scalar broadcasts) so every sequence in the batch masks at its
    own absolute depth; ``kpos`` ((B, S_kv)) overrides slot positions
    (ring-buffer caches)."""
    b, sq, h, hd = q.shape
    skv, kvh = ck.shape[1], ck.shape[2]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, sq, kvh, g, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, ck.astype(jnp.float32))
    logits *= hd ** -0.5
    logits = softcap(logits, cfg.attn_softcap)
    last_pos = jnp.broadcast_to(jnp.asarray(last_pos, jnp.int32), (b,))
    kpos = (jnp.broadcast_to(jnp.arange(skv), (b, skv)) if kpos is None
            else jnp.broadcast_to(kpos, (b, skv)))[:, None, :]  # (B, 1, Skv)
    qpos = (last_pos[:, None] - (sq - 1) + jnp.arange(sq))[..., None]
    mask = (kpos <= qpos) & (kpos >= 0)                   # (B, Sq, Skv)
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, cv.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, tp: int,
                  dtype=jnp.bfloat16) -> Params:
    pd = cfg.padded(tp)
    shape = (batch, max_seq, pd.n_kv_heads, cfg.head_dim)
    if cfg.kv_int8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv_pool(cfg: ModelConfig, rows: int, tp: int,
                       dtype=jnp.bfloat16) -> Params:
    """Physical KV pool of ``rows`` page-resident rows shared by every slot
    (DESIGN.md §12).  Same leaf set as :func:`init_kv_cache` minus the slot
    axis: the engine's page table supplies the slot -> row indirection."""
    pd = cfg.padded(tp)
    shape = (rows, pd.n_kv_heads, cfg.head_dim)
    if cfg.kv_int8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:2], jnp.float32),
                "v_scale": jnp.zeros(shape[:2], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_specs(cfg: ModelConfig | None = None) -> Params:
    base = {"k": P(BATCH_AXES, None, TP, None),
            "v": P(BATCH_AXES, None, TP, None)}
    if cfg is not None and cfg.kv_int8:
        base["k_scale"] = P(BATCH_AXES, None, TP)
        base["v_scale"] = P(BATCH_AXES, None, TP)
    return base


def paged_kv_pool_specs(cfg: ModelConfig | None = None) -> Params:
    """Shardings for :func:`init_paged_kv_pool` leaves (rows, kv_heads, hd):
    kv-heads shard over TP like the dense cache; the physical-row axis stays
    replicated — rows are addressed by the host-side page table, which must
    see every row on every shard."""
    base = {"k": P(None, TP, None), "v": P(None, TP, None)}
    if cfg is not None and cfg.kv_int8:
        base["k_scale"] = P(None, TP)
        base["v_scale"] = P(None, TP)
    return base


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": _normal(ks[1], (d, f), d ** -0.5, dtype),
         "w_down": _normal(ks[2], (f, d), f ** -0.5, dtype)}
    p["w_gate"] = _normal(ks[0], (d, f), d ** -0.5, dtype)
    return p


def mlp_specs() -> Params:
    return {"w_gate": P(FSDP, TP), "w_up": P(FSDP, TP),
            "w_down": P(TP, FSDP)}


def mlp(params: Params, x: jax.Array, gelu: bool = False) -> jax.Array:
    act = jax.nn.gelu if gelu else jax.nn.silu
    h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k router, capacity dispatch, expert parallelism)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig, tp: int, dtype) -> Params:
    pd = cfg.padded(tp)
    e, d, f = pd.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    # padded experts are routed -inf -> never selected (exact); strong f32
    # so the aval matches a checkpoint round-trip (no weak-type cache split)
    mask = jnp.where(jnp.arange(e) < cfg.n_experts, 0.0, -1e30) \
        .astype(jnp.float32)
    return {
        "router": _normal(ks[0], (d, e), d ** -0.5, jnp.float32),
        "router_mask": mask,
        "w_gate": _normal(ks[1], (e, d, f), d ** -0.5, dtype),
        "w_up": _normal(ks[2], (e, d, f), d ** -0.5, dtype),
        "w_down": _normal(ks[3], (e, f, d), f ** -0.5, dtype),
    }


def moe_specs() -> Params:
    return {"router": P(None, TP), "router_mask": P(TP),
            "w_gate": P(TP, FSDP, None), "w_up": P(TP, FSDP, None),
            "w_down": P(TP, None, FSDP)}


def _dispatch_group(x2, logits, k, cap):
    """Group-local top-k routing + capacity scatter.  x2: (T, d);
    logits: (T, E).  Returns (buf (E, cap, d), flat_e, slot, keep, gates)."""
    t, d = x2.shape
    e = logits.shape[-1]
    top_vals, top_idx = jax.lax.top_k(logits, k)              # (T, K)
    gates = jax.nn.softmax(top_vals, axis=-1).astype(x2.dtype)
    flat_e = top_idx.reshape(-1)                              # (T*K,) token-major
    # position-within-expert via stable argsort ranking: O(n log n), versus
    # the classic (T·K, E) one-hot cumsum that XLA lowers quadratically
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    start = jnp.searchsorted(sorted_e, jnp.arange(e))         # first slot per e
    pos_sorted = jnp.arange(t * k) - start[sorted_e]
    mypos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = (mypos < cap)[:, None].astype(x2.dtype)
    slot = jnp.minimum(mypos, cap - 1)
    xrep = jnp.broadcast_to(x2[:, None, :], (t, k, d)).reshape(t * k, d)
    buf = jnp.zeros((e, cap, d), x2.dtype).at[flat_e, slot].add(xrep * keep)
    return buf, flat_e, slot, keep, gates


def moe(params: Params, cfg: ModelConfig, x: jax.Array, tp: int) -> jax.Array:
    """Grouped capacity-dispatch MoE (DESIGN.md §5, EXPERIMENTS.md §Perf).

    Routing, ranking and the capacity scatter run *per batch-group* (vmap
    over the batch dim, which is data-sharded) so every token-indexed op
    stays shard-local; only the expert einsums communicate (buf grouped over
    'data' × experts over 'model').  The original global-token scatter made
    GSPMD replicate the dispatch — 23 TB/device of wire on granite train_4k;
    grouping removes ~all of it.  FLOPs still scale with top_k·T (capacity
    1.25×), not E·T.
    """
    b, s, d = x.shape
    e = params["w_gate"].shape[0]
    k = cfg.top_k
    cap = max(8, int(math.ceil(s * k / e * cfg.capacity_factor)))

    logits = (jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                         params["router"]) + params["router_mask"])
    buf, flat_e, slot, keep, gates = jax.vmap(
        functools.partial(_dispatch_group, k=k, cap=cap))(
        x.reshape(b, s, d), logits)                          # buf: (B,E,cap,d)

    from repro.distributed.context import constrain
    gspec = P(("pod", FSDP), TP, None, None)                 # groups x experts
    if cfg.moe_int8_dispatch:
        # quantize the dispatch buffer so the group->expert resharding moves
        # int8 (halves the dominant MoE collectives; EXPERIMENTS.md §Perf)
        scale = jnp.max(jnp.abs(buf.astype(jnp.float32)),
                        axis=-1, keepdims=True) / 127.0 + 1e-12
        q = jnp.round(buf.astype(jnp.float32) / scale).astype(jnp.int8)
        q = constrain(q, gspec)
        scale = constrain(scale.astype(jnp.float32), gspec)
        buf = (q.astype(jnp.float32) * scale).astype(x.dtype)
    buf = constrain(buf, gspec)
    h = (jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"]))
         * jnp.einsum("becd,edf->becf", buf, params["w_up"]))
    h = constrain(h, P(("pod", FSDP), TP, None, None))
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    # re-shard group-local (full E per group) BEFORE the token gather: the
    # gather indexes the expert dim, which would otherwise all-reduce a full
    # activation per layer (EXPERIMENTS.md §Perf iteration 2)
    out_buf = constrain(out_buf, P(("pod", FSDP), None, None, None))

    def gather_group(out_b, flat_e_b, slot_b, keep_b, gates_b):
        out_tok = out_b[flat_e_b, slot_b] * keep_b           # (S*K, d)
        return (out_tok.reshape(s, k, d) * gates_b[..., None]).sum(axis=1)

    out = jax.vmap(gather_group)(out_buf, flat_e, slot, keep, gates)
    return out.astype(x.dtype)
