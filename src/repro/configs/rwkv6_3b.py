"""rwkv6-3b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv6",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab=65536, rwkv_head_dim=64,
)
