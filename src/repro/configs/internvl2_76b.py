"""internvl2-76b [vlm] — InternViT frontend (STUB: precomputed patch
embeddings) + llama3-70b-class LLM backbone [arXiv:2404.16821; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128, rope_theta=500_000.0,
    vis_tokens=256,
)
