"""Architecture registry: one module per assigned architecture
(``--arch <id>`` in the launchers), plus the paper's own CNN workload sets
(``repro.core.workloads.cnn_set``)."""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeCell, reduced

ARCH_IDS = (
    "deepseek-coder-33b", "deepseek-67b", "qwen3-8b", "gemma2-2b",
    "granite-moe-3b-a800m", "moonshot-v1-16b-a3b", "internvl2-76b",
    "rwkv6-3b", "zamba2-2.7b", "hubert-xlarge",
)


def _module_name(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch)}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# Shape-cell applicability (DESIGN.md §5: 31 runnable cells + 9 skips)
# ---------------------------------------------------------------------------

_SKIPS: dict[tuple[str, str], str] = {}
for _a in ("deepseek-coder-33b", "deepseek-67b", "qwen3-8b", "internvl2-76b"):
    _SKIPS[(_a, "long_500k")] = "pure full attention (quadratic context)"
_SKIPS[("gemma2-2b", "long_500k")] = \
    "global layers in the local/global alternation are full attention"
for _a in ("granite-moe-3b-a800m", "moonshot-v1-16b-a3b"):
    _SKIPS[(_a, "long_500k")] = "full-attention MoE"
_SKIPS[("hubert-xlarge", "long_500k")] = "encoder-only: no autoregressive step"
_SKIPS[("hubert-xlarge", "decode_32k")] = "encoder-only: no autoregressive step"


def cell_skip_reason(arch: str, shape: str) -> str | None:
    return _SKIPS.get((arch, shape))


def runnable_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES
            if (a, s) not in _SKIPS]


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
