"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks,
ssm_state=64 [arXiv:2411.15242; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="zamba2",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, attn_every=6,
)
