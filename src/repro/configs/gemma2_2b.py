"""gemma2-2b [dense] — local+global alternating attention, logit softcaps,
sandwich norms, tied embeddings [arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_ff=9216, vocab=256000, head_dim=256,
    attn_softcap=50.0, final_softcap=30.0,
    local_window=4096, alt_local_global=True,
    sandwich_norm=True, gelu_mlp=True,
)
