"""Unified tracing + metrics layer (DESIGN.md §13).

Off by default.  One module-level singleton (:func:`state`) guards every
instrumentation site in serving, co-design, and the tuner:

  * **disabled** (the default) — :func:`state` returns ``None`` and
    :func:`span`/:func:`instant` hand back a shared no-op, so an
    uninstrumented run pays one global read + ``is not None`` per site and
    allocates nothing (call sites that build ``args`` dicts or touch
    metrics must sit behind an ``if st is not None`` guard — the decode hot
    path's zero-allocation contract, gated by ``benchmarks/bench_obs.py``);
  * **enabled** (:func:`enable`) — spans land in a preallocated ring buffer
    (:mod:`repro.obs.trace`), instruments in a
    :class:`~repro.obs.metrics.MetricsRegistry`, and :func:`snapshot` /
    :func:`export_telemetry` / :func:`export_chrome_trace` turn the session
    into a schema-versioned ``artifacts/telemetry.json`` plus a
    Perfetto-viewable trace.

Instrumentation idioms::

    from repro import obs

    with obs.span("serve.decode_step"):      # no-op CM when disabled
        ...
    st = obs.state()
    if st is not None:                       # guard dict/metric work
        st.tracer.instant("req.retire", {"rid": rid})
        st.metrics.counter("serve.preemptions").inc()
"""
from __future__ import annotations

from .metrics import (DEFAULT_COUNT_EDGES, DEFAULT_TIME_EDGES, Counter,
                      Gauge, Histogram, MetricsRegistry, geometric_edges,
                      linear_edges)
from .trace import NULL_SPAN, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "ObsState",
    "Tracer", "DEFAULT_COUNT_EDGES", "DEFAULT_TIME_EDGES", "disable",
    "enable", "enabled", "export_chrome_trace", "export_telemetry",
    "geometric_edges", "instant", "linear_edges", "snapshot", "span",
    "state",
]


class ObsState:
    """One observability session: a tracer and a metrics registry."""

    __slots__ = ("tracer", "metrics")

    def __init__(self, capacity: int = 65536):
        self.tracer = Tracer(capacity)
        self.metrics = MetricsRegistry()


_STATE: ObsState | None = None


def enable(capacity: int = 65536) -> ObsState:
    """Start a fresh observability session (replacing any previous one)."""
    global _STATE
    _STATE = ObsState(capacity)
    return _STATE


def disable() -> None:
    """Back to no-op mode; the previous session's data is dropped."""
    global _STATE
    _STATE = None


def enabled() -> bool:
    return _STATE is not None


def state() -> ObsState | None:
    """The live session, or ``None`` — THE guard every hot path checks."""
    return _STATE


def span(name: str, args: dict | None = None):
    """A span context manager, or the shared no-op when disabled."""
    st = _STATE
    if st is None:
        return NULL_SPAN
    return st.tracer.span(name, args)


def instant(name: str, args: dict | None = None) -> None:
    st = _STATE
    if st is not None:
        st.tracer.instant(name, args)


def snapshot() -> dict:
    """Schema-versioned telemetry document for the live session."""
    if _STATE is None:
        raise RuntimeError("observability is disabled; call obs.enable()")
    from .export import snapshot as _snapshot
    return _snapshot(_STATE.tracer, _STATE.metrics)


def export_telemetry(path=None):
    """Write ``artifacts/telemetry.json`` (atomic); returns the path."""
    if _STATE is None:
        raise RuntimeError("observability is disabled; call obs.enable()")
    from .export import DEFAULT_TELEMETRY_PATH, export_telemetry as _export
    return _export(_STATE.tracer, _STATE.metrics,
                   path if path is not None else DEFAULT_TELEMETRY_PATH)


def export_chrome_trace(path=None):
    """Write the Perfetto-viewable Chrome trace; returns the path."""
    if _STATE is None:
        raise RuntimeError("observability is disabled; call obs.enable()")
    from .export import DEFAULT_TRACE_PATH, export_chrome_trace as _export
    return _export(_STATE.tracer,
                   path if path is not None else DEFAULT_TRACE_PATH)
