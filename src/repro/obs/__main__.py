"""Telemetry schema validator CLI (CI's ``obs-smoke`` gate):

    PYTHONPATH=src python -m repro.obs artifacts/telemetry.json

Exits non-zero (listing the defects) when the artifact drifts from the
schema ``repro.obs.export`` writes.
"""
from __future__ import annotations

import argparse
import json
import sys

from .export import DEFAULT_TELEMETRY_PATH, validate_telemetry_file


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default=str(DEFAULT_TELEMETRY_PATH),
                    help="telemetry artifact to validate")
    args = ap.parse_args()

    errs = validate_telemetry_file(args.path)
    if errs:
        for e in errs:
            print(f"INVALID {args.path}: {e}", file=sys.stderr)
        raise SystemExit(1)
    doc = json.loads(open(args.path).read())
    tr, met = doc["trace"], doc["metrics"]
    print(f"OK {args.path}: schema v{doc['schema_version']}, "
          f"{len(tr['events'])} events ({tr['dropped']} dropped), "
          f"{len(met['counters'])} counters, {len(met['gauges'])} gauges, "
          f"{len(met['histograms'])} histograms")


if __name__ == "__main__":
    main()
