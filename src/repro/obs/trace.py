"""Span tracer over a preallocated ring buffer (DESIGN.md §13).

Events are plain tuples written into a fixed-size list — recording a span is
two ``perf_counter_ns`` reads, one tuple build, and one list-slot store, so
an *enabled* tracer stays cheap enough to leave on around jitted model
steps.  When the buffer wraps, the oldest events are overwritten (``dropped``
counts them); capacity is chosen at construction and never grows.

Export is Chrome trace-event JSON (the ``{"traceEvents": [...]}`` document
both ``chrome://tracing`` and https://ui.perfetto.dev open directly):
complete spans are ``"ph": "X"`` events with microsecond ``ts``/``dur``,
instant events are ``"ph": "i"``.  Span nesting is tracked per thread; the
recorded ``depth`` makes parent/child structure testable without re-deriving
it from timestamps.
"""
from __future__ import annotations

import threading
import time

# event tuple layout: (ph, name, ts_us, dur_us, tid, depth, args)
PH, NAME, TS, DUR, TID, DEPTH, ARGS = range(7)


class _NullSpan:
    """The shared do-nothing context manager handed out while tracing is
    disabled — no allocation per call site (``__slots__`` keeps it inert)."""

    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records a complete ("X") event at exit."""

    __slots__ = ("_tracer", "name", "args", "_t0", "depth")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        self.depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        tr = self._tracer
        tr._stack().pop()
        tr._record(("X", self.name, (self._t0 - tr._t0) / 1e3,
                    (t1 - self._t0) / 1e3, threading.get_ident(), self.depth,
                    self.args))
        return False


class Tracer:
    """Nested-span recorder over a preallocated ring buffer."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError("tracer capacity must be >= 1")
        self.capacity = capacity
        self._buf: list[tuple | None] = [None] * capacity
        self._n = 0
        self._t0 = time.perf_counter_ns()
        self._local = threading.local()

    # -- recording ----------------------------------------------------------

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, ev: tuple) -> None:
        self._buf[self._n % self.capacity] = ev
        self._n += 1

    def span(self, name: str, args: dict | None = None) -> _Span:
        """Context manager recording one complete span on exit."""
        return _Span(self, name, args)

    def instant(self, name: str, args: dict | None = None) -> None:
        """Record one instant ("i") event at the current time."""
        self._record(("i", name, (time.perf_counter_ns() - self._t0) / 1e3,
                      0.0, threading.get_ident(),
                      len(self._stack()), args))

    # -- reading ------------------------------------------------------------

    @property
    def recorded(self) -> int:
        """Total events ever recorded (including ones the ring dropped)."""
        return self._n

    @property
    def dropped(self) -> int:
        return max(0, self._n - self.capacity)

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def events(self) -> list[tuple]:
        """Surviving events, oldest first (ring unrolled)."""
        if self._n <= self.capacity:
            return [e for e in self._buf[:self._n]]
        i = self._n % self.capacity
        return self._buf[i:] + self._buf[:i]

    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON document (Perfetto-viewable)."""
        out = []
        for ev in self.events():
            d = {"name": ev[NAME], "ph": ev[PH], "ts": ev[TS],
                 "pid": 0, "tid": ev[TID],
                 "args": dict(ev[ARGS] or {}, depth=ev[DEPTH])}
            if ev[PH] == "X":
                d["dur"] = ev[DUR]
            else:
                d["s"] = "t"
            out.append(d)
        return {"traceEvents": out, "displayTimeUnit": "ms"}
