"""Telemetry snapshot/export + schema validation (DESIGN.md §13).

``snapshot`` folds one observability session (tracer ring + metrics
registry) into a schema-versioned plain dict; ``export_telemetry`` persists
it as ``artifacts/telemetry.json`` through the corrupt-safe atomic writer
shared with the tuning DB and solution registry, and
``export_chrome_trace`` writes the Perfetto-viewable trace document.

``validate_telemetry`` is the other half of the contract: CI's ``obs-smoke``
job (and ``python -m repro.obs <path>``) reject any artifact that drifts
from the schema, so downstream consumers — e.g. the learned cost model
training on accumulated (config, measurement) telemetry — can trust the
shape without defensive parsing.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

TELEMETRY_SCHEMA_VERSION = 1
DEFAULT_TELEMETRY_PATH = Path("artifacts/telemetry.json")
DEFAULT_TRACE_PATH = Path("artifacts/trace.json")


def snapshot(tracer, metrics) -> dict:
    """One schema-versioned document for the whole session."""
    return {
        "schema_version": TELEMETRY_SCHEMA_VERSION,
        "created_unix": int(time.time()),
        "trace": {
            "capacity": tracer.capacity,
            "recorded": tracer.recorded,
            "dropped": tracer.dropped,
            "events": [
                {"ph": ev[0], "name": ev[1], "ts_us": ev[2], "dur_us": ev[3],
                 "tid": ev[4], "depth": ev[5], "args": ev[6] or {}}
                for ev in tracer.events()
            ],
        },
        "metrics": metrics.snapshot(),
    }


def export_telemetry(tracer, metrics,
                     path: Path | str = DEFAULT_TELEMETRY_PATH) -> Path:
    """Write the telemetry snapshot atomically; returns the path."""
    from repro.core.artifacts import atomic_write_json

    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    atomic_write_json(path, snapshot(tracer, metrics))
    return path


def export_chrome_trace(tracer,
                        path: Path | str = DEFAULT_TRACE_PATH) -> Path:
    """Write the Chrome trace-event document (open in ui.perfetto.dev)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(tracer.to_chrome()) + "\n")
    return path


# ---------------------------------------------------------------------------
# Schema validation
# ---------------------------------------------------------------------------

_EVENT_KEYS = {"ph": str, "name": str, "ts_us": (int, float),
               "dur_us": (int, float), "tid": int, "depth": int,
               "args": dict}


def validate_telemetry(doc) -> list[str]:
    """Schema defects of a telemetry document; empty list == valid."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return [f"document is {type(doc).__name__}, expected object"]
    if doc.get("schema_version") != TELEMETRY_SCHEMA_VERSION:
        errs.append(f"schema_version {doc.get('schema_version')!r} != "
                    f"{TELEMETRY_SCHEMA_VERSION}")
    if not isinstance(doc.get("created_unix"), int):
        errs.append("created_unix missing or not an int")

    trace = doc.get("trace")
    if not isinstance(trace, dict):
        errs.append("trace section missing or not an object")
    else:
        for key in ("capacity", "recorded", "dropped"):
            if not isinstance(trace.get(key), int) or trace.get(key, -1) < 0:
                errs.append(f"trace.{key} missing or negative")
        events = trace.get("events")
        if not isinstance(events, list):
            errs.append("trace.events missing or not a list")
        else:
            for i, ev in enumerate(events):
                if not isinstance(ev, dict):
                    errs.append(f"trace.events[{i}] is not an object")
                    continue
                for key, typ in _EVENT_KEYS.items():
                    if not isinstance(ev.get(key), typ):
                        errs.append(f"trace.events[{i}].{key} missing or "
                                    f"mistyped")
                if ev.get("ph") not in ("X", "i"):
                    errs.append(f"trace.events[{i}].ph {ev.get('ph')!r} "
                                f"not in ('X', 'i')")
                if errs and len(errs) > 20:
                    errs.append("... (truncated)")
                    return errs

    met = doc.get("metrics")
    if not isinstance(met, dict):
        errs.append("metrics section missing or not an object")
    else:
        for section in ("counters", "gauges", "histograms"):
            if not isinstance(met.get(section), dict):
                errs.append(f"metrics.{section} missing or not an object")
        for name, h in (met.get("histograms") or {}).items():
            if not isinstance(h, dict):
                errs.append(f"metrics.histograms[{name!r}] is not an object")
                continue
            edges, counts = h.get("edges"), h.get("counts")
            if not isinstance(edges, list) or not isinstance(counts, list) \
                    or len(counts) != len(edges) + 1:
                errs.append(f"metrics.histograms[{name!r}]: counts must be "
                            f"len(edges) + 1 buckets")
            elif isinstance(h.get("count"), int) \
                    and sum(counts) != h["count"]:
                errs.append(f"metrics.histograms[{name!r}]: bucket counts "
                            f"do not sum to count")
    return errs


def validate_telemetry_file(path: Path | str) -> list[str]:
    path = Path(path)
    try:
        doc = json.loads(path.read_text())
    except FileNotFoundError:
        return [f"{path}: not found"]
    except json.JSONDecodeError as e:
        return [f"{path}: corrupt JSON ({e})"]
    return validate_telemetry(doc)
