"""Counters, gauges, and fixed-bucket histograms (DESIGN.md §13).

Instruments are get-or-create by name from a :class:`MetricsRegistry`; each
is a tiny ``__slots__`` object whose update methods do constant work — no
numpy on the record path, so observing a latency inside the serving loop
costs a couple of float ops.

Histograms have *fixed* bucket edges chosen at creation (half-open
``[edges[i-1], edges[i])`` buckets plus underflow/overflow), which keeps
``observe`` O(log n_buckets) and makes two histograms with the same edges
mergeable by adding counts.  ``quantile`` interpolates linearly inside the
covering bucket, clamped to the observed min/max, so estimates degrade
gracefully with bucket width instead of snapping to edges.
"""
from __future__ import annotations

import bisect
import math


def geometric_edges(lo: float, hi: float, per_octave: int = 4
                    ) -> tuple[float, ...]:
    """Geometric bucket edges from ``lo`` to at least ``hi`` with
    ``per_octave`` buckets per doubling — the default shape for latency
    histograms, whose values span decades."""
    if not (lo > 0 and hi > lo and per_octave >= 1):
        raise ValueError("need 0 < lo < hi and per_octave >= 1")
    ratio = 2.0 ** (1.0 / per_octave)
    edges = [lo]
    while edges[-1] < hi:
        edges.append(edges[-1] * ratio)
    return tuple(edges)


def linear_edges(lo: float, hi: float, n: int = 64) -> tuple[float, ...]:
    """``n`` equal-width buckets spanning [lo, hi]."""
    if not (hi > lo and n >= 1):
        raise ValueError("need hi > lo and n >= 1")
    step = (hi - lo) / n
    return tuple(lo + i * step for i in range(n + 1))


# default latency edges: 10 µs .. ~84 s, 4 buckets per octave
DEFAULT_TIME_EDGES = geometric_edges(1e-5, 64.0)
# small-integer count edges (batch sizes, pool sizes)
DEFAULT_COUNT_EDGES = tuple(float(v) for v in
                            (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024))


class Counter:
    """Monotone accumulator."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def to_dict(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Last-value instrument that also tracks the min/max it has seen."""

    __slots__ = ("value", "min", "max", "n_sets")

    def __init__(self):
        self.value = math.nan
        self.min = math.inf
        self.max = -math.inf
        self.n_sets = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        self.n_sets += 1

    def to_dict(self) -> dict:
        return {"value": self.value, "min": self.min, "max": self.max,
                "n_sets": self.n_sets}


class Histogram:
    """Fixed-bucket histogram: ``len(edges) + 1`` half-open buckets
    (underflow ``(-inf, edges[0])``, interior ``[edges[i-1], edges[i])``,
    overflow ``[edges[-1], inf)``)."""

    __slots__ = ("edges", "counts", "count", "total", "min", "max")

    def __init__(self, edges):
        edges = tuple(float(e) for e in edges)
        if len(edges) < 1 or any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError("edges must be non-empty, strictly increasing")
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        # a value exactly at an edge belongs to the bucket it opens
        self.counts[bisect.bisect_right(self.edges, v)] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def quantile(self, q: float) -> float:
        """Interpolated q-quantile estimate, clamped to [min, max]."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        rank = q * self.count
        cum = 0
        for b, c in enumerate(self.counts):
            if cum + c >= rank and c > 0:
                lo = self.edges[b - 1] if b > 0 else self.min
                hi = self.edges[b] if b < len(self.edges) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - cum) / c
                return min(max(lo + frac * (hi - lo), self.min), self.max)
            cum += c
        return self.max

    def to_dict(self) -> dict:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "count": self.count, "sum": self.total,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None}


class MetricsRegistry:
    """Get-or-create instrument store; one per observability session."""

    def __init__(self):
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def __len__(self) -> int:
        return (len(self._counters) + len(self._gauges)
                + len(self._histograms))

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, edges=None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                edges if edges is not None else DEFAULT_TIME_EDGES)
        return h

    def snapshot(self) -> dict:
        return {
            "counters": {k: v.to_dict()
                         for k, v in sorted(self._counters.items())},
            "gauges": {k: v.to_dict()
                       for k, v in sorted(self._gauges.items())},
            "histograms": {k: v.to_dict()
                           for k, v in sorted(self._histograms.items())},
        }
