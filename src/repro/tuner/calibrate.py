"""Calibration: fit the analytical cost model to measured latencies
(DESIGN.md §8.2).

The analytical model's job in the DSE loop is *ranking*, and its absolute
numbers target a TPU-instance abstraction — real kernels (or the interpret
backend on CPU) have different constants and different second-order terms.
Following the learned-co-design recipe (Shi et al., "Learned Hardware/
Software Co-Design of Neural Accelerators"), we keep the cheap model as the
feature generator and fit a small per-op correction from its predictions to
measured truth:

    log(measured_s) ≈ w · φ(report)

where φ is a log-space feature vector drawn from the CostReport the
analytical model already computes (predicted latency, calls, flops, HBM
bytes, utilization, compute fraction).  A ridge least-squares fit needs only
a few dozen measurements; with fewer samples the fit degrades gracefully to
a pure log-offset (scale) correction, and with none it is the identity.

:class:`CalibratedCostModel` exposes the corrected model through the same
``evaluate``/``evaluate_batch`` surface as ``core/cost_model.py`` (including
EvalCache sharing), so explorers can swap it in without code changes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.core.cost_model import (CostReport, EvalCache, _fingerprint,
                                   evaluate, evaluate_batch_reports)
from repro.core.hw_primitives import HWConfig
from repro.core.sw_primitives import Schedule
from repro.core.tst import TensorExpr

from .measure import MeasureResult, classify

N_FEATURES = 7
_MIN_LINEAR_SAMPLES = N_FEATURES + 3   # under this, offset-only is safer
_RIDGE = 1e-3


def features(report: CostReport) -> np.ndarray:
    """φ(report): log-space features of one analytical evaluation."""
    lat = report.latency_s
    if not math.isfinite(lat) or lat <= 0:
        return np.full(N_FEATURES, np.nan)
    total = report.compute_s + report.memory_s
    return np.array([
        1.0,
        math.log(lat),
        math.log1p(report.calls),
        math.log1p(report.flops),
        math.log1p(report.hbm_bytes),
        report.utilization,
        report.compute_s / total if total > 0 else 0.5,
    ])


@dataclass(frozen=True)
class Correction:
    """One op family's fitted analytical->measured latency map."""

    kind: str                      # 'identity' | 'offset' | 'linear'
    weights: tuple[float, ...] = ()
    offset: float = 0.0
    n_samples: int = 0

    def predict(self, report: CostReport) -> float:
        """Corrected latency for one analytical report (inf passes through)."""
        if not math.isfinite(report.latency_s):
            return report.latency_s
        if self.kind == "identity":
            return report.latency_s
        if self.kind == "offset":
            return report.latency_s * math.exp(self.offset)
        phi = features(report)
        return float(math.exp(float(np.dot(np.asarray(self.weights), phi))))

    def to_dict(self) -> dict:
        return {"kind": self.kind, "weights": list(self.weights),
                "offset": self.offset, "n_samples": self.n_samples}

    @classmethod
    def from_dict(cls, d: dict) -> "Correction":
        return cls(d.get("kind", "identity"),
                   tuple(d.get("weights", ())),
                   float(d.get("offset", 0.0)), int(d.get("n_samples", 0)))


IDENTITY = Correction("identity")


@dataclass
class Calibration:
    """Per-op corrections, persisted inside the tuning database."""

    corrections: dict[str, Correction] = field(default_factory=dict)

    def for_op(self, op: str) -> Correction:
        return self.corrections.get(op, IDENTITY)

    def to_dict(self) -> dict:
        return {op: c.to_dict() for op, c in self.corrections.items()}

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        return cls({op: Correction.from_dict(c) for op, c in d.items()})

    def __bool__(self) -> bool:
        return bool(self.corrections)


def fit_correction(reports: Sequence[CostReport],
                   measured_s: Sequence[float]) -> Correction:
    """Fit one op's correction from paired (analytical report, measured)."""
    phis, ys = [], []
    for rep, m in zip(reports, measured_s):
        phi = features(rep)
        if np.all(np.isfinite(phi)) and math.isfinite(m) and m > 0:
            phis.append(phi)
            ys.append(math.log(m))
    n = len(ys)
    if n == 0:
        return IDENTITY
    X = np.stack(phis)
    y = np.asarray(ys)
    if n < _MIN_LINEAR_SAMPLES:
        return Correction("offset", offset=float(np.median(y - X[:, 1])),
                          n_samples=n)
    # ridge least squares in log space; the bias column makes it affine
    A = X.T @ X + _RIDGE * np.eye(N_FEATURES)
    w = np.linalg.solve(A, X.T @ y)
    return Correction("linear", weights=tuple(float(v) for v in w),
                      n_samples=n)


def fit(samples: Sequence[tuple[str, CostReport, float]]) -> Calibration:
    """Fit per-op corrections from (op, analytical report, measured_s)."""
    by_op: dict[str, tuple[list, list]] = {}
    for op, rep, m in samples:
        by_op.setdefault(op, ([], []))
        by_op[op][0].append(rep)
        by_op[op][1].append(m)
    return Calibration({op: fit_correction(reps, ms)
                        for op, (reps, ms) in by_op.items()})


def collect_samples(workload: TensorExpr, reports: Sequence[CostReport],
                    results: Sequence[MeasureResult]
                    ) -> list[tuple[str, CostReport, float]]:
    """Pair analytical reports with successful measurements for fitting."""
    cls = classify(workload)
    if cls is None:
        return []
    op = cls[0]
    return [(op, rep, res.latency_s)
            for rep, res in zip(reports, results)
            if res.ok and rep.legal and math.isfinite(rep.latency_s)]


class CalibratedCostModel:
    """The analytical model with measured-truth corrections applied.

    Drop-in for the ``evaluate``/``evaluate_batch`` API: same signatures,
    same EvalCache protocol (the cache stores *analytical* reports, so one
    cache serves both the raw and the calibrated model), latency corrected
    per the workload's op family; power and area pass through unchanged.
    An EvalCache attached at construction becomes the default for every
    evaluate call, and its ``cache_hits``/``cache_misses``/``cache_hit_rate``
    are forwarded here so explorers can report reuse without reaching
    through to the cache object.
    """

    def __init__(self, calibration: Calibration,
                 target: str = "tpu", cache: EvalCache | None = None):
        self.calibration = calibration
        self.target = target
        self.cache = cache     # default EvalCache for evaluate/evaluate_batch
        self._op_cache: dict[tuple, str | None] = {}

    @property
    def cache_hits(self) -> int:
        """Hits of the attached EvalCache (0 when none is attached)."""
        return self.cache.hits if self.cache is not None else 0

    @property
    def cache_misses(self) -> int:
        return self.cache.misses if self.cache is not None else 0

    @property
    def cache_hit_rate(self) -> float:
        return self.cache.hit_rate if self.cache is not None else 0.0

    def _op(self, workload: TensorExpr) -> str | None:
        key = _fingerprint(workload)
        if key not in self._op_cache:
            cls = classify(workload)
            self._op_cache[key] = cls[0] if cls else None
        return self._op_cache[key]

    def evaluate(self, workload: TensorExpr, schedule: Schedule,
                 hw: HWConfig, target: str | None = None,
                 cache: EvalCache | None = None) -> CostReport:
        """Analytical report with its latency replaced by the corrected
        prediction (energy/power/area untouched)."""
        import dataclasses

        rep = evaluate(workload, schedule, hw, target or self.target,
                       cache=cache if cache is not None else self.cache)
        op = self._op(workload)
        if op is None or not rep.legal:
            return rep
        lat = self.calibration.for_op(op).predict(rep)
        return dataclasses.replace(rep, latency_s=lat)

    def evaluate_batch(self, workload: TensorExpr,
                       hw_configs, schedules: Sequence[Schedule],
                       target: str | None = None,
                       cache: EvalCache | None = None) -> np.ndarray:
        """(N, 3) minimized objectives with calibrated latency."""
        reports = evaluate_batch_reports(
            workload, hw_configs, schedules, target or self.target,
            cache=cache if cache is not None else self.cache)
        op = self._op(workload)
        corr = self.calibration.for_op(op) if op else IDENTITY
        ys = np.empty((len(reports), 3))
        for i, rep in enumerate(reports):
            lat = corr.predict(rep) if rep.legal else rep.latency_s
            ys[i] = (lat, rep.power_w, rep.area_um2)
        return ys

    def predict_latency(self, workload: TensorExpr,
                        reports: Sequence[CostReport]) -> np.ndarray:
        """Corrected latency for pre-computed analytical reports."""
        op = self._op(workload)
        corr = self.calibration.for_op(op) if op else IDENTITY
        return np.array([corr.predict(r) if r.legal else r.latency_s
                         for r in reports])


# ---------------------------------------------------------------------------
# Rank-correlation metric (scipy-free): how well does a model *order*
# candidates?  This is the quantity calibration must improve.
# ---------------------------------------------------------------------------


def _ranks(x: np.ndarray) -> np.ndarray:
    """Average ranks (ties shared), the classic Spearman prerequisite."""
    order = np.argsort(x, kind="mergesort")
    ranks = np.empty(len(x), dtype=float)
    sx = x[order]
    i = 0
    while i < len(x):
        j = i
        while j + 1 < len(x) and sx[j + 1] == sx[i]:
            j += 1
        ranks[order[i:j + 1]] = 0.5 * (i + j)
        i = j + 1
    return ranks


def spearman(a, b) -> float:
    """Spearman rank correlation over finite pairs; nan if degenerate."""
    a = np.asarray(a, dtype=float)
    b = np.asarray(b, dtype=float)
    m = np.isfinite(a) & np.isfinite(b)
    if m.sum() < 2:
        return float("nan")
    ra, rb = _ranks(a[m]), _ranks(b[m])
    ra -= ra.mean()
    rb -= rb.mean()
    denom = math.sqrt(float(ra @ ra) * float(rb @ rb))
    if denom == 0:
        return float("nan")
    return float(ra @ rb) / denom
