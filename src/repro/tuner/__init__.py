"""Measured-autotuning subsystem (DESIGN.md §8): close the loop from
analytical DSE to real Pallas kernel latencies.

  measure    lower (HWConfig, Schedule) candidates to concrete kernel
             invocations via ``kernels/ops.py`` and time them
             (warmup/repeat/median, failure capture)
  calibrate  fit per-op log-linear corrections from analytical predictions
             to measured latencies; CalibratedCostModel plugs into the
             ``evaluate_batch``/EvalCache API
  db         persistent tuning database keyed by (op, shape, dtype,
             backend): versioned JSON, merge-on-save, ``best_config``

The flow: ``codesign(measure=True, db_path=...)`` explores analytically,
re-ranks its Pareto frontier by measurement, and persists tuned block
shapes + calibration; ``kernels/ops.py`` dispatch and the launch drivers
consult the database at runtime.  ``python -m repro.tuner --help`` runs the
whole loop from the command line.
"""
from . import calibrate, db, measure
from .calibrate import CalibratedCostModel, Calibration, fit, spearman
from .db import DEFAULT_DB_PATH, TuningDB, TuningRecord
from .measure import (KernelPoint, MeasureOptions, MeasureResult, classify,
                      measure_batch, measure_one, summarize_batch)

__all__ = [
    "calibrate", "db", "measure",
    "CalibratedCostModel", "Calibration", "fit", "spearman",
    "DEFAULT_DB_PATH", "TuningDB", "TuningRecord",
    "KernelPoint", "MeasureOptions", "MeasureResult", "classify",
    "measure_batch", "measure_one", "summarize_batch",
]
