"""Persistent tuning database (DESIGN.md §8.3).

One versioned JSON artifact holds everything the runtime needs from a
co-design/tuning run:

  * ``records`` — best-measured kernel configurations keyed by
    ``(op, shape, dtype, backend)``: the block shapes ``kernels/ops.py``
    dispatch consults, plus the measured and predicted latencies that
    justify them;
  * ``calibration`` — the fitted per-op analytical->measured corrections
    (``tuner/calibrate.py``), so later explorations can start calibrated;
  * ``apps`` — per-application co-design solutions (accelerator config +
    intrinsic + objectives), subsuming the older ``core/solution.py``
    registry format;
  * ``failures`` / ``quarantine`` — bounded diagnostic failure records, and
    the persistently-failing kernel candidates future measurement runs skip
    unrun (DESIGN.md §14).

Robustness contract (shared with the hardened solution registry): corrupt or
missing files load as an empty database with a warning — a bad artifact must
never take down serving — and ``save()`` is atomic (tmp file + rename) with
merge-on-save, so concurrent tuning runs of different apps/shapes union
rather than clobber.
"""
from __future__ import annotations

import json
import math
import warnings
from contextlib import contextmanager
from dataclasses import dataclass
from pathlib import Path

from repro.core.artifacts import atomic_write_json, read_json_object

from .calibrate import Calibration

DB_VERSION = 1
DEFAULT_DB_PATH = Path("artifacts/tuning_db.json")
# the "failures" section is bounded: it is diagnostic data (which candidates
# fail, how, and how much wall clock they burn), not a ledger
MAX_FAILURES = 256


def _key(op: str, shape, dtype: str, backend: str) -> str:
    return "|".join([op, "x".join(str(int(v)) for v in shape), dtype, backend])


@dataclass
class TuningRecord:
    """Best-known kernel configuration for one (op, shape, dtype, backend)."""

    op: str
    shape: tuple[int, ...]
    dtype: str
    backend: str
    blocks: dict[str, int]
    measured_s: float = math.inf
    predicted_s: float = math.inf
    app: str = ""

    @property
    def key(self) -> str:
        return _key(self.op, self.shape, self.dtype, self.backend)

    def to_dict(self) -> dict:
        return {"op": self.op, "shape": list(self.shape), "dtype": self.dtype,
                "backend": self.backend,
                "blocks": {k: int(v) for k, v in self.blocks.items()},
                "measured_s": self.measured_s,
                "predicted_s": self.predicted_s, "app": self.app}

    @classmethod
    def from_dict(cls, d: dict) -> "TuningRecord":
        return cls(str(d["op"]), tuple(int(v) for v in d["shape"]),
                   str(d["dtype"]), str(d["backend"]),
                   {k: int(v) for k, v in d["blocks"].items()},
                   float(d.get("measured_s", math.inf)),
                   float(d.get("predicted_s", math.inf)),
                   str(d.get("app", "")))


class TuningDB:
    """In-memory view over the tuning artifact; see module docstring."""

    def __init__(self, path: Path | str = DEFAULT_DB_PATH):
        self.path = Path(path)
        self.records: dict[str, TuningRecord] = {}
        self.calibration = Calibration()
        self.apps: dict[str, dict] = {}
        self.failures: list[dict] = []
        # persistently failing kernel candidates (measure.quarantine_key ->
        # diagnostic info); future measurement runs skip these unrun
        self.quarantine: dict[str, dict] = {}

    # -- loading --------------------------------------------------------------
    @classmethod
    def load(cls, path: Path | str = DEFAULT_DB_PATH) -> "TuningDB":
        db = cls(path)
        data = _read_json(db.path)
        db._absorb(data)
        return db

    def _absorb(self, data: dict) -> None:
        """Fold a raw artifact dict in; schema defects (wrong-typed
        sections, malformed entries — hand edits, version skew, foreign
        files) are dropped with a warning, never fatal (the load contract)."""
        def section(name: str) -> dict:
            sec = data.get(name, {})
            if not isinstance(sec, dict):
                warnings.warn(f"tuning db {self.path}: ignoring {name!r} "
                              f"section of type {type(sec).__name__}",
                              stacklevel=4)
                return {}
            return sec

        for key, rec in section("records").items():
            try:
                self._merge_record(TuningRecord.from_dict(rec))
            except (KeyError, TypeError, ValueError, AttributeError) as e:
                warnings.warn(f"tuning db {self.path}: dropping malformed "
                              f"record {key!r} ({e})", stacklevel=3)
        for op, corr in section("calibration").items():
            try:
                corr = Calibration.from_dict({op: corr}).corrections[op]
            except (KeyError, TypeError, ValueError, AttributeError) as e:
                warnings.warn(f"tuning db {self.path}: dropping malformed "
                              f"calibration for {op!r} ({e})", stacklevel=3)
                continue
            mine = self.calibration.corrections.get(op)
            if mine is None or corr.n_samples >= mine.n_samples:
                self.calibration.corrections[op] = corr
        for app, sol in section("apps").items():
            if not isinstance(sol, dict):
                warnings.warn(f"tuning db {self.path}: dropping malformed "
                              f"app entry {app!r}", stacklevel=3)
                continue
            if app not in self.apps:
                self.apps[app] = sol
        fails = data.get("failures", [])
        if isinstance(fails, list):
            self.add_failures(f for f in fails if isinstance(f, dict))
        elif "failures" in data:
            warnings.warn(f"tuning db {self.path}: ignoring 'failures' "
                          f"section of type {type(fails).__name__}",
                          stacklevel=4)
        for key, info in section("quarantine").items():
            if not isinstance(info, dict):
                warnings.warn(f"tuning db {self.path}: dropping malformed "
                              f"quarantine entry {key!r}", stacklevel=3)
                continue
            self.quarantine.setdefault(str(key), info)

    def _merge_record(self, rec: TuningRecord) -> None:
        cur = self.records.get(rec.key)
        if cur is None or rec.measured_s < cur.measured_s:
            self.records[rec.key] = rec

    # -- updates --------------------------------------------------------------
    def record(self, rec: TuningRecord) -> bool:
        """Keep ``rec`` if it beats the stored config; -> whether it did."""
        cur = self.records.get(rec.key)
        if cur is None or rec.measured_s < cur.measured_s:
            self.records[rec.key] = rec
            return True
        return False

    def set_calibration(self, calibration: Calibration) -> None:
        for op, corr in calibration.corrections.items():
            self.calibration.corrections[op] = corr

    def set_app(self, app: str, solution: dict) -> None:
        self.apps[app] = solution

    def add_failures(self, failures) -> None:
        """Append measurement-failure records (plain dicts: workload,
        error_type, error, elapsed_s, backend, app...).  Deduplicated by
        content — re-absorbing a file this db was saved to is a no-op — and
        capped at MAX_FAILURES most-recent entries."""
        self.failures.extend(dict(f) for f in failures)
        seen: set[str] = set()
        out: list[dict] = []
        for f in self.failures:
            k = json.dumps(f, sort_keys=True, default=str)
            if k not in seen:
                seen.add(k)
                out.append(f)
        self.failures = out[-MAX_FAILURES:]

    def quarantine_candidate(self, key: str, info: dict | None = None) -> bool:
        """Quarantine one kernel candidate (``measure.quarantine_key``
        string): future measurement runs skip it without burning wall
        clock.  -> whether the key was newly quarantined."""
        if key in self.quarantine:
            return False
        self.quarantine[key] = dict(info or {})
        return True

    def quarantined_keys(self) -> set[str]:
        return set(self.quarantine)

    # -- lookups --------------------------------------------------------------
    def best_config(self, op: str, shape, dtype: str = "float32",
                    backend: str = "interpret") -> dict[str, int] | None:
        """Tuned block shapes for an exact (op, shape, dtype, backend), or
        None — callers fall back to their safe defaults."""
        rec = self.records.get(_key(op, shape, dtype, backend))
        return dict(rec.blocks) if rec is not None else None

    def best_record(self, op: str, shape, dtype: str = "float32",
                    backend: str = "interpret") -> TuningRecord | None:
        return self.records.get(_key(op, shape, dtype, backend))

    # -- persistence ----------------------------------------------------------
    def to_dict(self) -> dict:
        out = {
            "version": DB_VERSION,
            "records": {k: r.to_dict()
                        for k, r in sorted(self.records.items())},
            "calibration": self.calibration.to_dict(),
            "apps": dict(sorted(self.apps.items())),
        }
        if self.failures:   # optional section: old artifacts stay byte-stable
            out["failures"] = list(self.failures)
        if self.quarantine:   # optional, same byte-stability contract
            out["quarantine"] = dict(sorted(self.quarantine.items()))
        return out

    def save(self, path: Path | str | None = None) -> Path:
        """Merge-on-save + atomic write: re-read whatever is on disk now,
        union it in (best-measured wins per key), then tmp-file + rename so a
        reader never sees a torn artifact.  The read-merge-write sequence
        holds an flock on a sidecar lock file, so *concurrent* tuning runs
        serialize and genuinely union rather than last-writer-wins."""
        path = Path(path) if path is not None else self.path
        path.parent.mkdir(parents=True, exist_ok=True)
        with _save_lock(path):
            return self._save_locked(path)

    def _save_locked(self, path: Path) -> Path:
        on_disk = _read_json(path)
        if on_disk:
            merged = TuningDB(path)
            merged.records = dict(self.records)
            merged.calibration = Calibration(dict(
                self.calibration.corrections))
            merged.apps = dict(self.apps)
            merged.failures = [dict(f) for f in self.failures]
            merged.quarantine = {k: dict(v)
                                 for k, v in self.quarantine.items()}
            merged._absorb(on_disk)
            # our freshly-set apps/calibration win over stale on-disk ones
            merged.apps.update(self.apps)
            merged.calibration.corrections.update(
                self.calibration.corrections)
            payload = merged.to_dict()
        else:
            payload = self.to_dict()
        atomic_write_json(path, payload)
        return path


def _read_json(path: Path) -> dict:
    return read_json_object(path, "tuning db")


@contextmanager
def _save_lock(path: Path):
    """Advisory flock over ``path``'s sidecar .lock file; degrades to
    unlocked (atomic-rename-only) where flock is unavailable."""
    lock = None
    try:
        import fcntl

        lock = open(path.with_name(path.name + ".lock"), "w")
        fcntl.flock(lock, fcntl.LOCK_EX)
    except (ImportError, OSError):
        if lock is not None:
            lock.close()
            lock = None
    try:
        yield
    finally:
        if lock is not None:
            lock.close()   # closing drops the flock
